"""Hybrid sparse encoding (paper H1): roundtrips, format rule, size model."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from repro.core import sparse


@given(st.integers(1, 40), st.integers(1, 120), st.floats(0.0, 1.0),
       st.integers(0, 10_000))
def test_bitmap_roundtrip(rows, cols, sparsity, seed):
    rng = np.random.RandomState(seed)
    w = rng.randn(rows, cols).astype(np.float32)
    w[rng.rand(rows, cols) < sparsity] = 0
    enc = sparse.encode_bitmap(w)
    dec = np.asarray(sparse.decode_bitmap(enc))
    np.testing.assert_array_equal(dec, w)


@given(st.integers(1, 40), st.integers(1, 120), st.floats(0.0, 1.0),
       st.integers(0, 10_000))
def test_coo_roundtrip(rows, cols, sparsity, seed):
    rng = np.random.RandomState(seed)
    w = rng.randn(rows, cols).astype(np.float32)
    w[rng.rand(rows, cols) < sparsity] = 0
    enc = sparse.encode_coo(w)
    dec = np.asarray(sparse.decode_coo(enc))
    np.testing.assert_array_equal(dec, w)


def test_coo_coords_sorted_and_lookup():
    rng = np.random.RandomState(3)
    w = rng.randn(16, 16).astype(np.float32)
    w[rng.rand(16, 16) < 0.85] = 0
    enc = sparse.encode_coo(w)
    c = np.asarray(enc.coords)[: enc.nnz]
    assert np.all(np.diff(c) > 0)
    q = jnp.arange(256, dtype=jnp.int32)
    got = np.asarray(sparse.coo_lookup(enc, q))
    np.testing.assert_array_equal(got, w.reshape(-1))


def test_choose_format_threshold():
    assert sparse.choose_format(0.79) == "bitmap"
    assert sparse.choose_format(0.80) == "coo"
    assert sparse.choose_format(0.95) == "coo"
    assert sparse.choose_format(0.04) == "bitmap"


def test_storage_model_crossover():
    """Byte-model facts behind the paper's 80% rule: bitmap wins at low
    sparsity, COO at very high. NOTE the pure-storage crossover for fp32
    values + int32 coords sits near ~95%, ABOVE the paper's 80% — their
    threshold also prices decode latency (3-cycle bitmap lookup vs log-depth
    search). Measured in benchmarks/encoding_table.py; see EXPERIMENTS.md."""
    shape = (128, 128)
    total = shape[0] * shape[1]
    for s in (0.2, 0.5, 0.7, 0.8, 0.9):
        nnz = int(total * (1 - s))
        assert (sparse.storage_bytes(shape, nnz, "bitmap")
                < sparse.storage_bytes(shape, nnz, "coo"))
    for s in (0.97, 0.99):
        nnz = int(total * (1 - s))
        assert (sparse.storage_bytes(shape, nnz, "coo")
                < sparse.storage_bytes(shape, nnz, "bitmap"))
    # bitmap beats dense at any meaningful sparsity
    nnz = int(total * 0.7)            # 30% sparse
    assert sparse.storage_bytes(shape, nnz, "bitmap") < \
        sparse.storage_bytes(shape, nnz, "dense")


def test_encode_hybrid_picks_by_sparsity():
    rng = np.random.RandomState(0)
    dense_ish = rng.randn(32, 32).astype(np.float32)
    dense_ish[rng.rand(32, 32) < 0.3] = 0
    fmt, s, _ = sparse.encode_hybrid(dense_ish)
    assert fmt == "bitmap" and s < 0.5
    sparse_w = rng.randn(32, 32).astype(np.float32)
    sparse_w[rng.rand(32, 32) < 0.95] = 0
    fmt2, s2, _ = sparse.encode_hybrid(sparse_w)
    assert fmt2 == "coo" and s2 > 0.8


def test_encode_hybrid_roundtrip_at_threshold_boundary():
    """Exactly-0.79 sparsity must pick bitmap, exactly-0.81 COO, and both
    must round-trip bit-exactly (the codec boundary the renderer relies on)."""
    rng = np.random.RandomState(0)
    for n_zero, want_fmt in ((79, "bitmap"), (80, "coo"), (81, "coo")):
        w = rng.randn(10, 10).astype(np.float32)
        w[np.unravel_index(rng.permutation(100)[:n_zero], w.shape)] = 0
        assert int((w == 0).sum()) == n_zero
        fmt, s, enc = sparse.encode_hybrid(w)
        assert fmt == want_fmt, (n_zero, fmt)
        dec = np.asarray(sparse.decode_coo(enc) if fmt == "coo"
                         else sparse.decode_bitmap(enc))
        np.testing.assert_array_equal(dec, w)


def test_bitmap_all_zero_and_empty_rows():
    w = np.zeros((8, 40), np.float32)
    enc = sparse.encode_bitmap(w)
    assert enc.nnz == 0
    np.testing.assert_array_equal(np.asarray(sparse.decode_bitmap(enc)), w)
    q = jnp.arange(8 * 40, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(sparse.bitmap_lookup(enc, q)),
                                  np.zeros(8 * 40, np.float32))
    # rows 0, 3, 7 empty; lookups across empty rows must still land on the
    # right packed addresses for the non-empty ones
    w2 = np.zeros((8, 40), np.float32)
    rng = np.random.RandomState(1)
    for r in (1, 2, 4, 5, 6):
        w2[r, rng.randint(0, 40, 7)] = rng.randn(7)
    enc2 = sparse.encode_bitmap(w2)
    got = np.asarray(sparse.bitmap_lookup(enc2, q)).reshape(8, 40)
    np.testing.assert_array_equal(got, w2)


def test_coo_all_zero_and_empty_rows():
    w = np.zeros((4, 32), np.float32)
    enc = sparse.encode_coo(w)
    assert enc.nnz == 0
    np.testing.assert_array_equal(np.asarray(sparse.decode_coo(enc)), w)
    q = jnp.arange(4 * 32, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(sparse.coo_lookup(enc, q)),
                                  np.zeros(4 * 32, np.float32))
    w2 = np.zeros((4, 32), np.float32)
    w2[2, 5] = 1.5
    w2[2, 30] = -2.0
    enc2 = sparse.encode_coo(w2)
    got = np.asarray(sparse.coo_lookup(enc2, q)).reshape(4, 32)
    np.testing.assert_array_equal(got, w2)


def test_bitmap_lookup_matches_decode():
    rng = np.random.RandomState(7)
    w = rng.randn(13, 70).astype(np.float32)
    w[rng.rand(13, 70) < 0.5] = 0
    enc = sparse.encode_bitmap(w)
    q = jnp.asarray(rng.randint(0, 13 * 70, 300), jnp.int32)
    got = np.asarray(sparse.bitmap_lookup(enc, q))
    want = np.asarray(sparse.decode_bitmap(enc)).reshape(-1)[np.asarray(q)]
    np.testing.assert_array_equal(got, want)


def test_factor_report_on_field():
    import jax
    from repro.configs.rtnerf import NeRFConfig
    from repro.core import tensorf
    cfg = NeRFConfig(grid_res=16, r_sigma=4, r_color=4, app_dim=6,
                     mlp_hidden=8)
    params = tensorf.init_field(cfg, jax.random.PRNGKey(0))
    params = tensorf.prune_factors(params, tol=0.05)
    rep = sparse.factor_report(params)
    assert len(rep) == 12                       # 4 factor kinds x 3 modes
    for v in rep.values():
        assert 0.0 <= v["sparsity"] <= 1.0
        assert v["chosen_bytes"] == min(v["bitmap_bytes"], v["coo_bytes"]) or \
            v["format"] in ("bitmap", "coo")
