"""SSM/RWKV block-level invariants, incl. the §Perf chunked-SSD equivalence
(the optimization is only admissible because this test pins it to the
sequential-scan oracle)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, reduced
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.common import Maker, split_pl

CFG = reduced(ARCHS["zamba2-7b"])


def _mamba_params(seed=0):
    mk = Maker(jax.random.PRNGKey(seed), dtype=jnp.float32)
    p, _ = split_pl(ssm_lib.init_mamba2(mk, CFG))
    return p


@pytest.mark.parametrize("seq", [8, 64, 130])   # incl. non-multiple of chunk
def test_ssd_chunked_matches_scan(seq):
    p = _mamba_params()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, CFG.d_model),
                          jnp.float32) * 0.5
    y_scan, st_scan = ssm_lib.mamba2_forward(p, CFG, x, impl="scan")
    y_chunk, st_chunk = ssm_lib.mamba2_forward(p, CFG, x, impl="chunked")
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_chunk),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_scan["h"]),
                               np.asarray(st_chunk["h"]), rtol=2e-3, atol=2e-3)


def test_mamba_decode_matches_forward():
    """Step-by-step decode must equal the train-mode scan."""
    p = _mamba_params()
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, CFG.d_model)) * 0.5
    y_full, _ = ssm_lib.mamba2_forward(p, CFG, x, impl="scan")
    d_in, nh, conv_ch = ssm_lib.ssm_dims(CFG)
    state = {"h": jnp.zeros((B, nh, CFG.ssm_head_dim, CFG.ssm_state)),
             "conv": jnp.zeros((B, CFG.ssm_conv - 1, conv_ch), x.dtype)}
    outs = []
    for t in range(S):
        y, state = ssm_lib.mamba2_decode(p, CFG, x[:, t:t + 1], state)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_decode_matches_forward():
    cfg = reduced(ARCHS["rwkv6-1.6b"])
    mk = Maker(jax.random.PRNGKey(0), dtype=jnp.float32)
    p, _ = split_pl(rwkv_lib.init_rwkv6(mk, cfg))
    B, S = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model)) * 0.5
    y_full, _ = rwkv_lib.rwkv6_forward(p, cfg, x)
    state = None
    outs = []
    for t in range(S):
        y, state = rwkv_lib.rwkv6_forward(p, cfg, x[:, t:t + 1], state=state)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_state_is_constant_size():
    cfg = reduced(ARCHS["rwkv6-1.6b"])
    st = rwkv_lib.rwkv6_state_shape(cfg, batch=4)
    n_bytes = sum(np.prod(s.shape) * s.dtype.itemsize
                  for s in jax.tree.leaves(st))
    assert n_bytes < 1e6      # O(1) in sequence length — the long_500k story


def test_grad_accum_matches_full_batch():
    """§Perf knob: grad_accum=4 step == single-batch step (same update)."""
    from repro.configs.base import ShapeConfig
    from repro.data.tokens import TokenStream
    from repro.launch.steps import build_train_step
    from repro.models import transformer as tf
    from repro.models.sharding import make_rules
    from repro.launch.mesh import make_host_mesh
    from repro.optim import sgd

    cfg = reduced(ARCHS["llama3.2-1b"])
    cfg_acc = dataclasses.replace(cfg, grad_accum=4)
    shape = ShapeConfig("t", 16, 8, "train")
    batch = TokenStream(cfg, shape).batch(0)
    params, _ = split_pl(tf.init_model(cfg, jax.random.PRNGKey(0)))
    rules = make_rules(make_host_mesh())
    opt = sgd(lr=0.1)

    s1 = build_train_step(cfg, rules, opt)
    s2 = build_train_step(cfg_acc, rules, opt)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    # bf16 grad accumulation: modest tolerance
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)
