"""RT-NeRF core invariants: Eq.2 field, occupancy, pipeline A1/A2."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, tensorf
from repro.data import rays as rays_lib

CFG = NeRFConfig(grid_res=32, occ_res=32, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, near=2.0, far=6.0)


@pytest.fixture(scope="module")
def field():
    return tensorf.init_field(CFG, jax.random.PRNGKey(0))


def test_eq2_matches_explicit_sum(field):
    """Eq. 2: sigma = softplus(sum_m sum_r plane_m[r](a,b) * line_m[r](c))."""
    pts = jax.random.uniform(jax.random.PRNGKey(1), (64, 3),
                             minval=-1.0, maxval=1.0)
    got = tensorf.eval_sigma(field, CFG, pts)
    pg = tensorf.to_grid(CFG, pts)
    acc = 0.0
    for m in range(3):
        a, b = tensorf.PLANE_AXES[m]
        pm = tensorf._interp_plane(field["sigma_planes"][m], pg[:, a], pg[:, b])
        lm = tensorf._interp_line(field["sigma_lines"][m], pg[:, m])
        acc = acc + jnp.sum(pm * lm, axis=0)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jax.nn.softplus(acc)), rtol=1e-5)


def test_sigma_nonnegative_and_color_bounded(field):
    pts = jax.random.uniform(jax.random.PRNGKey(2), (128, 3),
                             minval=-1.5, maxval=1.5)
    sig = tensorf.eval_sigma(field, CFG, pts)
    assert np.all(np.asarray(sig) >= 0)
    feats = tensorf.eval_app_features(field, CFG, pts)
    dirs = jnp.ones((128, 3)) / np.sqrt(3)
    rgb = tensorf.eval_color(field, CFG, feats, dirs)
    assert np.all(np.asarray(rgb) >= 0) and np.all(np.asarray(rgb) <= 1)


def test_prune_creates_exact_zeros(field):
    pruned = tensorf.prune_factors(field, tol=0.05)
    sp = tensorf.factor_sparsity(pruned)
    assert all(0 < v < 1 for v in sp.values())
    assert np.all(np.asarray(jnp.abs(pruned["sigma_planes"])
                             [pruned["sigma_planes"] != 0]) >= 0.05)


def test_occupancy_and_cube_extraction(field):
    occ = occ_lib.build_occupancy(field, CFG, sigma_thresh=1.0)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.centers.shape == (CFG.max_cubes, 3)
    assert cubes.count == int(np.asarray(cubes.valid).sum())
    # every valid cube center lies inside the scene bound
    c = np.asarray(cubes.centers)[np.asarray(cubes.valid)]
    assert np.all(np.abs(c) <= CFG.scene_bound)
    # occupancy query agrees with the raw grid
    pts = jnp.asarray(c[:8], jnp.float32)
    hit = occ_lib.occupancy_query(occ, CFG, pts)
    gc = CFG.cube_size
    # a cube is non-zero because SOME voxel inside is occupied; probing the
    # center may miss, so just check the query runs and is boolean
    assert hit.dtype == jnp.bool_


def test_order_cubes_front_to_back(field):
    occ = occ_lib.build_occupancy(field, CFG, sigma_thresh=1.0)
    cubes = occ_lib.extract_cubes(occ, CFG)
    origin = jnp.asarray([4.0, 0.0, 0.0])
    perm = rt_pipe.order_cubes(cubes, origin, "distance")
    c = np.asarray(cubes.centers)[np.asarray(perm)]
    v = np.asarray(cubes.valid)[np.asarray(perm)]
    d = np.linalg.norm(c - np.asarray(origin), axis=-1)
    dv = d[v]
    assert np.all(np.diff(dv) >= -1e-5)         # sorted front-to-back
    assert not v[len(dv):].any()                # invalid cubes pushed last

    perm_o = rt_pipe.order_cubes(cubes, origin, "octant")
    vo = np.asarray(cubes.valid)[np.asarray(perm_o)]
    assert vo[: int(vo.sum())].all()            # valid first under octant too


def _trained_setup():
    """Small trained field shared by the pipeline-equivalence tests.

    occ_sigma_thresh=2.0: these tests probe pipeline equivalence (ordering
    invariance, chunking) on a compact cube set; the low serving default
    (0.5) floods a 120-step field with near-empty cubes, which inflates the
    documented chunk>1 overlap approximation rather than testing it. The
    trainer reads whatever the config says — this is the config saying it.
    """
    from repro.core import train as nerf_train
    cfg = NeRFConfig(grid_res=32, occ_res=32, cube_size=4, max_cubes=512,
                     r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                     max_samples_per_ray=96, train_rays=512,
                     occ_sigma_thresh=2.0)
    res = nerf_train.train_nerf(cfg, "mic", steps=120, n_views=6,
                                image_hw=48, log_every=1000, verbose=False)
    scene = rays_lib.make_scene("mic")
    cam = rays_lib.make_cameras(5, 48, 48)[1]
    gt = rays_lib.render_gt(scene, cam)
    return cfg, res, cam, gt


@pytest.fixture(scope="module")
def trained():
    return _trained_setup()


def test_pipeline_matches_uniform_psnr(trained):
    cfg, res, cam, gt = trained
    from repro.core import train as nerf_train
    p_uni, s_uni, _ = nerf_train.eval_view(res.field, cfg, res.cubes, cam,
                                           gt, pipeline="uniform")
    p_rt, s_rt, _ = nerf_train.eval_view(res.field, cfg, res.cubes, cam, gt,
                                         pipeline="rtnerf")
    assert p_rt > p_uni - 1.5                   # quality parity (box clip)
    # A1 claim: occupancy accesses reduced by orders of magnitude
    assert s_rt["occ_accesses"] < s_uni["occ_accesses"] / 50


def test_ordering_modes_agree(trained):
    """A2 invariance: octant vs distance order must render the same image
    (compositing along each ray is order-independent across disjoint cubes
    as long as both orders are front-to-back per ray ... up to early-term
    boundary effects, so compare loosely)."""
    cfg, res, cam, gt = trained
    img_o, _ = rt_pipe.render_rtnerf(res.field, cfg, res.cubes, cam,
                                     order_mode="octant")
    img_d, _ = rt_pipe.render_rtnerf(res.field, cfg, res.cubes, cam,
                                     order_mode="distance")
    diff = np.abs(np.asarray(img_o) - np.asarray(img_d)).mean()
    assert diff < 5e-3


def test_chunked_matches_sequential(trained):
    cfg, res, cam, gt = trained
    img_1, _ = rt_pipe.render_rtnerf(res.field, cfg, res.cubes, cam, chunk=1)
    img_8, _ = rt_pipe.render_rtnerf(res.field, cfg, res.cubes, cam, chunk=8)
    diff = np.abs(np.asarray(img_1) - np.asarray(img_8)).mean()
    assert diff < 5e-3


def test_early_termination_reduces_work(trained):
    cfg, res, cam, gt = trained
    import dataclasses
    cfg_no_term = dataclasses.replace(cfg, term_eps=0.0)
    _, s_term = rt_pipe.render_rtnerf(res.field, cfg, res.cubes, cam)
    _, s_all = rt_pipe.render_rtnerf(res.field, cfg_no_term, res.cubes, cam)
    assert float(s_term["processed_samples"]) <= float(s_all["processed_samples"])


def test_composite_eq1_white_background():
    sigma = jnp.zeros((4, 8))
    rgb = jnp.ones((4, 8, 3)) * 0.3
    color, t_final, w = rendering.composite(sigma, rgb,
                                            jnp.ones((4, 8), bool), 0.1)
    np.testing.assert_allclose(np.asarray(color), 1.0)   # empty -> white bg
    np.testing.assert_allclose(np.asarray(t_final), 1.0)


def test_gt_renderer_and_cameras():
    scene = rays_lib.make_scene("chair")
    cam = rays_lib.make_cameras(3, 32, 32)[0]
    img = rays_lib.render_gt(scene, cam)
    a = np.asarray(img)
    assert a.shape == (32 * 32, 3)
    assert np.all(a >= 0) and np.all(a <= 1)
    assert a.min() < 0.95                        # something visible
    o, d = rendering.camera_rays(cam)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(d), axis=-1), 1.0,
                               rtol=1e-5)
