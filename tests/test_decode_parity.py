"""Integration: prefill-then-decode must reproduce teacher-forced forward
logits for every cache family (GQA, MLA, MoE, enc-dec, Mamba2, RWKV6,
hybrid). The strongest correctness check of the serving path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, reduced
from repro.models import transformer as tf
from repro.models.common import split_pl

# one representative per cache family
FAMILIES = ["llama3.2-1b", "deepseek-v3-671b", "grok-1-314b",
            "seamless-m4t-large-v2", "zamba2-7b", "rwkv6-1.6b"]


@pytest.mark.parametrize("name", FAMILIES)
def test_prefill_then_decode_matches_forward(name):
    import dataclasses
    cfg = reduced(ARCHS[name])
    if cfg.is_moe:
        # isolate cache correctness from capacity-drop semantics: COO
        # dispatch groups differ between teacher-forced forward (per-seq)
        # and decode (per-batch), so give capacity headroom
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params, _ = split_pl(tf.init_model(cfg, jax.random.PRNGKey(0)))
    B, S = 2, 12
    n_gen = 4
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks[:, : S - n_gen]}
    if cfg.enc_dec:
        frames = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        batch["enc_frames"] = frames

    # prefill on the prompt
    logits_p, cache = jax.jit(
        lambda p, b: tf.model_prefill(p, cfg, b))(params, batch)

    # pad caches to full horizon S (cross-KV stays at true encoder length)
    shapes, _ = tf.serve_cache_spec(cfg, B, S, enc_len=S)

    def fit(c, s):
        if c is None:
            return None
        if tuple(c.shape) == tuple(s.shape):
            return c.astype(s.dtype)
        pad = [(0, a - b) for a, b in zip(s.shape, c.shape)]
        return jnp.pad(c.astype(s.dtype), pad)
    cache = jax.tree.map(fit, cache, shapes)

    decode = jax.jit(lambda p, t, pos, c: tf.model_decode(
        p, cfg, t, pos, c, seq_len=S))

    # teacher-forced decode of the last n_gen tokens
    dec_logits = [logits_p]
    for i in range(n_gen - 1):
        pos = S - n_gen + i
        t = toks[:, pos:pos + 1]
        lg, cache = decode(params, t, jnp.int32(pos), cache)
        dec_logits.append(lg)
    dec = jnp.concatenate(dec_logits, axis=1)     # (B, n_gen, V)
    # MLA decode uses the weight-absorbed formulation — mathematically equal
    # but bf16-reassociated, so its tolerance is wider.
    tol = 8e-2 if cfg.attention == "mla" else 3e-2

    # full teacher-forced forward over all S tokens
    full_batch = {"tokens": toks}
    if cfg.enc_dec:
        full_batch["enc_frames"] = frames
    h_logits = _full_logits(params, cfg, full_batch)
    want = h_logits[:, S - n_gen - 1: S - 1]      # logits predicting t+1

    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def _full_logits(params, cfg, batch):
    """All-position logits via the training trunk (no loss)."""
    import repro.models.transformer as t

    memory = None
    if cfg.enc_dec:
        frames = batch["enc_frames"].astype(jnp.bfloat16)
        memory = t._scan_encoder(params["enc"], cfg, frames,
                                 jnp.arange(frames.shape[1]))
        from repro.models.common import rms_norm
        memory = rms_norm(memory, params["enc_norm"], cfg.norm_eps)
    x, positions = t._assemble_input(params, cfg, batch)
    h, _, _ = t._trunk(params, cfg, x, positions, memory=memory)
    return jax.jit(lambda p, hh: t._logits(p, cfg, hh))(params, h)
