"""Compressed-field (hybrid bitmap/COO) rendering path: codec boundary,
dense/hybrid eval parity, and end-to-end render parity (paper Sec. 4.2.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, sparse, tensorf
from repro.data import rays as rays_lib

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _pruned_field(target=0.9, seed=0):
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    return tensorf.prune_to_sparsity(params, target)


def test_prune_to_sparsity_hits_target():
    params = _pruned_field(0.9)
    for k, s in tensorf.factor_sparsity(params).items():
        assert s >= 0.89, (k, s)


def test_compress_field_roundtrip_exact():
    params = _pruned_field(0.9)
    cf = sparse.compress_field(params, CFG)
    rec = sparse.decompress_field(cf)
    for k in sparse.FACTOR_KEYS:
        np.testing.assert_array_equal(np.asarray(rec[k]),
                                      np.asarray(params[k]))
    # extras pass through untouched
    assert "basis" in cf.extras and "mlp_w1" in cf.extras


def test_compress_field_dense_factors_stay_dense():
    """Don't pessimize: an unpruned (fully dense) field must not be encoded
    into a format larger than its raw bytes."""
    params = tensorf.init_field(CFG, jax.random.PRNGKey(1))
    cf = sparse.compress_field(params, CFG)
    for efs in cf.factors.values():
        for ef in efs:
            assert ef.fmt == "dense"
            assert ef.storage() <= ef.dense_storage()
    assert cf.factor_bytes() == cf.dense_factor_bytes()


def test_compress_field_bytes_ratio_at_90pct():
    cf = sparse.compress_field(_pruned_field(0.9), CFG)
    assert cf.compression_ratio() >= 3.0
    for efs in cf.factors.values():
        for ef in efs:
            assert ef.fmt == "coo"          # 0.9 >= 0.8 threshold
            assert ef.storage() < ef.dense_storage()


def test_compress_field_respects_threshold():
    """Between the storage break-even and the 0.80 switch, factors encode
    as bitmap; at/above the switch, COO."""
    params = _pruned_field(0.6)
    cf = sparse.compress_field(params, CFG, threshold=0.80)
    fmts = {ef.fmt for efs in cf.factors.values() for ef in efs}
    assert "coo" not in fmts                # 0.6 sparsity < threshold
    cf2 = sparse.compress_field(params, CFG, threshold=0.55)
    fmts2 = {ef.fmt for efs in cf2.factors.values() for ef in efs}
    assert "coo" in fmts2


@pytest.mark.parametrize("target", [0.6, 0.9])
def test_eval_sigma_hybrid_matches_dense(target):
    params = _pruned_field(target)
    cf = sparse.compress_field(params, CFG)
    pts = jax.random.uniform(jax.random.PRNGKey(2), (513, 3),
                             minval=-1.4, maxval=1.4)
    sd = np.asarray(tensorf.eval_sigma(params, CFG, pts))
    sh = np.asarray(tensorf.eval_sigma_hybrid(cf, CFG, pts))
    np.testing.assert_allclose(sh, sd, rtol=1e-6, atol=1e-6)


def test_eval_app_features_hybrid_matches_dense():
    params = _pruned_field(0.9)
    cf = sparse.compress_field(params, CFG)
    pts = jax.random.uniform(jax.random.PRNGKey(3), (257, 3),
                             minval=-1.4, maxval=1.4)
    fd = np.asarray(tensorf.eval_app_features(params, CFG, pts))
    fh = np.asarray(tensorf.eval_app_features_hybrid(cf, CFG, pts))
    np.testing.assert_allclose(fh, fd, rtol=1e-5, atol=1e-6)


def test_hybrid_render_psnr_vs_dense():
    """End-to-end: the RT-NeRF pipeline rendered from the compressed stream
    must match the dense-factor render (>= 40 dB on a pruned toy field)."""
    params = _pruned_field(0.9)
    occ = occ_lib.build_occupancy(params, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.count > 0
    cam = rays_lib.make_cameras(3, 32, 32)[0]
    img_d, st_d = rt_pipe.render_rtnerf(params, CFG, cubes, cam, chunk=8,
                                        field_mode="dense")
    img_h, st_h = rt_pipe.render_rtnerf(params, CFG, cubes, cam, chunk=8,
                                        field_mode="hybrid")
    psnr = float(rendering.psnr(jnp.clip(img_h, 0, 1),
                                jnp.clip(img_d, 0, 1)))
    assert psnr >= 40.0, psnr
    assert float(st_h["factor_bytes"]) * 3 <= float(st_d["factor_bytes"])
    assert float(st_h["factor_bytes_dense"]) == float(st_d["factor_bytes"])


def test_render_accepts_prebuilt_compressed_field():
    params = _pruned_field(0.9)
    occ = occ_lib.build_occupancy(params, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    cam = rays_lib.make_cameras(3, 24, 24)[0]
    cf = sparse.compress_field(params, CFG)
    img_cf, _ = rt_pipe.render_rtnerf(cf, CFG, cubes, cam, chunk=8,
                                      field_mode="hybrid")
    img_p, _ = rt_pipe.render_rtnerf(params, CFG, cubes, cam, chunk=8,
                                     field_mode="hybrid")
    np.testing.assert_allclose(np.asarray(img_cf), np.asarray(img_p),
                               rtol=1e-6, atol=1e-6)
    # dense mode decompresses a CompressedField rather than failing
    img_dd, _ = rt_pipe.render_rtnerf(cf, CFG, cubes, cam, chunk=8,
                                      field_mode="dense")
    assert np.isfinite(np.asarray(img_dd)).all()


def test_eval_view_rejects_hybrid_on_uniform_pipeline():
    from repro.core import train as nerf_train
    from repro.data import rays as rays_lib

    params = _pruned_field(0.9)
    occ = occ_lib.build_occupancy(params, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    gt = jnp.zeros((16 * 16, 3))
    with pytest.raises(ValueError, match="uniform"):
        nerf_train.eval_view(params, CFG, cubes, cam, gt,
                             pipeline="uniform", field_mode="hybrid")
    # a CompressedField on the uniform pipeline decompresses, not crashes
    cf = sparse.compress_field(params, CFG)
    p, stats, img = nerf_train.eval_view(cf, CFG, cubes, cam, gt,
                                         pipeline="uniform")
    assert np.isfinite(np.asarray(img)).all()


def test_render_rejects_unknown_field_mode():
    params = _pruned_field(0.9)
    occ = occ_lib.build_occupancy(params, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    with pytest.raises(ValueError):
        rt_pipe.render_rtnerf(params, CFG, cubes, cam, field_mode="sparse")


def test_gather_factor_all_formats_agree():
    """The renderer-facing gather must agree across dense/bitmap/coo
    representations of the same factor."""
    rng = np.random.RandomState(0)
    w = rng.randn(6, 24 * 24).astype(np.float32)
    w[rng.rand(*w.shape) < 0.85] = 0
    cols = jnp.asarray(rng.randint(0, w.shape[1], 100), jnp.int32)
    want = w[:, np.asarray(cols)]
    for fmt in ("dense", "bitmap", "coo"):
        ef = sparse.EncodedFactor(
            fmt=fmt, nd_shape=(6, 24, 24), shape=w.shape,
            nnz=int((w != 0).sum()), sparsity=sparse.sparsity(w))
        if fmt == "dense":
            ef.dense = jnp.asarray(w)
        elif fmt == "bitmap":
            ef.bitmap = sparse.encode_bitmap(w)
        else:
            ef.coo = sparse.encode_coo(w)
        got = np.asarray(tensorf.gather_factor(ef, cols))
        np.testing.assert_array_equal(got, want, err_msg=fmt)
