"""Compressed-field (hybrid bitmap/COO) rendering path: codec boundary,
dense/hybrid eval parity, and end-to-end render parity (paper Sec. 4.2.2),
all through the unified FieldBackend API (core/field.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, sparse, tensorf
from repro.data import rays as rays_lib

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _pruned_field(target=0.9, seed=0) -> field_lib.DenseField:
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    return field_lib.DenseField(params, CFG).prune(sparsity=target)


def test_prune_to_sparsity_hits_target():
    """prune(sparsity=t) targets t over each full factor tensor; per-mode
    slices may sit slightly below while the tensor-level fraction holds."""
    f = _pruned_field(0.9)
    sp = tensorf.factor_sparsity(f.params)
    for k, s in sp.items():
        assert s >= 0.89, (k, s)
    for k, v in f.sparsity_report().items():
        assert v["sparsity"] >= 0.85, (k, v)


def test_encode_decode_roundtrip_exact():
    f = _pruned_field(0.9)
    cf = f.encode()
    rec = cf.decode().params
    for k in sparse.FACTOR_KEYS:
        np.testing.assert_array_equal(np.asarray(rec[k]),
                                      np.asarray(f.params[k]))
    # extras pass through untouched
    assert "basis" in cf.extras and "mlp_w1" in cf.extras


def test_encode_dense_factors_stay_dense():
    """Don't pessimize: an unpruned (fully dense) field must not be encoded
    into a format larger than its raw bytes."""
    params = tensorf.init_field(CFG, jax.random.PRNGKey(1))
    cf = field_lib.DenseField(params, CFG).encode()
    for v in cf.sparsity_report().values():
        assert v["format"] == "dense"
        assert v["bytes"] <= v["dense_bytes"]
    assert cf.factor_bytes() == cf.dense_factor_bytes()


def test_encode_bytes_ratio_at_90pct():
    cf = _pruned_field(0.9).encode()
    assert cf.compression_ratio() >= 3.0
    for v in cf.sparsity_report().values():
        assert v["format"] == "coo"          # 0.9 >= 0.8 threshold
        assert v["bytes"] < v["dense_bytes"]


def test_encode_respects_threshold():
    """Between the storage break-even and the 0.80 switch, factors encode
    as bitmap; at/above the switch, COO."""
    f = _pruned_field(0.6)
    fmts = {v["format"] for v in f.encode(threshold=0.80)
            .sparsity_report().values()}
    assert "coo" not in fmts                # 0.6 sparsity < threshold
    fmts2 = {v["format"] for v in f.encode(threshold=0.55)
             .sparsity_report().values()}
    assert "coo" in fmts2


@pytest.mark.parametrize("target", [0.6, 0.9])
def test_sigma_hybrid_matches_dense(target):
    f = _pruned_field(target)
    cf = f.encode()
    pts = jax.random.uniform(jax.random.PRNGKey(2), (513, 3),
                             minval=-1.4, maxval=1.4)
    np.testing.assert_allclose(np.asarray(cf.sigma(pts)),
                               np.asarray(f.sigma(pts)),
                               rtol=1e-6, atol=1e-6)


def test_app_features_hybrid_matches_dense():
    f = _pruned_field(0.9)
    cf = f.encode()
    pts = jax.random.uniform(jax.random.PRNGKey(3), (257, 3),
                             minval=-1.4, maxval=1.4)
    np.testing.assert_allclose(np.asarray(cf.app_features(pts)),
                               np.asarray(f.app_features(pts)),
                               rtol=1e-5, atol=1e-6)


def test_hybrid_render_psnr_vs_dense():
    """End-to-end: the RT-NeRF pipeline rendered from the compressed stream
    must match the dense-factor render (>= 40 dB on a pruned toy field)."""
    f = _pruned_field(0.9)
    occ = occ_lib.build_occupancy(f, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.count > 0
    cam = rays_lib.make_cameras(3, 32, 32)[0]
    img_d, st_d = rt_pipe.render_rtnerf(f, CFG, cubes, cam, chunk=8)
    img_h, st_h = rt_pipe.render_rtnerf(f.encode(), CFG, cubes, cam, chunk=8)
    psnr = float(rendering.psnr(jnp.clip(img_h, 0, 1),
                                jnp.clip(img_d, 0, 1)))
    assert psnr >= 40.0, psnr
    assert float(st_h["factor_bytes"]) * 3 <= float(st_d["factor_bytes"])
    assert float(st_h["factor_bytes_dense"]) == float(st_d["factor_bytes"])


def test_render_accepts_dict_and_backend():
    """as_backend: render_rtnerf takes raw params dicts and backends alike,
    and the encoded/dense results agree."""
    f = _pruned_field(0.9)
    occ = occ_lib.build_occupancy(f, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    cam = rays_lib.make_cameras(3, 24, 24)[0]
    img_dict, _ = rt_pipe.render_rtnerf(f.params, CFG, cubes, cam, chunk=8)
    img_back, _ = rt_pipe.render_rtnerf(f, CFG, cubes, cam, chunk=8)
    np.testing.assert_allclose(np.asarray(img_dict), np.asarray(img_back),
                               rtol=1e-6, atol=1e-6)


def test_uniform_pipeline_samples_encoded_field():
    """The uniform baseline renders straight from the encoded streams too —
    no decompressed copy, same image as the dense field."""
    from repro.core import train as nerf_train

    f = _pruned_field(0.9)
    occ = occ_lib.build_occupancy(f, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    gt = jnp.zeros((16 * 16, 3))
    p_d, _, img_d = nerf_train.eval_view(f, CFG, cubes, cam, gt,
                                         pipeline="uniform")
    p_h, _, img_h = nerf_train.eval_view(f.encode(), CFG, cubes, cam, gt,
                                         pipeline="uniform")
    np.testing.assert_allclose(np.asarray(img_h), np.asarray(img_d),
                               rtol=1e-5, atol=1e-5)


def test_as_backend_rejects_non_fields():
    with pytest.raises(TypeError, match="field_mode"):
        field_lib.as_backend("hybrid")
    with pytest.raises(ValueError, match="NeRFConfig"):
        field_lib.as_backend({"sigma_planes": jnp.zeros((3, 4, 8, 8))})


def test_gather_factor_all_formats_agree():
    """The renderer-facing gather must agree across dense/bitmap/coo
    representations of the same factor."""
    rng = np.random.RandomState(0)
    w = rng.randn(6, 24 * 24).astype(np.float32)
    w[rng.rand(*w.shape) < 0.85] = 0
    cols = jnp.asarray(rng.randint(0, w.shape[1], 100), jnp.int32)
    want = w[:, np.asarray(cols)]
    for fmt in ("dense", "bitmap", "coo"):
        ef = sparse.EncodedFactor(
            fmt=fmt, nd_shape=(6, 24, 24), shape=w.shape,
            nnz=int((w != 0).sum()), sparsity=sparse.sparsity(w))
        if fmt == "dense":
            ef.dense = jnp.asarray(w)
        elif fmt == "bitmap":
            ef.bitmap = sparse.encode_bitmap(w)
        else:
            ef.coo = sparse.encode_coo(w)
        got = np.asarray(tensorf.gather_factor(ef, cols))
        np.testing.assert_array_equal(got, want, err_msg=fmt)
