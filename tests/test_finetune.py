"""Online fine-tuning service: background trainer -> live swap_field loop,
support revival at re-encode boundaries, and the engine's async background
flush thread (clean shutdown, producers never render inline)."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import sparse, tensorf
from repro.core import train as nerf_train
from repro.data import rays as rays_lib
from repro.serving import FineTuneLoop, RenderEngine

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _field_and_cubes(target=0.9, seed=0):
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    field = field_lib.DenseField(params, CFG).prune(sparsity=target)
    occ = occ_lib.build_occupancy(field, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.count > 0
    return field, cubes


# -- support revival -------------------------------------------------------


def test_revive_seeds_top_grad_zeros_within_support():
    """revive() re-admits exactly the top-|grad| zero entries, at magnitude
    eps against the gradient sign, and never touches live entries or the
    MLP/basis extras."""
    field, _ = _field_and_cubes()
    grads = {k: np.zeros_like(np.asarray(v))
             for k, v in field.params.items()}
    w = np.asarray(field.params["sigma_planes"])
    zeros = np.argwhere(w == 0)
    hot = tuple(zeros[0])                         # one zero gets a big grad
    grads["sigma_planes"][hot] = 7.0
    out = field.revive(grads, frac=1.0 / w.size, eps=2e-3)
    got = np.asarray(out.params["sigma_planes"])
    assert got[hot] == pytest.approx(-2e-3)       # step against the grad
    # only grad-carrying zeros revive; everything else is bit-identical
    changed = np.argwhere(got != w)
    assert [tuple(c) for c in changed] == [hot]
    for k in field.params:
        if k not in sparse.FACTOR_KEYS:
            np.testing.assert_array_equal(np.asarray(out.params[k]),
                                          np.asarray(field.params[k]))
    # the revived entry survives a tol-prune + encode: it is IN the support
    kept = out.prune(tol=1e-3).encode().decode()
    assert np.asarray(kept.params["sigma_planes"])[hot] != 0.0


def test_revive_zero_frac_is_identity():
    field, _ = _field_and_cubes()
    grads = {k: np.ones_like(np.asarray(v)) for k, v in field.params.items()}
    assert field.revive(grads, frac=0.0, eps=1e-3) is field


def test_trainer_revives_zeroed_entries_across_boundary():
    """Acceptance (support revival): an entry pruned to zero before an
    encode regrows after the next occ_every rebuild boundary — the support
    is no longer frozen between rebuilds. The trainer starts from an
    ENCODED pruned field, so the zeroed entries are genuinely out of the
    trainable support (dense training would regrow them trivially)."""
    start, _ = _field_and_cubes(target=0.9)
    trainer = nerf_train.NerfTrainer(CFG, "lego", field=start.encode(),
                                     n_views=2, image_hw=16,
                                     occ_every=4, revive_frac=0.2)
    for _ in range(4):
        trainer.step()
    before = trainer.snapshot().decode()
    zero_before = {k: np.asarray(before.params[k]) == 0
                   for k in sparse.FACTOR_KEYS}
    assert any(m.any() for m in zero_before.values())  # something to revive
    trainer.step()                                # crosses the boundary
    after = trainer.snapshot().decode()
    regrown = sum(int((zero_before[k]
                       & (np.asarray(after.params[k]) != 0)).sum())
                  for k in sparse.FACTOR_KEYS)
    assert regrown > 0, "no pruned entry regrew across the rebuild boundary"


def test_trainer_snapshot_matches_train_nerf():
    """NerfTrainer driven manually == train_nerf (same cfg/seed/steps):
    the refactor kept the training loop bit-compatible."""
    res = nerf_train.train_nerf(CFG, "lego", steps=6, n_views=2,
                                image_hw=16, verbose=False)
    trainer = nerf_train.NerfTrainer(CFG, "lego", n_views=2, image_hw=16)
    for _ in range(6):
        trainer.step()
    final = trainer.final()
    p1 = res.field.decode().params
    p2 = final.field.decode().params
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))


# -- async background flush ------------------------------------------------


def test_auto_flush_resolves_without_caller_flush():
    """With the background flush thread on, futures resolve by waiting
    alone — no caller ever invokes flush()."""
    field, cubes = _field_and_cubes()
    with RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                      max_batch_views=2,
                      auto_flush_interval=0.05) as engine:
        cams = rays_lib.make_cameras(3, 16, 16)
        futs = [engine.submit(c) for c in cams]
        for f in futs:
            r = f.result(timeout=300)
            assert np.isfinite(r.img).all()
        assert engine.stats()["views_served"] == 3
        assert engine.stats()["auto_flush_running"]


def test_auto_flush_shutdown_is_clean():
    """close() joins the (non-daemon) flusher: no thread leaks, queued
    work drained, close is idempotent."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          auto_flush_interval=30.0)   # won't tick on its own
    flusher = engine._flusher
    assert flusher is not None and flusher.is_alive()
    assert not flusher.daemon
    fut = engine.submit(rays_lib.make_cameras(3, 16, 16)[0])
    engine.close()                            # drains the queue
    assert fut.done() and np.isfinite(fut.result().img).all()
    assert not flusher.is_alive()
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("engine-auto-flush") and t.is_alive()]
    assert not leaked, f"leaked flusher threads: {leaked}"
    engine.close()                            # idempotent
    assert engine.stats()["auto_flush_running"] is False


def test_auto_flush_submit_never_renders_inline(monkeypatch):
    """Producers only enqueue: even a queue-full submit returns before any
    render happens (the flusher thread does the rendering)."""
    field, cubes = _field_and_cubes()
    with RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                      max_batch_views=1,
                      auto_flush_interval=60.0) as engine:
        render_thread = []
        real = engine._render

        def spy(*a):
            render_thread.append(threading.current_thread().name)
            return real(*a)

        monkeypatch.setattr(engine, "_render", spy)
        fut = engine.submit(rays_lib.make_cameras(3, 16, 16)[0])
        fut.result(timeout=300)
    assert render_thread and all(n == "engine-auto-flush"
                                 for n in render_thread)


def test_deadline_expiry_behind_live_request():
    """Regression: an expired request queued AFTER a live one must time out
    cleanly (the deadline pass once compared _Request dataclasses by value,
    which choked on the jax arrays inside Camera)."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          max_batch_views=16)
    cams = rays_lib.make_cameras(3, 16, 16)
    live = engine.submit(cams[0])                      # no deadline, first
    stale = engine.submit(cams[1], deadline_s=-1.0)    # expired, second
    engine.flush()
    assert stale.result().timed_out
    assert not live.result().timed_out
    assert np.isfinite(live.result().img).all()
    assert engine.stats()["timeouts"] == 1
    assert engine.stats()["views_served"] == 1


# -- the fine-tune loop ----------------------------------------------------


def test_finetune_psnr_improves_across_swaps_concurrent_submits():
    """Acceptance: concurrent submit threads stream views while the
    fine-tuner publishes >= 2 refreshed fields — every future resolves
    (zero drops/timeouts) and served PSNR improves monotonically across
    swap epochs from first to last."""
    res = nerf_train.train_nerf(CFG, "lego", steps=3, n_views=4,
                                image_hw=24, verbose=False)
    scene = rays_lib.make_scene("lego")
    cams = rays_lib.make_cameras(4, 24, 24)
    gts = [rays_lib.render_gt(scene, c) for c in cams]
    with RenderEngine(CFG, res.field, res.cubes, ray_chunk=24 * 24,
                      max_batch_views=2,
                      auto_flush_interval=0.05) as engine:
        loop = FineTuneLoop(engine, "lego", steps=40, publish_every=10,
                            n_views=4, image_hw=24).start()
        records, errs = [], []

        def producer(tid):
            try:
                i = tid
                while loop.running():
                    r = engine.submit(cams[i % len(cams)],
                                      gts[i % len(cams)]).result(timeout=600)
                    records.append(
                        (r.psnr, engine.stats()["field_swaps"], r.timed_out))
                    i += 1
            except BaseException as e:            # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=producer, args=(t,))
                   for t in range(2)]
        for t in threads:
            t.start()
        loop.join()
        for t in threads:
            t.join()
        stats = engine.stats()
    assert not errs
    assert stats["field_swaps"] >= 2
    assert stats["timeouts"] == 0
    assert not any(to for _, _, to in records)
    assert len(records) == stats["views_served"]  # every future resolved
    by_epoch = {}
    for p, sw, _ in records:
        by_epoch.setdefault(sw, []).append(p)
    epochs = sorted(by_epoch)
    assert len(epochs) >= 2
    first = float(np.mean(by_epoch[epochs[0]]))
    last = float(np.mean(by_epoch[epochs[-1]]))
    assert last > first, (first, last, {e: np.mean(v)
                                        for e, v in by_epoch.items()})


def test_finetune_swap_latency_below_flush_interval():
    """The publication stall a producer could observe (engine-lock hold in
    swap_field, cubes precomputed on the trainer thread) hides inside one
    flush interval."""
    res = nerf_train.train_nerf(CFG, "lego", steps=3, n_views=2,
                                image_hw=16, verbose=False)
    interval = 0.25
    with RenderEngine(CFG, res.field, res.cubes, ray_chunk=16 * 16,
                      auto_flush_interval=interval) as engine:
        loop = FineTuneLoop(engine, "lego", steps=10, publish_every=5,
                            n_views=2, image_hw=16).start()
        loop.join()
        s = engine.stats()
    assert len(loop.swaps) >= 2
    assert s["swap_latency_s_max"] < interval, s["swap_latency_s_max"]
    assert all(sw["swap_s"] < interval for sw in loop.swaps)


def test_finetune_stop_is_prompt_and_clean():
    res = nerf_train.train_nerf(CFG, "lego", steps=3, n_views=2,
                                image_hw=16, verbose=False)
    engine = RenderEngine(CFG, res.field, res.cubes, ray_chunk=16 * 16)
    loop = FineTuneLoop(engine, "lego", steps=10_000, publish_every=50,
                        n_views=2, image_hw=16).start()
    time.sleep(0.2)
    loop.stop()
    loop.join(timeout=300)
    assert not loop.running()
    assert loop.trainer.step_count < 10_000


def test_finetune_loop_propagates_trainer_errors():
    res = nerf_train.train_nerf(CFG, "lego", steps=3, n_views=2,
                                image_hw=16, verbose=False)
    engine = RenderEngine(CFG, res.field, res.cubes, ray_chunk=16 * 16)
    loop = FineTuneLoop(engine, "lego", steps=5, publish_every=2,
                        n_views=2, image_hw=16)
    def boom():
        raise RuntimeError("boom")

    loop.trainer.step = boom
    loop.start()
    with pytest.raises(RuntimeError, match="boom"):
        loop.join()
