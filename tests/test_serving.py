"""Streaming multi-view serving engine: micro-batch packing, request/
response futures, batched-vs-sequential render parity, ordering-cache
reuse, checkpoint-backed field lifecycle, live field hot-swap, and request
deadlines."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, tensorf
from repro.data import rays as rays_lib
from repro.serving import RenderEngine, plan_microbatches, prepare_field

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _field_and_cubes(target=0.9, seed=0):
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    field = field_lib.DenseField(params, CFG).prune(sparsity=target)
    occ = occ_lib.build_occupancy(field, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.count > 0
    return field, cubes


# -- micro-batching --------------------------------------------------------


def test_plan_microbatches_roundtrip():
    rng = np.random.RandomState(0)
    sizes = [100, 257, 64]
    batches = [(rng.randn(n, 3).astype(np.float32),
                rng.randn(n, 3).astype(np.float32)) for n in sizes]
    plan = plan_microbatches(batches, chunk=128)
    assert plan.total == sum(sizes)
    assert plan.rays_o.shape == (plan.n_chunks, 128, 3)
    assert plan.n_chunks * 128 >= plan.total
    # identity "render": scatter returns each view its own rays
    outs = [plan.rays_o[i] for i in range(plan.n_chunks)]
    views = plan.scatter(outs)
    for (ro, _), got in zip(batches, views):
        np.testing.assert_array_equal(got, ro)


def test_plan_microbatches_empty_rejected():
    with pytest.raises(ValueError):
        plan_microbatches([], chunk=64)


# -- ray renderer vs image-space pipeline ----------------------------------


@pytest.mark.parametrize("encoded", [False, True])
def test_ray_renderer_matches_image_pipeline(encoded):
    """The serving ray renderer must match render_rtnerf on a full view
    (same geometry, compositing, ordering; no tile clipping) for dense and
    encoded fields alike."""
    field, cubes = _field_and_cubes()
    if encoded:
        field = field.encode()
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    img_s, _ = rt_pipe.render_rtnerf(field, CFG, cubes, cam, chunk=8)
    render = rt_pipe.make_ray_renderer(CFG, chunk=8)
    perm = rt_pipe.order_cubes(cubes, cam.origin)
    ro, rd = rendering.camera_rays(cam)
    img_r, aux = render(field, cubes.centers[perm], cubes.valid[perm],
                        ro, rd)
    assert int(aux["dropped_pairs"]) == 0
    psnr = float(rendering.psnr(jnp.clip(img_r, 0, 1),
                                jnp.clip(img_s, 0, 1)))
    assert psnr >= 40.0, psnr


def test_ray_renderer_nondivisible_cube_chunk_keeps_all_cubes():
    """A cube count that doesn't divide cube_chunk must be padded, never
    truncated — with truncation, chunk=8 over 10 cubes would drop 2."""
    field, cubes = _field_and_cubes()
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    ro, rd = rendering.camera_rays(cam)
    c10 = cubes.centers[:10]                  # valid cubes sort first
    v10 = cubes.valid[:10]
    assert bool(np.asarray(v10).all())
    img5, _ = rt_pipe.make_ray_renderer(CFG, chunk=5)(field, c10, v10,
                                                      ro, rd)
    img8, _ = rt_pipe.make_ray_renderer(CFG, chunk=8)(field, c10, v10,
                                                      ro, rd)
    psnr = float(rendering.psnr(jnp.clip(img8, 0, 1), jnp.clip(img5, 0, 1)))
    assert psnr >= 40.0, psnr


def test_ray_renderer_budget_overflow_is_counted():
    field, cubes = _field_and_cubes()
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    render = rt_pipe.make_ray_renderer(CFG, chunk=8, pair_budget=8)
    perm = rt_pipe.order_cubes(cubes, cam.origin)
    ro, rd = rendering.camera_rays(cam)
    img, aux = render(field, cubes.centers[perm], cubes.valid[perm], ro, rd)
    assert int(aux["dropped_pairs"]) > 0     # 8 pairs can't cover the view
    assert np.isfinite(np.asarray(img)).all()


# -- engine ----------------------------------------------------------------


def test_engine_batched_matches_sequential():
    """submit/flush over several views == the sequential per-view loop."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          max_batch_views=8)
    assert engine.field.kind == "compressed"   # encoded at construction
    cams = rays_lib.make_cameras(3, 16, 16)
    futs = [engine.submit(cam) for cam in cams]
    assert not any(f.done() for f in futs)
    results = [f.result() for f in futs]     # result() flushes
    assert all(f.done() for f in futs)
    for cam, r in zip(cams, results):
        img_s, _ = rt_pipe.render_rtnerf(field.encode(), CFG, cubes, cam,
                                         chunk=8)
        psnr = float(rendering.psnr(
            jnp.clip(jnp.asarray(r.img), 0, 1), jnp.clip(img_s, 0, 1)))
        assert psnr >= 40.0, (r.view_id, psnr)
    s = engine.stats()
    assert s["views_served"] == 3
    assert s["dropped_pairs"] == 0
    assert s["latency_p95_s"] >= s["latency_p50_s"] >= 0.0
    assert s["fps"] > 0.0
    assert s["compression_ratio"] >= 3.0     # resident field is encoded
    assert s["occ_accesses_per_view"] == cubes.count


def test_engine_encode_false_serves_dense():
    """encode=False is a real dense/compressed toggle: a pre-encoded field
    is decoded, so the dense baseline actually measures the dense path."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field.encode(), cubes, encode=False,
                          ray_chunk=16 * 16)
    assert engine.field.kind == "dense"
    s = engine.stats()
    assert s["field_kind"] == "dense"
    assert s["compression_ratio"] == 1.0


def test_engine_ordering_cache_reused_across_requests():
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          max_batch_views=16)
    # 4 views on a circle: octants repeat -> schedules are reused
    cams = rays_lib.make_cameras(4, 16, 16)
    engine.render_views(cams)
    oc = engine.stats()["ordering_cache"]
    assert oc["hits"] + oc["misses"] == 4
    assert oc["entries"] == oc["misses"] <= 4
    # a second pass over the same cameras is all hits
    engine.render_views(cams)
    oc2 = engine.stats()["ordering_cache"]
    assert oc2["misses"] == oc["misses"]
    assert oc2["hits"] == oc["hits"] + 4
    # occupancy rebuild invalidates
    engine.update_cubes(cubes)
    assert engine.stats()["ordering_cache"]["entries"] == 0


def test_engine_auto_flush_at_max_batch():
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          max_batch_views=2)
    f1 = engine.submit(rays_lib.make_cameras(3, 16, 16)[0])
    assert not f1.done()
    f2 = engine.submit(rays_lib.make_cameras(3, 16, 16)[1])
    assert f1.done() and f2.done()           # queue hit max_batch_views


def test_engine_psnr_against_gt_is_reported():
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    gt = np.zeros((16 * 16, 3), np.float32)
    r = engine.submit(cam, gt).result()
    assert r.psnr is not None and np.isfinite(r.psnr)
    assert r.latency_s > 0.0
    assert r.stats["factor_bytes"] > 0


def test_engine_mixed_resolutions_share_one_step():
    """Views at different resolutions micro-batch into the same chunks."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=256,
                          max_batch_views=8)
    cams = [rays_lib.make_cameras(3, 16, 16)[0],
            rays_lib.make_cameras(3, 24, 24)[1]]
    res = engine.render_views(cams)
    assert res[0].img.shape == (16 * 16, 3)
    assert res[1].img.shape == (24 * 24, 3)
    for r in res:
        assert np.isfinite(r.img).all()
    # padding rays originate outside the scene: no pad may register hits
    # and eat pair-budget slots from real rays
    assert engine.stats()["dropped_pairs"] == 0


# -- request deadlines -----------------------------------------------------


def test_engine_deadline_expired_requests_time_out():
    """A request past its deadline resolves with a timeout result instead
    of being rendered late; live requests in the same flush still render."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          max_batch_views=16)
    cams = rays_lib.make_cameras(3, 16, 16)
    stale = engine.submit(cams[0], deadline_s=-1.0)    # already expired
    live = engine.submit(cams[1], deadline_s=600.0)
    engine.flush()
    r_stale, r_live = stale.result(), live.result()
    assert r_stale.timed_out and r_stale.img is None
    assert r_stale.psnr is None
    assert not r_live.timed_out
    assert np.isfinite(r_live.img).all()
    s = engine.stats()
    assert s["timeouts"] == 1
    assert s["views_served"] == 1            # the timeout never rendered


def test_engine_no_deadline_never_times_out():
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16)
    r = engine.submit(rays_lib.make_cameras(3, 16, 16)[0]).result()
    assert not r.timed_out
    assert engine.stats()["timeouts"] == 0


def test_engine_deadline_fires_during_stalled_flush(stall_render):
    """Deadlines must hold even when the flush thread itself is slow: with
    the render artificially stalled (conftest `stall_render` fault
    injector), a short-deadline request queued behind the stalled flush
    still resolves as a timeout at the next cycle — it is never rendered
    late and never hangs."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          auto_flush_interval=0.05)
    try:
        cams = rays_lib.make_cameras(3, 16, 16)
        engine.submit(cams[0]).result(timeout=120.0)   # warm the jit path
        handle = stall_render(engine, delay_s=0.8)
        slow = engine.submit(cams[1])                  # no deadline
        assert handle.entered.wait(30.0)               # flush is stalling
        stale = engine.submit(cams[2], deadline_s=0.05)
        r_stale = stale.result(timeout=60.0)
        r_slow = slow.result(timeout=60.0)
        assert r_stale.timed_out and r_stale.img is None
        assert not r_slow.timed_out
        assert np.isfinite(r_slow.img).all()
        assert engine.stats()["timeouts"] == 1
    finally:
        engine.close()


# -- live field hot-swap ---------------------------------------------------


def test_engine_swap_field_changes_served_field():
    """After swap_field, new requests render from the published field (and
    match a direct render of it); the occupancy cube set is rebuilt."""
    field, cubes = _field_and_cubes(seed=0)
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    img_before = engine.submit(cam).result().img

    field2, cubes2 = _field_and_cubes(seed=7)
    engine.swap_field(field2)                 # cubes rebuilt from field2
    img_after = engine.submit(cam).result().img
    ref, _ = rt_pipe.render_rtnerf(field2.encode(), CFG, engine.cubes, cam,
                                   chunk=8)
    psnr = float(rendering.psnr(jnp.clip(jnp.asarray(img_after), 0, 1),
                                jnp.clip(ref, 0, 1)))
    assert psnr >= 40.0, psnr
    # the two fields are different scenes-worth of params: images differ
    assert float(np.abs(img_after - img_before).mean()) > 1e-4
    s = engine.stats()
    assert s["field_swaps"] == 1
    assert s["ordering_cache"]["entries"] <= 1   # invalidated on swap


def test_engine_swap_field_under_concurrent_submits():
    """Acceptance: swap_field while producer threads submit — every future
    resolves (rendered by old or new field, or after the swap), none are
    dropped, and the engine stays consistent."""
    field, cubes = _field_and_cubes(seed=0)
    field2, _ = _field_and_cubes(seed=7)
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          max_batch_views=3)
    cams = rays_lib.make_cameras(6, 16, 16)
    futs, errs = [], []

    def producer(tid):
        try:
            for i in range(4):
                futs.append(engine.submit(cams[(tid + i) % len(cams)]))
        except BaseException as e:            # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(3)]
    for t in threads:
        t.start()
    engine.swap_field(field2)                 # races with the submits
    for t in threads:
        t.join()
    engine.flush()
    assert not errs
    assert len(futs) == 12
    for f in futs:
        r = f.result()
        assert not r.timed_out
        assert np.isfinite(r.img).all()
    s = engine.stats()
    assert s["views_served"] == 12
    assert s["field_swaps"] == 1


# -- checkpoint-backed field lifecycle -------------------------------------


def test_prepare_field_trains_once_then_restores(tmp_path):
    from repro.ckpt import checkpoint as ckpt_lib

    ckpt = str(tmp_path / "ckpt")
    f1 = prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=3,
                       n_views=2, image_hw=16, verbose=False)
    step = ckpt_lib.latest_step(ckpt)
    assert step == 3                          # trained + checkpointed
    f2 = prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=3,
                       n_views=2, image_hw=16, verbose=False)
    assert f2.kind == f1.kind
    p1, p2 = f1.decode().params, f2.decode().params
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))
    # the restore path really is a restore: the checkpoint step is unchanged
    assert ckpt_lib.latest_step(ckpt) == step


def test_prepare_field_restores_encoded_representation(tmp_path):
    """Compressed-native training checkpoints the ENCODED field; a restore
    hands back the same representation without decompressing."""
    ckpt = str(tmp_path / "ckpt")
    f1 = prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=3,
                       n_views=2, image_hw=16, verbose=False)
    assert f1.kind == "compressed"            # train_nerf default
    f2 = prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=3,
                       n_views=2, image_hw=16, verbose=False)
    assert f2.kind == "compressed"
    assert f2.sparsity_report() == f1.sparsity_report()
    assert f2.factor_bytes() == f1.factor_bytes()


def test_prepare_field_restores_legacy_params_checkpoint(tmp_path):
    """Checkpoints from before the FieldBackend refactor (raw params dict,
    no field_spec) must still restore — as a dense field — instead of
    crashing the serve path."""
    import json

    from repro.ckpt import checkpoint as ckpt_lib

    ckpt = str(tmp_path / "ckpt")
    params = tensorf.init_field(CFG, jax.random.PRNGKey(3))
    ckpt_lib.save_checkpoint(ckpt, 5, params)          # legacy format
    with open(str(tmp_path / "ckpt" / "field_meta.json"), "w") as f:
        json.dump({"scene": "lego", "steps": 5, "seed": 0}, f)
    restored = prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=5,
                             n_views=2, image_hw=16, verbose=False)
    assert restored.kind == "dense"
    for k in params:
        np.testing.assert_array_equal(np.asarray(restored.params[k]),
                                      np.asarray(params[k]))


def test_stream_sharding_multidevice():
    """8 virtual devices: encoded streams replicate, ray chunks shard over
    the data axis (with replication fallback on non-divisible chunks), and
    the engine renders correctly on the mesh."""
    import os
    import subprocess
    import sys
    import textwrap

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.rtnerf import NeRFConfig
    from repro.core import distributed, field as field_lib
    from repro.core import occupancy as occ_lib, tensorf
    from repro.data import rays as rays_lib
    from repro.models.sharding import make_rules
    from repro.serving import RenderEngine

    cfg = NeRFConfig(grid_res=16, occ_res=16, cube_size=4, max_cubes=64,
                     r_sigma=2, r_color=4, app_dim=4, mlp_hidden=8,
                     max_samples_per_ray=32, train_rays=64)
    field = field_lib.DenseField(
        tensorf.init_field(cfg, jax.random.PRNGKey(0)), cfg).prune(
        sparsity=0.9)
    occ = occ_lib.build_occupancy(field, cfg, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, cfg)

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    rules = make_rules(mesh)
    cf = distributed.place_field(field.encode(), rules)
    for leaf in jax.tree.leaves(cf):
        assert leaf.sharding.is_fully_replicated
    ro, rd = distributed.shard_rays(rules, jnp.zeros((256, 3)),
                                    jnp.zeros((256, 3)))
    assert not ro.sharding.is_fully_replicated        # 256 % 8 == 0: sharded
    ro2, _ = distributed.shard_rays(rules, jnp.zeros((100, 3)),
                                    jnp.zeros((100, 3)))
    assert ro2.sharding.is_fully_replicated           # fallback: replicated

    eng = RenderEngine(cfg, cf, cubes, ray_chunk=256, mesh=mesh)
    r = eng.submit(rays_lib.make_cameras(3, 16, 16)[0]).result()
    assert np.isfinite(r.img).all()
    assert eng.stats()["n_devices"] == 8
    print("serving sharding ok")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "serving sharding ok" in r.stdout


def test_prepare_field_rejects_cfg_mismatch(tmp_path):
    """A checkpoint trained under another NeRFConfig must fail loudly on
    restore (shape comparison through the encoded spec), not serve a
    distorted field."""
    ckpt = str(tmp_path / "ckpt")
    prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=2, n_views=2,
                  image_hw=16, verbose=False)
    other = NeRFConfig(grid_res=16, occ_res=16, cube_size=4, max_cubes=64,
                       r_sigma=2, r_color=4, app_dim=4, mlp_hidden=8,
                       max_samples_per_ray=32, train_rays=64)
    with pytest.raises(ValueError, match="different"):
        prepare_field(other, "lego", ckpt_dir=ckpt, train_steps=2,
                      n_views=2, image_hw=16, verbose=False)


def test_prepare_field_rejects_scene_mismatch(tmp_path):
    """One ckpt dir holds one scene; restoring it for another scene must
    fail loudly instead of serving the wrong field."""
    ckpt = str(tmp_path / "ckpt")
    prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=2, n_views=2,
                  image_hw=16, verbose=False)
    with pytest.raises(ValueError, match="scene"):
        prepare_field(CFG, "chair", ckpt_dir=ckpt, train_steps=2,
                      n_views=2, image_hw=16, verbose=False)


def test_engine_flush_failure_requeues(monkeypatch):
    """A render error must not strand queued futures: requests go back on
    the queue and the next flush resolves them."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16)
    fut = engine.submit(rays_lib.make_cameras(3, 16, 16)[0])
    good_render = engine._render
    calls = {"n": 0}

    def flaky(*a):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return good_render(*a)

    monkeypatch.setattr(engine, "_render", flaky)
    with pytest.raises(RuntimeError, match="transient"):
        engine.flush()
    assert not fut.done()
    assert engine.stats()["views_served"] == 0   # nothing resolved, none
    r = fut.result()                             # counted; retry via flush
    assert np.isfinite(r.img).all()
    assert engine.stats()["views_served"] == 1
    assert len(engine._latencies) == 1           # latencies match the count


def test_engine_from_scene_with_ckpt(tmp_path):
    engine = RenderEngine.from_scene(
        CFG, "lego", ckpt_dir=str(tmp_path / "ckpt"), train_steps=3,
        n_views=2, image_hw=16, prune_sparsity=0.9, verbose=False,
        ray_chunk=16 * 16)
    assert engine.field.kind == "compressed"
    r = engine.submit(rays_lib.make_cameras(3, 16, 16)[0]).result()
    assert np.isfinite(r.img).all()
