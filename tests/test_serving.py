"""Streaming multi-view serving engine: micro-batch packing, request/
response futures, batched-vs-sequential render parity, ordering-cache
reuse, and checkpoint-backed field lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, sparse, tensorf
from repro.data import rays as rays_lib
from repro.serving import RenderEngine, plan_microbatches, prepare_field

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _field_and_cubes(target=0.9, seed=0):
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    params = tensorf.prune_to_sparsity(params, target)
    occ = occ_lib.build_occupancy(params, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.count > 0
    return params, cubes


# -- micro-batching --------------------------------------------------------


def test_plan_microbatches_roundtrip():
    rng = np.random.RandomState(0)
    sizes = [100, 257, 64]
    batches = [(rng.randn(n, 3).astype(np.float32),
                rng.randn(n, 3).astype(np.float32)) for n in sizes]
    plan = plan_microbatches(batches, chunk=128)
    assert plan.total == sum(sizes)
    assert plan.rays_o.shape == (plan.n_chunks, 128, 3)
    assert plan.n_chunks * 128 >= plan.total
    # identity "render": scatter returns each view its own rays
    outs = [plan.rays_o[i] for i in range(plan.n_chunks)]
    views = plan.scatter(outs)
    for (ro, _), got in zip(batches, views):
        np.testing.assert_array_equal(got, ro)


def test_plan_microbatches_empty_rejected():
    with pytest.raises(ValueError):
        plan_microbatches([], chunk=64)


# -- ray renderer vs image-space pipeline ----------------------------------


@pytest.mark.parametrize("field_mode", ["dense", "hybrid"])
def test_ray_renderer_matches_image_pipeline(field_mode):
    """The serving ray renderer must match render_rtnerf on a full view
    (same geometry, compositing, ordering; no tile clipping)."""
    params, cubes = _field_and_cubes()
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    img_s, _ = rt_pipe.render_rtnerf(params, CFG, cubes, cam, chunk=8,
                                     field_mode=field_mode)
    render = rt_pipe.make_ray_renderer(params, CFG, field_mode=field_mode,
                                       chunk=8)
    perm = rt_pipe.order_cubes(cubes, cam.origin)
    ro, rd = rendering.camera_rays(cam)
    img_r, aux = render(cubes.centers[perm], cubes.valid[perm], ro, rd)
    assert int(aux["dropped_pairs"]) == 0
    psnr = float(rendering.psnr(jnp.clip(img_r, 0, 1),
                                jnp.clip(img_s, 0, 1)))
    assert psnr >= 40.0, psnr


def test_ray_renderer_nondivisible_cube_chunk_keeps_all_cubes():
    """A cube count that doesn't divide cube_chunk must be padded, never
    truncated — with truncation, chunk=8 over 10 cubes would drop 2."""
    params, cubes = _field_and_cubes()
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    ro, rd = rendering.camera_rays(cam)
    c10 = cubes.centers[:10]                  # valid cubes sort first
    v10 = cubes.valid[:10]
    assert bool(np.asarray(v10).all())
    img5, _ = rt_pipe.make_ray_renderer(params, CFG, chunk=5)(c10, v10,
                                                              ro, rd)
    img8, _ = rt_pipe.make_ray_renderer(params, CFG, chunk=8)(c10, v10,
                                                              ro, rd)
    psnr = float(rendering.psnr(jnp.clip(img8, 0, 1), jnp.clip(img5, 0, 1)))
    assert psnr >= 40.0, psnr


def test_ray_renderer_budget_overflow_is_counted():
    params, cubes = _field_and_cubes()
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    render = rt_pipe.make_ray_renderer(params, CFG, chunk=8, pair_budget=8)
    perm = rt_pipe.order_cubes(cubes, cam.origin)
    ro, rd = rendering.camera_rays(cam)
    img, aux = render(cubes.centers[perm], cubes.valid[perm], ro, rd)
    assert int(aux["dropped_pairs"]) > 0     # 8 pairs can't cover the view
    assert np.isfinite(np.asarray(img)).all()


# -- engine ----------------------------------------------------------------


def test_engine_batched_matches_sequential():
    """submit/flush over several views == the sequential per-view loop."""
    params, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, params, cubes, field_mode="hybrid",
                          ray_chunk=16 * 16, max_batch_views=8)
    cams = rays_lib.make_cameras(3, 16, 16)
    futs = [engine.submit(cam) for cam in cams]
    assert not any(f.done() for f in futs)
    results = [f.result() for f in futs]     # result() flushes
    assert all(f.done() for f in futs)
    for cam, r in zip(cams, results):
        img_s, _ = rt_pipe.render_rtnerf(params, CFG, cubes, cam, chunk=8,
                                         field_mode="hybrid")
        psnr = float(rendering.psnr(
            jnp.clip(jnp.asarray(r.img), 0, 1), jnp.clip(img_s, 0, 1)))
        assert psnr >= 40.0, (r.view_id, psnr)
    s = engine.stats()
    assert s["views_served"] == 3
    assert s["dropped_pairs"] == 0
    assert s["latency_p95_s"] >= s["latency_p50_s"] >= 0.0
    assert s["fps"] > 0.0
    assert s["compression_ratio"] >= 3.0     # resident field is encoded
    assert s["occ_accesses_per_view"] == cubes.count


def test_engine_ordering_cache_reused_across_requests():
    params, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, params, cubes, ray_chunk=16 * 16,
                          max_batch_views=16)
    # 4 views on a circle: octants repeat -> schedules are reused
    cams = rays_lib.make_cameras(4, 16, 16)
    engine.render_views(cams)
    oc = engine.stats()["ordering_cache"]
    assert oc["hits"] + oc["misses"] == 4
    assert oc["entries"] == oc["misses"] <= 4
    # a second pass over the same cameras is all hits
    engine.render_views(cams)
    oc2 = engine.stats()["ordering_cache"]
    assert oc2["misses"] == oc["misses"]
    assert oc2["hits"] == oc["hits"] + 4
    # occupancy rebuild invalidates
    engine.update_cubes(cubes)
    assert engine.stats()["ordering_cache"]["entries"] == 0


def test_engine_auto_flush_at_max_batch():
    params, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, params, cubes, ray_chunk=16 * 16,
                          max_batch_views=2)
    f1 = engine.submit(rays_lib.make_cameras(3, 16, 16)[0])
    assert not f1.done()
    f2 = engine.submit(rays_lib.make_cameras(3, 16, 16)[1])
    assert f1.done() and f2.done()           # queue hit max_batch_views


def test_engine_psnr_against_gt_is_reported():
    params, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, params, cubes, ray_chunk=16 * 16)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    gt = np.zeros((16 * 16, 3), np.float32)
    r = engine.submit(cam, gt).result()
    assert r.psnr is not None and np.isfinite(r.psnr)
    assert r.latency_s > 0.0
    assert r.stats["factor_bytes"] > 0


def test_engine_mixed_resolutions_share_one_step():
    """Views at different resolutions micro-batch into the same chunks."""
    params, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, params, cubes, ray_chunk=256,
                          max_batch_views=8)
    cams = [rays_lib.make_cameras(3, 16, 16)[0],
            rays_lib.make_cameras(3, 24, 24)[1]]
    res = engine.render_views(cams)
    assert res[0].img.shape == (16 * 16, 3)
    assert res[1].img.shape == (24 * 24, 3)
    for r in res:
        assert np.isfinite(r.img).all()
    # padding rays originate outside the scene: no pad may register hits
    # and eat pair-budget slots from real rays
    assert engine.stats()["dropped_pairs"] == 0


# -- checkpoint-backed field lifecycle -------------------------------------


def test_prepare_field_trains_once_then_restores(tmp_path):
    from repro.ckpt import checkpoint as ckpt_lib

    ckpt = str(tmp_path / "ckpt")
    p1 = prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=3,
                       n_views=2, image_hw=16, verbose=False)
    step = ckpt_lib.latest_step(ckpt)
    assert step == 3                          # trained + checkpointed
    p2 = prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=3,
                       n_views=2, image_hw=16, verbose=False)
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))
    # the restore path really is a restore: the checkpoint step is unchanged
    assert ckpt_lib.latest_step(ckpt) == step


def test_stream_sharding_multidevice():
    """8 virtual devices: encoded streams replicate, ray chunks shard over
    the data axis (with replication fallback on non-divisible chunks), and
    the engine renders correctly on the mesh."""
    import os
    import subprocess
    import sys
    import textwrap

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.rtnerf import NeRFConfig
    from repro.core import distributed, occupancy as occ_lib, sparse, tensorf
    from repro.data import rays as rays_lib
    from repro.models.sharding import make_rules
    from repro.serving import RenderEngine

    cfg = NeRFConfig(grid_res=16, occ_res=16, cube_size=4, max_cubes=64,
                     r_sigma=2, r_color=4, app_dim=4, mlp_hidden=8,
                     max_samples_per_ray=32, train_rays=64)
    params = tensorf.prune_to_sparsity(
        tensorf.init_field(cfg, jax.random.PRNGKey(0)), 0.9)
    occ = occ_lib.build_occupancy(params, cfg, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, cfg)

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    rules = make_rules(mesh)
    cf = distributed.place_field(sparse.compress_field(params, cfg), rules)
    for efs in cf.factors.values():
        for ef in efs:
            for arr in (ef.dense, ef.bitmap and ef.bitmap.values,
                        ef.coo and ef.coo.values):
                if arr is not None:
                    assert arr.sharding.is_fully_replicated, ef.fmt
    ro, rd = distributed.shard_rays(rules, jnp.zeros((256, 3)),
                                    jnp.zeros((256, 3)))
    assert not ro.sharding.is_fully_replicated        # 256 % 8 == 0: sharded
    ro2, _ = distributed.shard_rays(rules, jnp.zeros((100, 3)),
                                    jnp.zeros((100, 3)))
    assert ro2.sharding.is_fully_replicated           # fallback: replicated

    eng = RenderEngine(cfg, cf, cubes, ray_chunk=256, mesh=mesh)
    r = eng.submit(rays_lib.make_cameras(3, 16, 16)[0]).result()
    assert np.isfinite(r.img).all()
    assert eng.stats()["n_devices"] == 8
    print("serving sharding ok")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "serving sharding ok" in r.stdout


def test_prepare_field_rejects_cfg_mismatch(tmp_path):
    """A checkpoint trained under another NeRFConfig has the same 11 leaves
    (leaf-count check passes) but different shapes — must fail loudly, not
    serve a distorted field."""
    ckpt = str(tmp_path / "ckpt")
    prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=2, n_views=2,
                  image_hw=16, verbose=False)
    other = NeRFConfig(grid_res=16, occ_res=16, cube_size=4, max_cubes=64,
                       r_sigma=2, r_color=4, app_dim=4, mlp_hidden=8,
                       max_samples_per_ray=32, train_rays=64)
    with pytest.raises(ValueError, match="different"):
        prepare_field(other, "lego", ckpt_dir=ckpt, train_steps=2,
                      n_views=2, image_hw=16, verbose=False)


def test_prepare_field_rejects_scene_mismatch(tmp_path):
    """One ckpt dir holds one scene; restoring it for another scene must
    fail loudly instead of serving the wrong field."""
    ckpt = str(tmp_path / "ckpt")
    prepare_field(CFG, "lego", ckpt_dir=ckpt, train_steps=2, n_views=2,
                  image_hw=16, verbose=False)
    with pytest.raises(ValueError, match="scene"):
        prepare_field(CFG, "chair", ckpt_dir=ckpt, train_steps=2,
                      n_views=2, image_hw=16, verbose=False)


def test_engine_flush_failure_requeues(monkeypatch):
    """A render error must not strand queued futures: requests go back on
    the queue and the next flush resolves them."""
    params, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, params, cubes, ray_chunk=16 * 16)
    fut = engine.submit(rays_lib.make_cameras(3, 16, 16)[0])
    good_render = engine._render
    calls = {"n": 0}

    def flaky(*a):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return good_render(*a)

    monkeypatch.setattr(engine, "_render", flaky)
    with pytest.raises(RuntimeError, match="transient"):
        engine.flush()
    assert not fut.done()
    assert engine.stats()["views_served"] == 0   # nothing resolved, none
    r = fut.result()                             # counted; retry via flush
    assert np.isfinite(r.img).all()
    assert engine.stats()["views_served"] == 1
    assert len(engine._latencies) == 1           # latencies match the count


def test_engine_from_scene_with_ckpt(tmp_path):
    engine = RenderEngine.from_scene(
        CFG, "lego", ckpt_dir=str(tmp_path / "ckpt"), train_steps=3,
        n_views=2, image_hw=16, prune_sparsity=0.9, verbose=False,
        ray_chunk=16 * 16)
    assert isinstance(engine.field, sparse.CompressedField)
    r = engine.submit(rays_lib.make_cameras(3, 16, 16)[0]).result()
    assert np.isfinite(r.img).all()
