"""Logical->mesh resolution rules + multi-device subprocess tests (8 virtual
devices; spawned so the main test process keeps 1 device)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.models.sharding import (AxisRules, DEFAULT_ACT_RULES,
                                   DEFAULT_PARAM_RULES, make_rules,
                                   resolve_spec)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def rules16():
    ar = AxisRules(mesh=FakeMesh({"data": 16, "model": 16}),
                   param_rules=dict(DEFAULT_PARAM_RULES),
                   act_rules=dict(DEFAULT_ACT_RULES))
    return ar


def test_divisibility_drop():
    ar = rules16()
    # 40 heads % 16 != 0 -> dropped (qwen1.5)
    spec = resolve_spec((5120, 40, 128), ("embed", "heads", "head_dim"),
                        ar.param_rules, ar)
    assert spec == P("data", None, None)
    # 48 heads ok
    spec = resolve_spec((6144, 48, 128), ("embed", "heads", "head_dim"),
                        ar.param_rules, ar)
    assert spec == P("data", "model", None)


def test_axis_reuse_conflict():
    ar = rules16()
    # experts takes model; mlp then can't reuse it
    spec = resolve_spec((256, 7168, 2048), ("experts", "embed", "mlp"),
                        ar.param_rules, ar)
    assert spec == P("model", "data", None)
    # grok: 8 experts don't divide -> mlp picks model instead
    spec = resolve_spec((8, 6144, 32768), ("experts", "embed", "mlp"),
                        ar.param_rules, ar)
    assert spec == P(None, "data", "model")


def test_vocab_padding_shards():
    from repro.configs.registry import ARCHS
    ar = rules16()
    for cfg in ARCHS.values():
        assert cfg.vocab_padded % 16 == 0
        spec = resolve_spec((cfg.vocab_padded, cfg.d_model),
                            ("vocab", "embed"), ar.param_rules, ar)
        assert spec == P("model", "data"), cfg.name


def test_heads_shardable_rules():
    from repro.configs.registry import ARCHS
    from repro.models.attention import heads_shardable
    assert heads_shardable(ARCHS["deepseek-v3-671b"])       # 128 H MLA
    assert heads_shardable(ARCHS["granite-34b"])            # MQA via G=48
    assert heads_shardable(ARCHS["seamless-m4t-large-v2"])  # kv=16
    assert not heads_shardable(ARCHS["qwen1.5-32b"])        # 40 heads
    assert not heads_shardable(ARCHS["grok-1-314b"])        # kv=8, G=6
    assert not heads_shardable(ARCHS["llama3.2-1b"])        # kv=8, G=4


SUBPROCESS_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
import numpy as np
"""


def run_sub(body: str):
    import os
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = SUBPROCESS_PRELUDE.format(src=os.path.abspath(src)) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_multidevice_train_step_matches_single():
    """(2 data x 2 model) sharded train loss == single-device loss."""
    out = run_sub("""
    from repro.configs.registry import ARCHS, reduced
    from repro.models import transformer as tf
    from repro.models.common import split_pl
    from repro.models.sharding import make_rules, param_sharding, use_rules
    from repro.launch.steps import batch_sharding
    from repro.configs.base import ShapeConfig
    from repro.data.tokens import TokenStream

    cfg = reduced(ARCHS["llama3.2-1b"])
    shape = ShapeConfig("t", 16, 4, "train")
    params, logical = split_pl(tf.init_model(cfg, jax.random.PRNGKey(0)))
    batch = TokenStream(cfg, shape).batch(0)

    loss1, _ = jax.jit(lambda p, b: tf.model_loss(p, cfg, b))(params, batch)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = make_rules(mesh)
    p_sh = param_sharding(params, logical, rules)
    _, b_sh = batch_sharding(cfg, shape, rules)
    pp = jax.device_put(params, p_sh)
    bb = jax.device_put(batch, b_sh)

    def f(p, b):
        with use_rules(rules):
            return tf.model_loss(p, cfg, b)
    loss2, _ = jax.jit(f, in_shardings=(p_sh, b_sh))(pp, bb)
    print("L1", float(loss1), "L2", float(loss2))
    assert abs(float(loss1) - float(loss2)) < 5e-2, (loss1, loss2)
    """)
    assert "L1" in out


def test_gpipe_matches_reference():
    out = run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.launch.pipeline import gpipe, mlp_stage, reference_apply

    mesh = jax.make_mesh((4, 2), ("stage", "data"))
    L, D, F = 8, 16, 32
    key = jax.random.PRNGKey(0)
    params = {
        "w1": jax.random.normal(key, (L, D, F)) * 0.1,
        "w2": jax.random.normal(jax.random.fold_in(key, 1), (L, F, D)) * 0.1,
    }
    x = jax.random.normal(jax.random.fold_in(key, 2), (6, 4, D))  # 6 micro
    pp = gpipe(mlp_stage, mesh)
    with mesh:
        y = jax.jit(pp)(params, x)
    y_ref = reference_apply(params, x)
    err = float(jnp.abs(y - y_ref).max())
    print("pipeline err", err)
    assert err < 1e-4
    """)
    assert "pipeline err" in out


def test_elastic_remesh_8_to_4_devices():
    out = run_sub("""
    from repro.launch.elastic import make_mesh_from
    devs = jax.devices()
    m8 = make_mesh_from(devs, model_axis=2)
    assert dict(m8.shape) == {"data": 4, "model": 2}
    m4 = make_mesh_from(devs[:4], model_axis=2)
    assert dict(m4.shape) == {"data": 2, "model": 2}
    m3 = make_mesh_from(devs[:3], model_axis=2)   # odd survivor count
    assert dict(m3.shape) == {"data": 3, "model": 1}
    print("remesh ok")
    """)
    assert "remesh ok" in out
