"""Property tests on the RT-NeRF pipeline geometry (Steps 2-1-a..d)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, strategies as st

from repro.configs.rtnerf import NeRFConfig
from repro.core import pipeline as rt_pipe
from repro.core.rendering import look_at_camera, pixel_rays

CFG = NeRFConfig(grid_res=32, occ_res=32, cube_size=4, max_cubes=64,
                 r_sigma=4, r_color=4, app_dim=6, mlp_hidden=8)


def _cam(az=0.7, r=4.0, res=48):
    o = [r * np.cos(az), r * np.sin(az), 1.5]
    return look_at_camera(o, [0, 0, 0], 1.2 * res, res, res)


@given(st.floats(-1.2, 1.2), st.floats(-1.2, 1.2), st.floats(-1.2, 1.2),
       st.floats(0.0, 6.2))
def test_ball_segment_contains_box_segment(cx, cy, cz, az):
    """Step 2-1-d: every box-clipped sample must also lie inside the
    bounding ball (the ball is a superset -> the paper's intersection is
    conservative w.r.t. ours)."""
    cam = _cam(az)
    center = jnp.asarray([cx, cy, cz], jnp.float32)
    tile = 8
    _, _, pts_ball, _, m_ball = rt_pipe._cube_samples(CFG, cam, center, tile,
                                                      "ball")
    _, _, pts_box, _, m_box = rt_pipe._cube_samples(CFG, cam, center, tile,
                                                    "box")
    m_box = np.asarray(m_box)
    if not m_box.any():
        return
    p = np.asarray(pts_box)[m_box]
    d = np.linalg.norm(p - np.asarray(center), axis=-1)
    assert (d <= CFG.cube_ball_radius() + 1e-4).all()
    # and box samples are inside the cube itself
    assert (np.abs(p - np.asarray(center)) <= CFG.cube_world() / 2 + 1e-4).all()


def test_projected_center_pixel_hits_cube():
    """Step 2-1-b/c: the ray through the projected center intersects the
    ball (projection is geometrically consistent)."""
    cam = _cam()
    for center in ([0.0, 0.0, 0.0], [0.8, -0.5, 0.3], [-1.0, 1.0, -0.7]):
        c = jnp.asarray(center, jnp.float32)
        pid, d, pts, ts, mask = rt_pipe._cube_samples(CFG, cam, c, 16, "ball")
        assert bool(np.asarray(mask).any()), f"no samples for cube at {center}"


def test_samples_front_to_back_monotone():
    cam = _cam()
    c = jnp.asarray([0.2, 0.1, 0.0], jnp.float32)
    _, _, _, ts, mask = rt_pipe._cube_samples(CFG, cam, c, 16, "box")
    ts = np.asarray(ts)
    assert (np.diff(ts, axis=-1) > 0).all()      # increasing along the ray


def test_auto_tile_covers_projection():
    cam = _cam(res=96)
    t = rt_pipe.auto_tile(CFG, cam)
    assert t % 8 == 0 and 8 <= t <= 128
    # projected diameter at the nearest possible cube depth fits the tile
    r_pix = cam.focal * CFG.cube_ball_radius() / max(
        CFG.near - CFG.cube_ball_radius(), 0.5)
    assert t >= min(2 * r_pix, 120)


def test_samples_per_segment_bound():
    ns = rt_pipe.samples_per_segment(CFG)
    from repro.core.rendering import step_world
    assert ns >= 2 * CFG.cube_ball_radius() / step_world(CFG)


# --------------------------------------------------------------------------
# Sec. 3.2 — view-dependent ordering + the serving engine's ordering cache
# --------------------------------------------------------------------------


def _cube_set(n=40, seed=0):
    from repro.core.occupancy import CubeSet
    rng = np.random.RandomState(seed)
    centers = np.zeros((64, 3), np.float32)
    centers[:n] = rng.uniform(-1.4, 1.4, (n, 3)).astype(np.float32)
    valid = np.zeros(64, bool)
    valid[:n] = True
    return CubeSet(jnp.asarray(centers), jnp.asarray(valid), n, 0.1,
                   jnp.zeros((8, 8, 8), bool))


@given(st.floats(0.0, 6.2), st.floats(-1.2, 1.2), st.floats(2.5, 6.0))
def test_octant_order_monotone_in_view_distance(az, elev, r):
    """Octant mode: walking the permutation front to back, the *octant-level*
    distance to the view origin never decreases — cubes from nearer octants
    always precede cubes from farther octants (back-to-front reversal is
    monotone non-increasing)."""
    cubes = _cube_set()
    origin = jnp.asarray([r * np.cos(az), r * np.sin(az), elev], jnp.float32)
    perm = np.asarray(rt_pipe.order_cubes(cubes, origin, "octant"))
    c = np.asarray(cubes.centers)[perm]
    valid = np.asarray(cubes.valid)[perm]
    c = c[valid]
    # octant-center distances, same normalisation as order_cubes
    o = np.asarray(origin)
    o_n = o / max(np.abs(o).max(), 1e-6)
    oct_id = (c[:, 0] > 0) * 4 + (c[:, 1] > 0) * 2 + (c[:, 2] > 0)
    signs = np.array([[sx, sy, sz] for sx in (-1, 1) for sy in (-1, 1)
                      for sz in (-1, 1)], np.float32) * 0.5
    d_oct = np.linalg.norm(signs - o_n[None], axis=-1)
    d_along = d_oct[oct_id]
    assert (np.diff(d_along) >= -1e-6).all(), \
        "front-to-back octant distance must be non-decreasing"
    # invalid cubes all sort last (key = inf)
    assert np.asarray(cubes.valid)[perm][: c.shape[0]].all()


def test_octant_order_within_octant_keeps_scan_order():
    """Cubes of one octant keep their fixed scan order (regular DRAM
    pattern, Sec. 3.2) — the permutation is stable within an octant."""
    cubes = _cube_set()
    origin = jnp.asarray([4.0, 1.0, 1.5], jnp.float32)
    perm = np.asarray(rt_pipe.order_cubes(cubes, origin, "octant"))
    c = np.asarray(cubes.centers)
    valid = np.asarray(cubes.valid)
    oct_id = (c[:, 0] > 0) * 4 + (c[:, 1] > 0) * 2 + (c[:, 2] > 0)
    for k in range(8):
        idx = [p for p in perm if valid[p] and oct_id[p] == k]
        assert idx == sorted(idx), f"octant {k} not in scan order"


def test_ordering_cache_hits_by_octant_ranking():
    """Views that rank the 8 octants identically reuse the cached schedule
    bit-exactly; a different ranking (even from the SAME octant) misses."""
    cubes = _cube_set()
    cache = rt_pipe.OrderingCache(cubes)
    p1 = cache.get([4.0, 1.0, 1.5])
    p2 = cache.get([3.9, 0.9, 1.4])          # same octant ranking -> hit
    assert cache.stats() == {"hits": 1, "misses": 1, "nn_hits": 0,
                             "entries": 1}
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    # same octant (+,+,+) but different dominant axis -> different ranking
    # -> MISS (reusing here would composite near cubes after far ones)
    p3 = cache.get([0.1, 0.1, 4.0])
    assert cache.stats()["misses"] == 2
    assert not np.array_equal(np.asarray(p1), np.asarray(p3))
    # every cached entry matches a fresh order_cubes for its origin
    for origin in ([4.0, 1.0, 1.5], [0.1, 0.1, 4.0]):
        want = np.asarray(rt_pipe.order_cubes(
            cubes, jnp.asarray(origin, jnp.float32), "octant"))
        np.testing.assert_array_equal(np.asarray(cache.get(origin)), want)
    # the permuted arrays are cached alongside the permutation
    ctr, vld = cache.get_ordered([4.0, 1.0, 1.5])
    np.testing.assert_array_equal(np.asarray(ctr),
                                  np.asarray(cubes.centers)[np.asarray(p1)])
    np.testing.assert_array_equal(np.asarray(vld),
                                  np.asarray(cubes.valid)[np.asarray(p1)])
    # invalidation drops every entry (occupancy rebuild path)
    misses = cache.stats()["misses"]
    cache.invalidate(cubes)
    assert cache.stats()["entries"] == 0
    cache.get([4.0, 1.0, 1.5])
    assert cache.stats()["misses"] == misses + 1


def test_ordering_key_determines_order_cubes():
    """ordering_key is sound: equal keys -> identical permutations, for
    random origins."""
    cubes = _cube_set()
    rng = np.random.RandomState(3)
    origins = rng.uniform(-5, 5, (24, 3)).astype(np.float32)
    by_key = {}
    for o in origins:
        k = rt_pipe.ordering_key(o, "octant")
        perm = np.asarray(rt_pipe.order_cubes(cubes, jnp.asarray(o),
                                              "octant"))
        if k in by_key:
            np.testing.assert_array_equal(perm, by_key[k], err_msg=str(o))
        else:
            by_key[k] = perm
    assert len(by_key) >= 2                   # keys actually discriminate


def test_ordering_key_distance_mode_keys_full_origin():
    k1 = rt_pipe.ordering_key([4.0, 1.0, 1.5], "distance")
    k2 = rt_pipe.ordering_key([4.0, 1.0, 1.5], "distance")
    k3 = rt_pipe.ordering_key([4.0, 1.0, 1.6], "distance")
    assert k1 == k2 and k1 != k3


def test_ordering_cache_bounded_lru():
    """Distance mode keys on the full origin — the cache must stay bounded
    under a free camera stream and evict least-recently-used entries."""
    cubes = _cube_set()
    cache = rt_pipe.OrderingCache(cubes, mode="distance", max_entries=4)
    for i in range(10):
        cache.get([4.0, 1.0, 1.0 + 0.1 * i])
    assert cache.stats()["entries"] == 4
    assert cache.stats()["misses"] == 10
    # most-recent entries survive; the oldest were evicted
    cache.get([4.0, 1.0, 1.9])
    assert cache.stats()["hits"] == 1
    cache.get([4.0, 1.0, 1.0])
    assert cache.stats()["misses"] == 11
