"""Property tests on the RT-NeRF pipeline geometry (Steps 2-1-a..d)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, strategies as st

from repro.configs.rtnerf import NeRFConfig
from repro.core import pipeline as rt_pipe
from repro.core.rendering import look_at_camera, pixel_rays

CFG = NeRFConfig(grid_res=32, occ_res=32, cube_size=4, max_cubes=64,
                 r_sigma=4, r_color=4, app_dim=6, mlp_hidden=8)


def _cam(az=0.7, r=4.0, res=48):
    o = [r * np.cos(az), r * np.sin(az), 1.5]
    return look_at_camera(o, [0, 0, 0], 1.2 * res, res, res)


@given(st.floats(-1.2, 1.2), st.floats(-1.2, 1.2), st.floats(-1.2, 1.2),
       st.floats(0.0, 6.2))
def test_ball_segment_contains_box_segment(cx, cy, cz, az):
    """Step 2-1-d: every box-clipped sample must also lie inside the
    bounding ball (the ball is a superset -> the paper's intersection is
    conservative w.r.t. ours)."""
    cam = _cam(az)
    center = jnp.asarray([cx, cy, cz], jnp.float32)
    tile = 8
    _, _, pts_ball, _, m_ball = rt_pipe._cube_samples(CFG, cam, center, tile,
                                                      "ball")
    _, _, pts_box, _, m_box = rt_pipe._cube_samples(CFG, cam, center, tile,
                                                    "box")
    m_box = np.asarray(m_box)
    if not m_box.any():
        return
    p = np.asarray(pts_box)[m_box]
    d = np.linalg.norm(p - np.asarray(center), axis=-1)
    assert (d <= CFG.cube_ball_radius() + 1e-4).all()
    # and box samples are inside the cube itself
    assert (np.abs(p - np.asarray(center)) <= CFG.cube_world() / 2 + 1e-4).all()


def test_projected_center_pixel_hits_cube():
    """Step 2-1-b/c: the ray through the projected center intersects the
    ball (projection is geometrically consistent)."""
    cam = _cam()
    for center in ([0.0, 0.0, 0.0], [0.8, -0.5, 0.3], [-1.0, 1.0, -0.7]):
        c = jnp.asarray(center, jnp.float32)
        pid, d, pts, ts, mask = rt_pipe._cube_samples(CFG, cam, c, 16, "ball")
        assert bool(np.asarray(mask).any()), f"no samples for cube at {center}"


def test_samples_front_to_back_monotone():
    cam = _cam()
    c = jnp.asarray([0.2, 0.1, 0.0], jnp.float32)
    _, _, _, ts, mask = rt_pipe._cube_samples(CFG, cam, c, 16, "box")
    ts = np.asarray(ts)
    assert (np.diff(ts, axis=-1) > 0).all()      # increasing along the ray


def test_auto_tile_covers_projection():
    cam = _cam(res=96)
    t = rt_pipe.auto_tile(CFG, cam)
    assert t % 8 == 0 and 8 <= t <= 128
    # projected diameter at the nearest possible cube depth fits the tile
    r_pix = cam.focal * CFG.cube_ball_radius() / max(
        CFG.near - CFG.cube_ball_radius(), 0.5)
    assert t >= min(2 * r_pix, 120)


def test_samples_per_segment_bound():
    ns = rt_pipe.samples_per_segment(CFG)
    from repro.core.rendering import step_world
    assert ns >= 2 * CFG.cube_ball_radius() / step_world(CFG)
