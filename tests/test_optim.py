"""Optimizers, schedules, gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.optim.optimizers import adafactor, adamw, pick_optimizer, sgd


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("make", [lambda: adamw(lr=0.1),
                                  lambda: adafactor(lr=0.3),
                                  lambda: sgd(lr=0.1)])
def test_optimizer_converges_on_quadratic(make):
    opt = make()
    params = {"w": jnp.ones((4, 8)), "b": jnp.zeros((8,))}
    state = opt.init(params)
    loss0 = float(quad_loss(params))

    @jax.jit
    def step(p, s):
        g = jax.grad(quad_loss)(p)
        return opt.update(g, s, p)

    for _ in range(60):
        params, state = step(params, state)
    assert float(quad_loss(params)) < loss0 * 0.05


def test_adamw_state_shapes_match_params():
    opt = adamw()
    params = {"a": jnp.ones((3, 5)), "nested": {"b": jnp.ones((7,))}}
    s = opt.init(params)
    assert s["m"]["a"].shape == (3, 5)
    assert s["v"]["nested"]["b"].shape == (7,)


def test_adafactor_factored_stats():
    opt = adafactor()
    params = {"w": jnp.ones((16, 32)), "b": jnp.ones((16,))}
    s = opt.init(params)
    assert s["v"]["w"]["vr"].shape == (16,)
    assert s["v"]["w"]["vc"].shape == (32,)
    assert s["v"]["b"]["v"].shape == (16,)
    # factored memory << full second moment
    n_stats = 16 + 32
    assert n_stats < 16 * 32


def test_pick_optimizer_size_rule():
    assert pick_optimizer(1_000_000).name == "adamw"
    assert pick_optimizer(100_000_000_000).name == "adafactor"


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_schedules():
    s = optim.cosine_schedule(10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)
    w = optim.linear_warmup(5)
    assert float(w(jnp.int32(2))) == pytest.approx(0.4)


def test_topk_compression_roundtrip_with_error_feedback():
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(1000).astype(np.float32))
    idx, vals, residual = optim.compress_topk(g, frac=0.1)
    dec = optim.decompress_topk(idx, vals, (1000,))
    # decompressed + residual == original
    np.testing.assert_allclose(np.asarray(dec + residual.reshape(-1)),
                               np.asarray(g), atol=1e-6)
    # top-k keeps the largest-magnitude entries
    kept = np.abs(np.asarray(g))[np.asarray(idx)]
    assert kept.min() >= np.sort(np.abs(np.asarray(g)))[-100:].min() - 1e-6


def test_int8_quantization_error_bounded():
    rng = np.random.RandomState(1)
    g = jnp.asarray(rng.randn(256, 4).astype(np.float32))
    q, scale = optim.quantize_int8(g)
    back = optim.dequantize_int8(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.abs(back - g).max()) <= float(scale) * 0.5 + 1e-6
