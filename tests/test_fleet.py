"""Fleet tier: consistent-hash ring properties (fast, in-process) and the
multi-process router/worker integration suite (`@pytest.mark.fleet` —
spawns real worker processes; run with --fleet / REPRO_FLEET=1 or by
invoking this file directly, as the CI fleet-smoke job does).

The integration tests cover the failure contract promised in
docs/architecture.md: affinity stable under registry churn, ~1/K key
movement on membership change, bit-identical frames from replicas, and a
SIGKILLed worker leaving no future unresolved.
"""
import os
import time

import jax
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import tensorf
from repro.data import rays as rays_lib
from repro.serving import FleetError, FleetRouter, HashRing, export_scene

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)

SCENES = ["alpha", "beta", "gamma"]


# -- hash ring (fast, no processes) ----------------------------------------


def _keys(n=400):
    return [f"scene-{i}" for i in range(n)]


def test_ring_deterministic_and_total():
    ring = HashRing(["w0", "w1", "w2"])
    again = HashRing(["w2", "w0", "w1"])      # insertion order is irrelevant
    for k in _keys():
        assert ring.owner(k) == again.owner(k)
        assert ring.owner(k) in ("w0", "w1", "w2")


def test_ring_owners_distinct_and_capped():
    ring = HashRing(["w0", "w1", "w2"])
    for k in _keys(50):
        owners = ring.owners(k, 2)
        assert len(owners) == 2 and len(set(owners)) == 2
        assert ring.owners(k, 10) and len(ring.owners(k, 10)) == 3
        assert owners[0] == ring.owner(k)


def test_ring_version_tracks_membership():
    ring = HashRing()
    assert ring.version == 0
    ring.add("w0")
    ring.add("w0")                            # idempotent: no version bump
    assert ring.version == 1
    ring.add("w1")
    ring.remove("w0")
    ring.remove("w0")
    assert ring.version == 3
    assert ring.nodes == ["w1"]


@given(st.integers(2, 6))
def test_ring_leave_moves_only_dead_workers_keys(k):
    """Removing a worker must not remap any key that worker didn't own."""
    nodes = [f"w{i}" for i in range(k)]
    ring = HashRing(nodes)
    before = {key: ring.owner(key) for key in _keys()}
    dead = nodes[0]
    ring.remove(dead)
    for key, owner in before.items():
        if owner != dead:
            assert ring.owner(key) == owner
        else:
            assert ring.owner(key) != dead


@given(st.integers(1, 6))
def test_ring_join_moves_about_one_over_k(k):
    """A joining worker takes ~1/(K+1) of the keyspace — and every moved
    key moves TO it (the consistent-hashing contract that keeps worker
    churn from invalidating every worker's resident set)."""
    nodes = [f"w{i}" for i in range(k)]
    ring = HashRing(nodes)
    keys = _keys(600)
    before = {key: ring.owner(key) for key in keys}
    ring.add("joiner")
    moved = [key for key in keys if ring.owner(key) != before[key]]
    for key in moved:
        assert ring.owner(key) == "joiner"
    # expectation is 1/(k+1); allow generous slack for vnode variance
    assert len(moved) / len(keys) <= 2.5 / (k + 1)


# -- multi-process integration ---------------------------------------------


def _export_scenes(root):
    paths = {}
    for i, name in enumerate(SCENES):
        params = tensorf.init_field(CFG, jax.random.PRNGKey(i))
        field = field_lib.DenseField(params, CFG).prune(sparsity=0.9)
        occ = occ_lib.build_occupancy(field, CFG, sigma_thresh=0.01)
        cubes = occ_lib.extract_cubes(occ, CFG)
        paths[name] = export_scene(str(root / name), field.encode(), cubes,
                                   scene=name)
    return paths


@pytest.fixture(scope="module")
def scene_paths(tmp_path_factory):
    return _export_scenes(tmp_path_factory.mktemp("fleet_scenes"))


@pytest.fixture(scope="module")
def fleet(scene_paths):
    """Shared 2-worker fleet for the non-destructive tests (spawn + jit
    warm-up is the expensive part; the kill test builds its own)."""
    router = FleetRouter(CFG, scene_paths, n_workers=2)
    yield router
    router.close()


CAM = rays_lib.make_cameras(1, 16, 16)[0]


def _render(router, scene, **kw):
    return router.submit(CAM, scene=scene, **kw).result(timeout=180.0)


@pytest.mark.fleet
def test_affinity_stable_under_churn(fleet):
    """Register/evict/revive churn must not move a scene's owner, and the
    revived scene must serve the identical frame (bit-for-bit spill
    round-trip, now across a process boundary)."""
    scene = SCENES[0]
    owner0 = fleet.owner_of(scene)
    version0 = fleet.ring.version
    r0 = _render(fleet, scene)
    assert not r0.timed_out and r0.img is not None

    fleet.evict(scene)                       # registry churn: spill ...
    assert fleet.owner_of(scene) == owner0
    r1 = _render(fleet, scene)               # ... auto-revive on touch
    np.testing.assert_array_equal(r0.img, r1.img)

    fleet.evict(scene)
    fleet.prefetch(scene)                    # ... async revive
    r2 = _render(fleet, scene)
    np.testing.assert_array_equal(r0.img, r2.img)

    assert fleet.owner_of(scene) == owner0
    assert fleet.ring.version == version0    # churn != membership change
    stats = fleet.stats()
    assert stats["prefetches_total"] == 1
    assert stats["workers_alive"] == 2


@pytest.mark.fleet
def test_replicated_scene_bit_identical_across_replicas(fleet):
    """A hot scene behind one key, resident on both workers: frames must
    be bit-identical regardless of which replica served them."""
    scene = SCENES[1]
    fleet.set_replicas(scene, 2)
    replicas = fleet.replica_workers(scene)
    assert len(replicas) == 2
    imgs = []
    for worker in replicas:
        r = _render(fleet, scene, prefer_worker=worker)
        assert r.worker == worker and not r.timed_out
        imgs.append(r.img)
    np.testing.assert_array_equal(imgs[0], imgs[1])
    snap = fleet.registry.snapshot()["gauges"]
    assert snap[f"fleet_replicas{{scene={scene}}}"]["value"] == 2


@pytest.mark.fleet
def test_slow_worker_deadline_fires(fleet, fleet_faults):
    """Injected pre-flush stall on the owner: a request with a shorter
    deadline must come back as a timed-out result (engine deadline
    semantics hold across the wire), then the worker recovers."""
    scene = SCENES[2]
    owner = fleet.owner_of(scene)
    _render(fleet, scene)                    # warm (register + jit) first
    fleet_faults.stall(fleet, owner, 1.0)
    try:
        r = fleet.submit(CAM, scene=scene, deadline_s=0.05,
                         prefer_worker=owner).result(timeout=60.0)
        assert r.timed_out and r.img is None
    finally:
        fleet_faults.stall(fleet, owner, 0.0)
    r2 = _render(fleet, scene, prefer_worker=owner)
    assert not r2.timed_out and r2.img is not None


@pytest.mark.fleet
def test_router_survives_sigkilled_worker(scene_paths, fleet_faults):
    """SIGKILL a worker with requests in flight: every future resolves
    (replayed result on the survivor, or timed-out for already-expired
    deadlines — never hung), the ring re-hashes, and the fleet keeps
    serving."""
    router = FleetRouter(CFG, scene_paths, n_workers=2)
    try:
        scene = SCENES[0]
        victim = router.owner_of(scene)
        survivor = [w for w in router.alive_workers() if w != victim][0]
        baseline = _render(router, scene, prefer_worker=survivor)
        version0 = router.ring.version

        # Stall the victim so its queue holds real in-flight requests,
        # then kill it mid-stall.
        _render(router, scene, prefer_worker=victim)       # warm victim
        fleet_faults.stall(router, victim, 5.0)
        live = [router.submit(CAM, scene=scene, prefer_worker=victim)
                for _ in range(3)]
        expired = router.submit(CAM, scene=scene, deadline_s=0.01,
                                prefer_worker=victim)
        time.sleep(0.5)                       # let the sends land
        fleet_faults.kill(router, victim)

        results = [f.result(timeout=180.0) for f in live]
        for r in results:
            assert not r.timed_out and r.img is not None
            assert r.replayed and r.worker == survivor
            np.testing.assert_array_equal(r.img, baseline.img)
        rexp = expired.result(timeout=60.0)
        assert rexp.timed_out and rexp.img is None

        assert router.alive_workers() == [survivor]
        assert router.ring.version == version0 + 1
        stats = router.stats()
        assert stats["worker_deaths"] == 1
        assert stats["replays_total"] >= 3
        # dead worker refuses new preferred traffic; affinity re-hashed
        with pytest.raises(FleetError):
            router.submit(CAM, scene=scene, prefer_worker=victim)
        assert router.owner_of(scene) == survivor
        r_after = _render(router, scene)
        assert not r_after.timed_out
        np.testing.assert_array_equal(r_after.img, baseline.img)
    finally:
        router.close()


@pytest.mark.fleet
def test_fleet_metrics_schema(fleet):
    """The fleet_* families promised to scripts/check_metrics_schema.py
    exist on the router registry after traffic."""
    _render(fleet, SCENES[0])
    fleet.poll_stats()                       # refreshes per-worker gauges
    snap = fleet.registry.snapshot()
    counters, gauges = snap["counters"], snap["gauges"]
    for fam in ("fleet_requests_total", "fleet_results_total",
                "fleet_registrations_total"):
        assert any(k.startswith(fam + "{") for k in counters), fam
    for fam in ("fleet_routing_version", "fleet_workers_alive"):
        assert fam in gauges, fam
    for fam in ("fleet_outstanding", "fleet_worker_fps",
                "fleet_worker_queue_depth", "fleet_worker_evictions"):
        assert any(k.startswith(fam + "{") for k in gauges), fam
    assert "fleet_latency_s" in snap["histograms"]
