"""Runtime lock-order assertion (`repro.obs.lockdebug`) — the dynamic
complement to repro-lint's static `lock-order` rule.

Off by default: `make_lock` must hand back plain stdlib locks unless
REPRO_LOCK_DEBUG=1, so the serving hot path pays nothing in production.
"""
import threading

import pytest

from repro.obs import lockdebug
from repro.obs.lockdebug import LockOrderError, make_lock


@pytest.fixture
def lock_debug(monkeypatch):
    monkeypatch.setenv("REPRO_LOCK_DEBUG", "1")
    lockdebug.reset()
    yield
    lockdebug.reset()


def test_disabled_returns_plain_stdlib_locks(monkeypatch):
    monkeypatch.delenv("REPRO_LOCK_DEBUG", raising=False)
    lk = make_lock("a")
    rlk = make_lock("b", kind="rlock")
    assert isinstance(lk, type(threading.Lock()))
    assert isinstance(rlk, type(threading.RLock()))
    assert not lockdebug.enabled()


def test_enabled_returns_tracked_locks(lock_debug):
    lk = make_lock("a")
    assert not isinstance(lk, type(threading.Lock()))
    with lk:
        pass


def test_inversion_raises_before_blocking(lock_debug):
    a, b = make_lock("A"), make_lock("B")
    with a:
        with b:
            pass                       # records the order A -> B
    assert ("A", "B") in lockdebug.edges()
    with b:
        with pytest.raises(LockOrderError, match="inversion"):
            with a:                    # B held, acquiring A: inverted
                pass
    # the raise happened before acquire: A is free, nothing deadlocks
    with a:
        pass


def test_inversion_detected_across_threads(lock_debug):
    a, b = make_lock("A"), make_lock("B")

    def establish():
        with a:
            with b:
                pass

    t = threading.Thread(target=establish)
    t.start()
    t.join()
    with b:
        with pytest.raises(LockOrderError):
            a.acquire()


def test_consistent_order_is_fine(lock_debug):
    a, b = make_lock("A"), make_lock("B")
    for _ in range(3):
        with a:
            with b:
                pass


def test_reentrant_rlock_allowed(lock_debug):
    r = make_lock("R", kind="rlock")
    with r:
        with r:
            pass


def test_reentrant_plain_lock_rejected(lock_debug):
    lk = make_lock("L")
    with lk:
        with pytest.raises(LockOrderError, match="reentrant"):
            lk.acquire()


def test_same_label_shares_ordering(lock_debug):
    # per-metric lock *families* share a label — and its constraints
    a1, a2, b = make_lock("A"), make_lock("A"), make_lock("B")
    with a1:
        with b:
            pass
    with b:
        with pytest.raises(LockOrderError):
            a2.acquire()


def test_condition_wait_keeps_held_stack_honest(lock_debug):
    lk = make_lock("cv", kind="rlock")
    cv = threading.Condition(lk)
    other = make_lock("other")
    with cv:
        cv.wait(timeout=0.01)          # release/reacquire cycle
        with other:                    # records cv -> other, no false edges
            pass
    with other:                        # 'cv' must not still appear held
        pass
    assert ("cv", "other") in lockdebug.edges()
    assert ("other", "cv") not in lockdebug.edges()


def test_engine_lock_order_clean_under_debug(lock_debug):
    """The declared serving order (render -> engine/store -> metrics) as
    exercised by the real labels: no inversion recorded."""
    render = make_lock("engine.render")
    engine = make_lock("engine", kind="rlock")
    store = make_lock("store", kind="rlock")
    metric = make_lock("obs.metric")
    with render:
        with engine:
            with metric:
                pass
        with store:
            with metric:
                pass
    with engine:
        with metric:
            pass
    assert ("engine", "obs.metric") in lockdebug.edges()
