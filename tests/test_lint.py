"""repro-lint suite tests: one bad + one good fixture per rule (rule id
and line asserted on the bad one), the waiver and baseline round-trips,
and the repo-level gate (`scripts/repro_lint.py src/` exits 0).

Tier-1: stdlib + the `repro.analysis` package only — no jax import, no
device work.
"""
import os
import subprocess
import sys
import textwrap

from repro.analysis import base, runner

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(tmp_path, source, *, rules=None, baseline=None, name="mod.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return runner.run([str(p)], root=str(tmp_path), rules=rules,
                      baseline=baseline)


def line_of(source, needle):
    """1-based line of the first line containing `needle`."""
    for i, ln in enumerate(textwrap.dedent(source).splitlines(), 1):
        if needle in ln:
            return i
    raise AssertionError(f"fixture is missing {needle!r}")


# -- lock-discipline -------------------------------------------------------

_LOCK_BAD = """
    import threading

    GUARDED_BY = {"S": {"lock": "_lock", "attrs": ("_q",)}}


    class S:
        def __init__(self):
            self._lock = threading.Lock()
            self._q = []

        def bad(self):
            return len(self._q)  # unguarded
"""

_LOCK_GOOD = """
    import threading

    GUARDED_BY = {"S": {"lock": "_lock", "attrs": ("_q",)}}


    class S:
        def __init__(self):
            self._lock = threading.Lock()
            self._q = []

        def good(self):
            with self._lock:
                return len(self._q)
"""


def test_lock_discipline_bad(tmp_path):
    rep = lint(tmp_path, _LOCK_BAD, rules=["lock-discipline"])
    assert len(rep.gating) == 1
    f = rep.gating[0]
    assert f.rule == "lock-discipline"
    assert f.line == line_of(_LOCK_BAD, "# unguarded")
    assert "_q" in f.message and "bad" in f.message


def test_lock_discipline_good(tmp_path):
    rep = lint(tmp_path, _LOCK_GOOD, rules=["lock-discipline"])
    assert rep.gating == []


def test_lock_discipline_guarded_by_comment(tmp_path):
    # the inline `# guarded-by: _lock` declaration form, no GUARDED_BY map
    src = """
        import threading


        class T:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0  # guarded-by: _lock

            def bad(self):
                self._n += 1  # unguarded
    """
    rep = lint(tmp_path, src, rules=["lock-discipline"])
    assert [f.line for f in rep.gating] == [line_of(src, "# unguarded")]


# -- lock-order ------------------------------------------------------------

_ORDER_BAD = """
    import threading

    GUARDED_BY = {
        "Eng": {"lock": "_lock", "attrs": ("_q",)},
        "Sto": {"lock": "_lock", "attrs": ("_r",)},
    }
    LOCK_ATTR_CLASSES = {"Eng.store": "Sto", "Sto.eng": "Eng"}


    class Eng:
        def order_a(self):
            with self._lock:
                self.store.locked_r()

        def locked_q(self):
            with self._lock:
                self._q = 1


    class Sto:
        def locked_r(self):
            with self._lock:
                self._r = 1

        def inverted(self):
            with self._lock:
                self.eng.locked_q()
"""


def test_lock_order_cycle(tmp_path):
    rep = lint(tmp_path, _ORDER_BAD, rules=["lock-order"])
    assert len(rep.gating) == 1
    f = rep.gating[0]
    assert f.rule == "lock-order"
    assert "Eng._lock" in f.message and "Sto._lock" in f.message


def test_lock_order_acyclic(tmp_path):
    # drop the inverting method -> the remaining order is a DAG
    good = _ORDER_BAD[:_ORDER_BAD.index("def inverted")].rstrip() + "\n"
    rep = lint(tmp_path, good, rules=["lock-order"])
    assert rep.gating == []


# -- jit-purity ------------------------------------------------------------

_JIT_BAD = """
    import jax


    @jax.jit
    def f(x):
        print("tracing", x)  # impure
        return x + 1
"""


def test_jit_purity_bad(tmp_path):
    rep = lint(tmp_path, _JIT_BAD, rules=["jit-purity"])
    assert len(rep.gating) == 1
    f = rep.gating[0]
    assert f.rule == "jit-purity"
    assert f.line == line_of(_JIT_BAD, "# impure")
    assert "print" in f.message


def test_jit_purity_reaches_helpers(tmp_path):
    src = """
        import time

        import jax


        def helper(x):
            t = time.perf_counter()  # impure, reachable from jit
            return x * t


        @jax.jit
        def f(x):
            return helper(x)
    """
    rep = lint(tmp_path, src, rules=["jit-purity"])
    assert [f.line for f in rep.gating] == [line_of(src, "# impure")]


def test_jit_purity_good(tmp_path):
    src = """
        import jax
        import jax.numpy as jnp


        @jax.jit
        def f(x):
            return jnp.sum(x * 2)


        def not_jitted(x):
            print("host-side logging is fine here", x)
            return x
    """
    rep = lint(tmp_path, src, rules=["jit-purity"])
    assert rep.gating == []


# -- recompile-hazard ------------------------------------------------------

_RECOMPILE_BAD = """
    import functools

    import jax


    @functools.partial(jax.jit, static_argnames=("shape",))
    def g(x, shape):
        return x.reshape(shape)


    def caller(x):
        return g(x, shape=[4, 4])  # unhashable static
"""


def test_recompile_unhashable_static(tmp_path):
    rep = lint(tmp_path, _RECOMPILE_BAD, rules=["recompile-hazard"])
    assert len(rep.gating) == 1
    f = rep.gating[0]
    assert f.rule == "recompile-hazard"
    assert f.line == line_of(_RECOMPILE_BAD, "# unhashable static")


def test_recompile_tracer_branch(tmp_path):
    src = """
        import jax


        @jax.jit
        def h(x):
            if x > 0:  # tracer branch
                return x
            return -x
    """
    rep = lint(tmp_path, src, rules=["recompile-hazard"])
    assert [f.line for f in rep.gating] == [line_of(src, "# tracer branch")]


def test_recompile_good(tmp_path):
    src = """
        import functools

        import jax


        @functools.partial(jax.jit, static_argnames=("shape",))
        def g(x, shape):
            return x.reshape(shape)


        @jax.jit
        def h(x):
            if x.shape[0] > 2:  # shape branch is static under jit
                return x
            return -x


        @jax.jit
        def k(x, aux=None):
            if aux is None:  # pytree-structural: static under jit
                return x
            return x + aux


        def caller(x):
            return g(x, shape=(4, 4))
    """
    rep = lint(tmp_path, src, rules=["recompile-hazard"])
    assert rep.gating == []


# -- pytree-completeness ---------------------------------------------------

_PYTREE_BAD = """
    import dataclasses

    import jax


    @dataclasses.dataclass
    class P:  # unregistered
        x: jax.Array
        scale: float
"""


def test_pytree_unregistered_dataclass(tmp_path):
    rep = lint(tmp_path, _PYTREE_BAD, rules=["pytree-completeness"])
    assert len(rep.gating) == 1
    f = rep.gating[0]
    assert f.rule == "pytree-completeness"
    assert f.line == line_of(_PYTREE_BAD, "# unregistered")
    assert "P" in f.message


def test_pytree_registered_good(tmp_path):
    src = """
        import dataclasses

        import jax


        @jax.tree_util.register_pytree_node_class
        @dataclasses.dataclass
        class Q:
            x: jax.Array
            scale: float

            def tree_flatten(self):
                return (self.x,), (self.scale,)

            @classmethod
            def tree_unflatten(cls, aux, children):
                return cls(children[0], aux[0])
    """
    rep = lint(tmp_path, src, rules=["pytree-completeness"])
    assert rep.gating == []


# -- wire-safety -----------------------------------------------------------

_WIRE_BAD = """
    LINT_WIRE_MODULE = True

    import pickle  # banned

    import numpy as np


    def unpack(buf, dt):
        return np.frombuffer(buf, dtype=dt)  # no allowlist
"""


def test_wire_safety_bad(tmp_path):
    rep = lint(tmp_path, _WIRE_BAD, rules=["wire-safety"])
    lines = {f.line for f in rep.gating}
    assert all(f.rule == "wire-safety" for f in rep.gating)
    assert line_of(_WIRE_BAD, "# banned") in lines
    assert line_of(_WIRE_BAD, "# no allowlist") in lines


def test_wire_safety_good(tmp_path):
    src = """
        LINT_WIRE_MODULE = True

        import numpy as np

        WIRE_DTYPES = ("float32", "int32")


        def unpack(buf, dt):
            if dt not in WIRE_DTYPES:
                raise ValueError(dt)
            return np.frombuffer(buf, dtype=np.dtype(dt))
    """
    rep = lint(tmp_path, src, rules=["wire-safety"])
    assert rep.gating == []


def test_wire_safety_ignores_non_wire_modules(tmp_path):
    # pickle use outside fleet/router (e.g. checkpointing) is not wire
    rep = lint(tmp_path, "import pickle\n", rules=["wire-safety"],
               name="ckpt.py")
    assert rep.gating == []


# -- waivers ---------------------------------------------------------------

def test_waiver_suppresses_with_reason(tmp_path):
    src = _LOCK_BAD.replace(
        "# unguarded",
        "# lint: waive(lock-discipline) — read is racy-by-design telemetry")
    rep = lint(tmp_path, src, rules=["lock-discipline"])
    assert rep.gating == []
    assert len(rep.waived) == 1
    assert "racy-by-design" in rep.waived[0].waive_reason
    assert "waived" in rep.format(show_waived=True)


def test_waiver_without_reason_is_ignored(tmp_path):
    src = _LOCK_BAD.replace("# unguarded", "# lint: waive(lock-discipline)")
    rep = lint(tmp_path, src, rules=["lock-discipline"])
    assert len(rep.gating) == 1


def test_waiver_wrong_rule_does_not_suppress(tmp_path):
    src = _LOCK_BAD.replace(
        "# unguarded", "# lint: waive(jit-purity) — wrong rule id")
    rep = lint(tmp_path, src, rules=["lock-discipline"])
    assert len(rep.gating) == 1


# -- baseline --------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    rep = lint(tmp_path, _LOCK_BAD, rules=["lock-discipline"])
    assert len(rep.gating) == 1
    bl = tmp_path / "baseline.json"
    n = base.write_baseline(str(bl), rep.findings)
    assert n == 1
    rep2 = lint(tmp_path, _LOCK_BAD, rules=["lock-discipline"],
                baseline=str(bl))
    assert rep2.gating == []
    assert any(f.baselined for f in rep2.findings)
    # fingerprints are line-free: edits above the finding don't churn it
    rep3 = lint(tmp_path, "X = 1\n" + textwrap.dedent(_LOCK_BAD),
                rules=["lock-discipline"], baseline=str(bl), name="mod2.py")
    # different file -> different fingerprint -> still gating
    assert len(rep3.gating) == 1
    shifted = "# a new comment line\n" + textwrap.dedent(_LOCK_BAD)
    (tmp_path / "mod.py").write_text(shifted)
    rep4 = runner.run([str(tmp_path / "mod.py")], root=str(tmp_path),
                      rules=["lock-discipline"], baseline=str(bl))
    assert rep4.gating == []          # same file/symbol, new line: baselined


# -- repo gate -------------------------------------------------------------

def test_repo_src_is_lint_clean():
    """The CI contract: `python scripts/repro_lint.py src/` exits 0."""
    proc = subprocess.run(
        [sys.executable, os.path.join("scripts", "repro_lint.py"), "src"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "repro-lint: 0 finding(s)" in proc.stdout


def test_cli_flags_fixture_and_exits_nonzero(tmp_path):
    (tmp_path / "bad.py").write_text(textwrap.dedent(_JIT_BAD))
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "repro_lint.py"),
         "bad.py", "--no-baseline"],
        cwd=tmp_path, capture_output=True, text=True)
    assert proc.returncode == 1
    assert "jit-purity" in proc.stdout


def test_list_rules():
    proc = subprocess.run(
        [sys.executable, os.path.join("scripts", "repro_lint.py"),
         "--list-rules"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0
    for rule in base.ALL_RULES:
        assert rule in proc.stdout.split()
