"""Checkpointing: atomicity, async, retention, corruption, elastic restore."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import (CheckpointManager, latest_step, restore_checkpoint,
                        save_checkpoint)


def tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"m": jnp.ones((5,)), "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 3, t)
    like = jax.tree.map(jnp.zeros_like, t)
    got = restore_checkpoint(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_keeps_last_k(tmp_path):
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree(), keep=2)
    steps = [int(d.split("_")[1]) for d in os.listdir(tmp_path)]
    assert sorted(steps) == [4, 5]


def test_corruption_detected(tmp_path):
    save_checkpoint(str(tmp_path), 1, tree())
    leaf = os.path.join(str(tmp_path), "step_00000001", "leaf_00000.npy")
    arr = np.load(leaf)
    arr.reshape(-1)[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(str(tmp_path), 1, tree())


def test_no_partial_checkpoint_visible(tmp_path):
    # a .tmp dir must never count as a restorable step
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 2, tree())
    assert latest_step(str(tmp_path)) == 2


def test_async_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = tree()
    for s in (0, 1, 2):
        mgr.save_async(s, t)
    mgr.wait()
    step, got = mgr.restore_latest(t)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_elastic_runner_failure_recovery(tmp_path):
    """Injected node loss mid-run: remesh + restore + continue to target."""
    from repro.launch.elastic import ElasticRunner
    from repro.optim import sgd

    opt = sgd(lr=0.05)

    def build(mesh):
        params = {"w": jnp.ones((4,)) * 5.0}
        state = opt.init(params)

        def loss(p, b):
            return jnp.sum((p["w"] - b["target"]) ** 2)

        @jax.jit
        def step_fn(st, batch):
            p, s = st
            l, g = jax.value_and_grad(loss)(p, batch)
            p2, s2 = opt.update(g, s, p)
            return (p2, s2), {"loss": l}

        return step_fn, (params, state), None

    runner = ElasticRunner(build=build, ckpt_dir=str(tmp_path), ckpt_every=5)
    batches = lambda s: {"target": jnp.zeros((4,))}
    state, log = runner.run(30, batches, inject_failure_at=17)
    kinds = [l[0] for l in log]
    assert "failure" in kinds and "remesh" in kinds
    steps_done = [l[1] for l in log if l[0] == "step"]
    assert max(steps_done) == 29
    final_loss = [l[2] for l in log if l[0] == "step"][-1]
    assert final_loss < 1.0                       # kept converging after loss
