"""MoE hybrid dispatch: coo (sort/gather) vs bitmap (dense-masked) must be
numerically equivalent when capacity is not binding — the paper's two
encodings decode to the same tensor."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models.common import Maker, split_pl

BASE = ModelConfig(name="test-moe", family="moe", n_layers=1, d_model=32,
                   n_heads=4, n_kv_heads=4, d_ff=64, vocab=128,
                   n_experts=8, top_k=2, d_ff_expert=64,
                   capacity_factor=8.0)       # high cf: no drops


def _params(cfg, seed=0):
    mk = Maker(jax.random.PRNGKey(seed), dtype=jnp.float32)
    p, _ = split_pl(moe_lib.init_moe(mk, cfg))
    return p


def test_dispatch_modes_equivalent():
    cfg = BASE
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_coo, aux1 = moe_lib.moe_forward_coo(p, cfg, x)
    y_bm, aux2 = moe_lib.moe_forward_bitmap(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_coo), np.asarray(y_bm),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_auto_rule_follows_paper_threshold():
    assert BASE.dispatch_sparsity == 0.75
    assert BASE.resolved_dispatch() == "bitmap"          # 75% < 80%
    fine = dataclasses.replace(BASE, n_experts=64, top_k=2)
    assert fine.dispatch_sparsity > 0.96
    assert fine.resolved_dispatch() == "coo"


def test_capacity_drops_tokens_not_crash():
    cfg = dataclasses.replace(BASE, capacity_factor=0.25)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.d_model))
    y, aux = moe_lib.moe_forward_coo(p, cfg, x)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))


def test_decode_path_single_token():
    cfg = BASE
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 1, cfg.d_model))
    y, _ = moe_lib.moe_forward_coo(p, cfg, x)
    assert y.shape == x.shape
    # equivalence against bitmap on the same tokens
    y_bm, _ = moe_lib.moe_forward_bitmap(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_bm),
                               rtol=2e-4, atol=2e-4)


def test_shared_expert_added():
    cfg = dataclasses.replace(BASE, n_shared_experts=1)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model))
    y_with, _ = moe_lib.moe_forward(p, cfg, x)
    p_no = {k: v for k, v in p.items() if not k.startswith("sw")}
    cfg_no = dataclasses.replace(cfg, n_shared_experts=0)
    y_wo, _ = moe_lib.moe_forward(p_no, cfg_no, x)
    assert np.abs(np.asarray(y_with) - np.asarray(y_wo)).max() > 1e-6


def test_router_weights_normalized():
    cfg = BASE
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 8, cfg.d_model))
    vals, idx, aux = moe_lib._router_scores(p, cfg, x)
    s = np.asarray(vals).sum(-1)
    np.testing.assert_allclose(s, 1.0, rtol=1e-5)
    assert float(aux) > 0
