"""Test config: single-device jax (no XLA_FLAGS here by design — the 512-
device forcing belongs ONLY to launch/dryrun.py), small hypothesis profile.

`hypothesis` is optional: when it is not installed (minimal CI images, the
container the kernels are validated in) we register a deterministic stand-in
that runs each @given test on the strategy boundary values plus a few seeded
random draws, so property tests keep running instead of breaking collection.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")
except ModuleNotFoundError:                       # degrade, don't die
    import random
    import types

    class _Strategy:
        def __init__(self, lo, hi, draw):
            self.lo, self.hi, self._draw = lo, hi, draw

        def draw(self, rng):
            return self._draw(rng)

    def _integers(lo, hi):
        return _Strategy(lo, hi, lambda rng: rng.randint(lo, hi))

    def _floats(lo, hi, **_kw):
        return _Strategy(lo, hi, lambda rng: rng.uniform(lo, hi))

    def _given(*strats, **_kw):
        def deco(fn):
            def run():
                rng = random.Random(0)
                cases = [tuple(s.lo for s in strats),
                         tuple(s.hi for s in strats)]
                cases += [tuple(s.draw(rng) for s in strats)
                          for _ in range(6)]
                for case in cases:
                    fn(*case)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco

    class _Settings:
        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

    class _HealthCheck:
        too_slow = data_too_large = None

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _hyp.strategies = _st
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.HealthCheck = _HealthCheck
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


# -- fleet marker ----------------------------------------------------------
# Multi-process fleet tests spawn worker processes that each pay a jit
# warm-up, which would dominate the tier-1 wall clock. They run when asked
# for explicitly: `pytest --fleet`, REPRO_FLEET=1, or a direct
# `pytest tests/test_fleet.py` invocation (the CI fleet-smoke job).

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--fleet", action="store_true", default=False,
        help="run multi-process fleet tests (@pytest.mark.fleet)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "fleet: multi-process fleet-tier test (skipped unless --fleet, "
        "REPRO_FLEET=1, or test_fleet.py is invoked directly)")


def _fleet_enabled(config) -> bool:
    if config.getoption("--fleet") or os.environ.get("REPRO_FLEET") == "1":
        return True
    return any("test_fleet" in str(a) for a in config.invocation_params.args)


def pytest_collection_modifyitems(config, items):
    if _fleet_enabled(config):
        return
    skip = pytest.mark.skip(
        reason="fleet test: needs --fleet / REPRO_FLEET=1")
    for item in items:
        if "fleet" in item.keywords:
            item.add_marker(skip)


# -- fault injection -------------------------------------------------------


class _StallHandle:
    """Handle for an in-engine render stall: `entered` fires when a flush
    has called into the (wrapped) render and is now sleeping."""

    def __init__(self):
        import threading
        self.entered = threading.Event()
        self.delay_s = 0.0
        self.calls = 0


@pytest.fixture
def stall_render():
    """Artificially delay an engine's flush thread: wraps `engine._render`
    so each call signals `handle.entered`, sleeps `handle.delay_s`, then
    renders normally. Models a slow/stalled flush without touching engine
    code — used to assert deadline semantics still fire (test_serving) and
    to build slow workers (fleet tests use the protocol-level `inject` op
    instead, since the engine lives in another process)."""
    import time as _time

    patched = []

    def arm(engine, delay_s):
        handle = _StallHandle()
        handle.delay_s = float(delay_s)
        inner = engine._render

        def stalled(*a, **kw):
            handle.calls += 1
            handle.entered.set()
            _time.sleep(handle.delay_s)
            return inner(*a, **kw)

        engine._render = stalled
        patched.append((engine, inner))
        return handle

    yield arm
    for engine, inner in patched:
        engine._render = inner


@pytest.fixture
def fleet_faults():
    """Fault injectors against a live `FleetRouter`:

      * `kill(router, worker)` — SIGKILL the worker process (hard crash:
        no goodbye on the pipe, the router finds out from EOF).
      * `stall(router, worker, stall_s)` — plant a pre-flush sleep via
        the wire-level `inject` op (slow-worker, still protocol-alive).
    """
    import signal
    import types as types_lib

    def kill(router, worker):
        os.kill(router.worker_pid(worker), signal.SIGKILL)

    def stall(router, worker, stall_s):
        router.inject(worker, stall_s=float(stall_s))

    return types_lib.SimpleNamespace(kill=kill, stall=stall)
