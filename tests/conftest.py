"""Test config: single-device jax (no XLA_FLAGS here by design — the 512-
device forcing belongs ONLY to launch/dryrun.py), small hypothesis profile.

`hypothesis` is optional: when it is not installed (minimal CI images, the
container the kernels are validated in) we register a deterministic stand-in
that runs each @given test on the strategy boundary values plus a few seeded
random draws, so property tests keep running instead of breaking collection.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")
except ModuleNotFoundError:                       # degrade, don't die
    import random
    import types

    class _Strategy:
        def __init__(self, lo, hi, draw):
            self.lo, self.hi, self._draw = lo, hi, draw

        def draw(self, rng):
            return self._draw(rng)

    def _integers(lo, hi):
        return _Strategy(lo, hi, lambda rng: rng.randint(lo, hi))

    def _floats(lo, hi, **_kw):
        return _Strategy(lo, hi, lambda rng: rng.uniform(lo, hi))

    def _given(*strats, **_kw):
        def deco(fn):
            def run():
                rng = random.Random(0)
                cases = [tuple(s.lo for s in strats),
                         tuple(s.hi for s in strats)]
                cases += [tuple(s.draw(rng) for s in strats)
                          for _ in range(6)]
                for case in cases:
                    fn(*case)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco

    class _Settings:
        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

    class _HealthCheck:
        too_slow = data_too_large = None

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _hyp.strategies = _st
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.HealthCheck = _HealthCheck
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
