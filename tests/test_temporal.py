"""Temporal tier: radiance warping (serving/temporal.py), delta planning,
deterministic active-pair compaction, trajectory-mode ordering cache, and
the engine's frame-coherent `submit_delta` path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, tensorf
from repro.core.rendering import look_at_camera
from repro.obs import MetricsRegistry
from repro.serving import RenderEngine
from repro.serving import temporal

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _field_and_cubes(target=0.9, seed=0):
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    field = field_lib.DenseField(params, CFG).prune(sparsity=target)
    occ = occ_lib.build_occupancy(field, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.count > 0
    return field, cubes


def _smooth_frame(h, w, depth0=3.0, seed=0):
    """A synthetic rendered frame: random radiance over a smooth (edge-free)
    depth field — gradients far below the 0.15 relative edge threshold."""
    rng = np.random.RandomState(seed)
    rgb = rng.rand(h * w, 3)
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    depth = (depth0 + 0.01 * (xx + yy)).reshape(-1).astype(np.float64)
    return rgb, depth


# -- warp_radiance ---------------------------------------------------------


def test_warp_identity_reproduces_frame():
    """Warping to the SAME camera is a no-op: every pixel lands back on
    itself, radiance and surface depth survive, confidence is full."""
    cam = look_at_camera([4.0, 0.0, 1.0], [0, 0, 0], 1.2 * 16, 16, 16)
    rgb, depth = _smooth_frame(16, 16)
    wr = temporal.warp_radiance(rgb, cam, cam, depth)
    assert wr.confidence.all()
    assert wr.warp_fraction == 1.0
    np.testing.assert_allclose(wr.rgb, rgb, atol=1e-9)
    np.testing.assert_allclose(wr.depth, depth, rtol=1e-6)
    np.testing.assert_allclose(wr.opacity, 1.0)


def test_warp_translation_flags_disocclusions():
    """A real camera move leaves uncovered target pixels (disocclusion /
    entered the frustum) flagged low-confidence; covered pixels carry
    radiance that exists in the source frame (splat, not resample)."""
    cam0 = look_at_camera([4.0, 0.0, 1.0], [0, 0, 0], 1.2 * 16, 16, 16)
    cam1 = look_at_camera([3.6, 1.2, 1.0], [0, 0, 0], 1.2 * 16, 16, 16)
    rgb, depth = _smooth_frame(16, 16)
    wr = temporal.warp_radiance(rgb, cam0, cam1, depth)
    assert 0.0 < wr.warp_fraction < 1.0
    # every non-white warped pixel is a verbatim copy of SOME source pixel
    warped = wr.rgb[np.any(wr.rgb != 1.0, axis=-1)]
    src_set = {tuple(np.round(p, 12)) for p in rgb}
    assert all(tuple(np.round(p, 12)) in src_set for p in warped)


def test_warp_depth_edges_masked():
    """A depth step (silhouette) poisons confidence around the edge even
    under an identity warp — both sides of a discontinuity may hide a
    disocclusion after any real motion."""
    cam = look_at_camera([4.0, 0.0, 1.0], [0, 0, 0], 1.2 * 16, 16, 16)
    rng = np.random.RandomState(1)
    rgb = rng.rand(256, 3)
    depth = np.full((16, 16), 2.0)
    depth[:, 8:] = 4.0                       # step >> 0.15 relative thresh
    wr = temporal.warp_radiance(rgb, cam, cam, depth.reshape(-1))
    conf = wr.confidence.reshape(16, 16)
    assert not conf[:, 6:10].any()           # edge columns + dilation
    assert conf[:, :5].all() and conf[:, 11:].all()   # far columns clean


def test_warp_background_rides_far_plane():
    """Low-opacity pixels are background: they warp on the far plane and
    keep zero opacity/depth so a chained warp still sees them as empty."""
    cam = look_at_camera([4.0, 0.0, 1.0], [0, 0, 0], 1.2 * 16, 16, 16)
    rgb, depth = _smooth_frame(16, 16)
    op = np.ones(256)
    op[:64] = 0.0                            # first rows: background
    wr = temporal.warp_radiance(rgb, cam, cam, depth * op, opacity=op)
    assert (wr.opacity[:64] == 0.0).all()
    assert (wr.depth[:64] == 0.0).all()
    assert (wr.opacity[64:] > 0.0).all()


def test_warp_offscreen_everything_low_confidence():
    """A camera that looks away from the scene gets no splats: white
    frame, zero warp fraction — submit_delta would fall back to full."""
    cam0 = look_at_camera([4.0, 0.0, 1.0], [0, 0, 0], 1.2 * 16, 16, 16)
    away = look_at_camera([4.0, 0.0, 1.0], [4.0, 0.0, 100.0],
                          1.2 * 16, 16, 16)
    rgb, depth = _smooth_frame(16, 16)
    wr = temporal.warp_radiance(rgb, cam0, away, depth)
    assert wr.warp_fraction == 0.0
    assert np.mean(wr.rgb == 1.0) > 0.95     # a stray splat may land; the
    assert not wr.confidence.any()           # mask still trusts none of it


# -- plan_delta ------------------------------------------------------------


def test_plan_delta_buckets_and_pads():
    conf = np.ones(64, bool)
    conf[[3, 10, 11, 40, 63]] = False
    wr = temporal.WarpResult(rgb=np.ones((64, 3)), depth=np.zeros(64),
                             opacity=np.ones(64), confidence=conf, h=8, w=8)
    plan = temporal.plan_delta(wr, bucket=16)
    assert plan.n_real == 5
    assert plan.n_rays == 16                 # rounded up to one bucket
    np.testing.assert_array_equal(plan.idx[:5], [3, 10, 11, 40, 63])
    assert (plan.idx[5:] == 0).all()         # pad points at pixel 0
    assert plan.warp_fraction == pytest.approx(1.0 - 5 / 64)

    # fully confident still emits one bucket (shape-stable flush)
    wr_all = temporal.WarpResult(rgb=np.ones((64, 3)), depth=np.zeros(64),
                                 opacity=np.ones(64),
                                 confidence=np.ones(64, bool), h=8, w=8)
    assert temporal.plan_delta(wr_all, bucket=16).n_rays == 16
    with pytest.raises(ValueError):
        temporal.plan_delta(wr, bucket=0)


# -- deterministic active-pair compaction ----------------------------------


def test_compact_select_matches_numpy_stable_oracle():
    """The jitted compaction must equal numpy's stable argsort oracle —
    hit pairs first in scan order, losers in scan order — and repeat
    bit-identically across two separate jit invocations (fresh traces)."""
    budget = 7
    rng = np.random.RandomState(3)
    for trial in range(2):                    # two distinct jit objects
        f = jax.jit(lambda h: rt_pipe.compact_select(h, budget))
        hit = rng.rand(40) < 0.3
        got1 = np.asarray(f(jnp.asarray(hit)))
        got2 = np.asarray(f(jnp.asarray(hit)))
        oracle = np.argsort(~hit, kind="stable")[:budget]
        np.testing.assert_array_equal(got1, oracle)
        np.testing.assert_array_equal(got2, got1)


# -- trajectory-mode ordering cache ----------------------------------------


def test_ordering_cache_trajectory_exact_nn_and_miss():
    """Quantised-pose keys: same cell -> exact hit, neighbouring cell
    within nn_radius -> NN hit (same schedule object), far pose -> miss;
    counters land in stats() AND the scene-labelled registry counters."""
    _, cubes = _field_and_cubes()
    reg = MetricsRegistry()
    oc = rt_pipe.OrderingCache(cubes, mode="trajectory", scene="s",
                               pose_quantum=0.25, nn_radius=1.5,
                               registry=reg)
    o0 = np.array([4.0, 0.0, 1.0])
    p0 = oc.get(o0)                                      # miss
    p_same = oc.get(o0 + 0.01)                           # same cell: exact
    p_nn = oc.get(o0 + np.array([0.3, 0.0, 0.0]))        # next cell: NN
    oc.get(np.array([-4.0, -4.0, -4.0]))                 # far: miss
    assert oc.stats() == {"hits": 2, "misses": 2, "nn_hits": 1,
                          "entries": 2}
    np.testing.assert_array_equal(np.asarray(p_same), np.asarray(p0))
    np.testing.assert_array_equal(np.asarray(p_nn), np.asarray(p0))
    assert reg.counter("ordering_cache_hits", scene="s").value == 2
    assert reg.counter("ordering_cache_misses", scene="s").value == 2

    # with_cubes: fresh entries, counters (and registry wiring) carried
    oc2 = oc.with_cubes(cubes)
    assert oc2.stats()["entries"] == 0
    assert (oc2.hits, oc2.misses, oc2.nn_hits) == (2, 2, 1)
    oc2.get(o0)                                          # miss in new cache
    assert oc2.stats()["misses"] == 3
    assert reg.counter("ordering_cache_misses", scene="s").value == 3


def test_ordering_cache_nn_deterministic_tie_break():
    """Two cached keys equidistant from the probe: the (distance, key)
    tie-break picks the lexicographically smaller key regardless of
    insertion order."""
    _, cubes = _field_and_cubes()
    a = rt_pipe.OrderingCache(cubes, mode="trajectory", pose_quantum=1.0)
    b = rt_pipe.OrderingCache(cubes, mode="trajectory", pose_quantum=1.0)
    lo, hi = np.array([3.0, 0.0, 0.0]), np.array([5.0, 0.0, 0.0])
    a.get(lo), a.get(hi)
    b.get(hi), b.get(lo)                     # reversed insertion
    probe = np.array([4.0, 0.0, 0.0])        # equidistant from both keys
    assert a._nearest(a.key_for(probe)) == b._nearest(b.key_for(probe)) \
        == (3, 0, 0)


# -- engine delta path -----------------------------------------------------


def test_engine_submit_delta_end_to_end():
    """The frame-coherent path: keyframes (prev=None) are bit-identical to
    `submit`; a delta frame composites warped + fresh into a full frame
    close to the full render, with telemetry on the shared registry and
    warp/mask/composite visible in the trace-derived breakdown."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=64,
                          delta_ray_bucket=32, order_mode="trajectory",
                          adaptive_pair_budget=False)
    cams = [look_at_camera([4.0 * np.cos(a), 4.0 * np.sin(a), 1.0],
                           [0, 0, 0], 1.2 * 16, 16, 16)
            for a in (0.0, 0.05, 0.10)]

    ref0 = engine.submit(cams[0]).result()
    key0 = engine.submit_delta(cams[0], prev=None).result()   # keyframe
    np.testing.assert_array_equal(np.asarray(key0.img),
                                  np.asarray(ref0.img))
    assert key0.depth is not None and key0.opacity is not None
    assert key0.warp_fraction == 0.0

    d1 = engine.submit_delta(cams[1], prev=key0).result()
    assert 0.0 < d1.warp_fraction < 1.0
    full1 = engine.submit(cams[1]).result()
    psnr = float(rendering.psnr(jnp.clip(jnp.asarray(d1.img), 0, 1),
                                jnp.clip(jnp.asarray(full1.img), 0, 1)))
    assert psnr >= 35.0, psnr

    d2 = engine.submit_delta(cams[2], prev=d1).result()       # chained
    assert np.isfinite(d2.depth).all() and 0.0 < d2.warp_fraction <= 1.0

    s = engine.stats()["delta"]
    assert s["views"] == 2 and s["fresh_rays"] > 0 and s["warped_rays"] > 0
    m = engine.metrics
    assert m.counter("warp_rays_total").value == s["warped_rays"]
    assert m.counter("render_dispatch_total", path="delta").value == 2
    assert m.histogram("warp_fraction").snapshot()["count"] == 2
    stages = engine.stage_breakdown()
    for st in ("warp", "mask", "render", "composite"):
        assert st in stages, st

    # a max_delta_frac no mask can meet forces a clean full render
    fb = engine.submit_delta(cams[0], prev=d2,
                             max_delta_frac=-1.0).result()
    np.testing.assert_array_equal(np.asarray(fb.img), np.asarray(ref0.img))
    assert fb.warp_fraction == 0.0
    assert engine.stats()["delta"]["full_fallbacks"] == 1
    engine.close()
