"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import train as nerf_train
from repro.data import rays as rays_lib


@pytest.fixture(scope="module")
def trained_scene():
    cfg = NeRFConfig(grid_res=32, occ_res=32, cube_size=4, max_cubes=512,
                     r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                     max_samples_per_ray=96, train_rays=512)
    # the occupancy rebuild reads cfg.occ_sigma_thresh (0.5 — the low
    # cutoff thin scenes need); no per-call-site threshold anymore
    res = nerf_train.train_nerf(cfg, "materials", steps=150, n_views=6,
                                image_hw=48, log_every=1000, verbose=False)
    return cfg, res


def test_nerf_training_learns(trained_scene):
    """Photometric loss must fall well below the init level."""
    cfg, res = trained_scene
    scene = rays_lib.make_scene("materials")
    cam = rays_lib.make_cameras(5, 48, 48)[2]
    gt = rays_lib.render_gt(scene, cam)
    p, stats, img = nerf_train.eval_view(res.field, cfg, res.cubes, cam, gt,
                                         pipeline="uniform")
    assert p > 14.0, f"PSNR too low: {p}"       # white bg baseline ~8-10


def test_rtnerf_pipeline_end_to_end(trained_scene):
    """The paper's pipeline renders the trained scene at quality parity with
    orders-of-magnitude fewer occupancy accesses (A1) and skips invisible
    points (A2)."""
    cfg, res = trained_scene
    scene = rays_lib.make_scene("materials")
    cam = rays_lib.make_cameras(5, 48, 48)[2]
    gt = rays_lib.render_gt(scene, cam)
    p_u, s_u, _ = nerf_train.eval_view(res.field, cfg, res.cubes, cam, gt,
                                       pipeline="uniform")
    p_r, s_r, _ = nerf_train.eval_view(res.field, cfg, res.cubes, cam, gt,
                                       pipeline="rtnerf")
    assert p_r > p_u - 1.5
    assert s_r["occ_accesses"] * 50 < s_u["occ_accesses"]
    assert s_r["processed_samples"] < s_r["candidate_samples"]


def test_lm_training_loss_decreases():
    """5 steps of LM training on the synthetic stream reduce loss."""
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import ARCHS, reduced
    from repro.data.tokens import TokenStream
    from repro.models import transformer as tf
    from repro.models.common import split_pl
    from repro.optim import adamw

    cfg = reduced(ARCHS["granite-3-8b"])
    shape = ShapeConfig("t", 32, 8, "train")
    stream = TokenStream(cfg, shape)
    params, _ = split_pl(tf.init_model(cfg, jax.random.PRNGKey(0)))
    opt = adamw(lr=5e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (l, _), g = jax.value_and_grad(
            lambda q: tf.model_loss(q, cfg, b), has_aux=True)(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, l

    # fixed batch -> loss must drop fast if gradients flow end to end
    batch = stream.batch(0)
    losses = []
    for i in range(6):
        params, state, l = step(params, state, batch)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.5, losses


def test_data_stream_deterministic_and_sharded():
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import ARCHS, reduced
    from repro.data.tokens import TokenStream

    cfg = reduced(ARCHS["llama3.2-1b"])
    shape = ShapeConfig("t", 16, 8, "train")
    a = TokenStream(cfg, shape).batch(5)
    b = TokenStream(cfg, shape).batch(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    # different shards -> disjoint streams
    s0 = TokenStream(cfg, shape, n_shards=2, shard=0).batch(5)
    s1 = TokenStream(cfg, shape, n_shards=2, shard=1).batch(5)
    assert not np.array_equal(np.asarray(s0["tokens"]),
                              np.asarray(s1["tokens"]))
    assert s0["tokens"].shape[0] == shape.global_batch // 2


def test_all_cells_enumerated():
    from repro.configs.registry import all_cells
    cells = all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2] is not None]
    assert len(skips) == 8          # long_500k on the 8 full-attention archs
    for cfg, shape, skip in skips:
        assert shape.name == "long_500k"
        assert cfg.family not in ("ssm", "hybrid")
