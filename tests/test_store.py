"""SceneStore + scene-routed serving: multi-scene registry, LRU eviction
to encoded checkpoints with bit-for-bit revival, concurrent cross-scene
request streams, per-scene fine-tune attach, and the engine's adaptive
pair budget."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, tensorf
from repro.core import train as nerf_train
from repro.data import rays as rays_lib
from repro.serving import FineTuneLoop, RenderEngine, SceneStore

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _field_and_cubes(target=0.9, seed=0):
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    field = field_lib.DenseField(params, CFG).prune(sparsity=target)
    occ = occ_lib.build_occupancy(field, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.count > 0
    return field, cubes


def _store(tmp_path, budget=None, **kw):
    return SceneStore(CFG, max_resident_bytes=budget,
                      spill_dir=str(tmp_path / "spill"), **kw)


# -- registry --------------------------------------------------------------


def test_store_register_and_duplicate_rejected(tmp_path):
    store = _store(tmp_path)
    f, c = _field_and_cubes()
    store.register("a", f, c)
    assert "a" in store and store.resident_scenes() == ["a"]
    assert store.resident_bytes() > 0
    with pytest.raises(ValueError, match="already registered"):
        store.register("a", f, c)
    with pytest.raises(KeyError, match="unknown scene"):
        store.snapshot("nope")


def test_store_snapshot_is_consistent_after_publish(tmp_path):
    """A snapshot taken before a publish keeps its (field, cubes, ordering)
    triple; the live record moves on."""
    store = _store(tmp_path)
    f1, c1 = _field_and_cubes(seed=0)
    f2, c2 = _field_and_cubes(seed=7)
    store.register("a", f1, c1)
    snap = store.snapshot("a")
    store.publish("a", f2, c2)
    assert snap.cubes is c1
    assert store.snapshot("a").cubes is not c1
    assert store.stats("a")["swaps"] == 1


# -- eviction / revival ----------------------------------------------------


def test_store_eviction_roundtrip_bit_for_bit(tmp_path):
    """Evict -> revive must rebuild the exact encoded representation:
    same formats, same packed bytes, bit-identical leaf arrays."""
    store = _store(tmp_path)
    f, c = _field_and_cubes()
    store.register("a", f, c)
    before = store.get_field("a")
    spec_b, arrays_b = field_lib.field_state(before)
    report_b = before.sparsity_report()

    store.evict("a")
    assert store.resident_scenes() == []
    assert store.stats("a")["field_kind"] == "evicted"

    after = store.get_field("a")               # transparent revival
    spec_a, arrays_a = field_lib.field_state(after)
    assert spec_a == spec_b
    assert sorted(arrays_a) == sorted(arrays_b)
    for k in arrays_b:
        np.testing.assert_array_equal(np.asarray(arrays_a[k]),
                                      np.asarray(arrays_b[k]))
    assert after.sparsity_report() == report_b
    # cube set reloaded, not rebuilt: identical geometry
    c2 = store.snapshot("a").cubes
    np.testing.assert_array_equal(np.asarray(c2.centers),
                                  np.asarray(c.centers))
    assert c2.count == c.count
    s = store.stats("a")
    assert s["evictions"] == 1 and s["revivals"] == 1


def test_store_budget_lru_evicts_coldest(tmp_path):
    """Registering past the byte budget evicts the least-recently-used
    resident scene, never the incoming one; touching a scene protects it."""
    f1, c1 = _field_and_cubes(seed=0)
    f2, c2 = _field_and_cubes(seed=1)
    f3, c3 = _field_and_cubes(seed=2)
    one = field_lib.as_backend(f1, CFG).encode().factor_bytes()
    store = _store(tmp_path, budget=int(2.5 * one))
    store.register("a", f1, c1)
    store.register("b", f2, c2)
    assert store.resident_scenes() == ["a", "b"]
    store.snapshot("a")                        # a is now warmer than b
    store.register("c", f3, c3)                # over budget -> evict b
    assert "b" not in store.resident_scenes()
    assert set(store.resident_scenes()) == {"a", "c"}
    # next touch revives b (and evicts the now-coldest, a)
    store.snapshot("b")
    assert "b" in store.resident_scenes()
    assert "a" not in store.resident_scenes()


def test_store_single_scene_over_budget_stays_resident(tmp_path):
    """A lone scene larger than the budget must stay resident (an
    unserveable store would be worse than an over-budget one)."""
    f, c = _field_and_cubes()
    store = _store(tmp_path, budget=1)          # absurdly tight
    store.register("a", f, c)
    assert store.resident_scenes() == ["a"]


def test_engine_revived_scene_renders_identically(tmp_path):
    """Acceptance: with max_resident_bytes forcing eviction, a revived
    scene returns PSNR identical to pre-eviction (encoded round-trip) —
    the engine route, not just the store."""
    f1, c1 = _field_and_cubes(seed=0)
    f2, c2 = _field_and_cubes(seed=7)
    one = field_lib.as_backend(f1, CFG).encode().factor_bytes()
    engine = RenderEngine(CFG, f1, c1, scene_name="a", ray_chunk=16 * 16,
                          max_resident_bytes=int(1.5 * one),
                          spill_dir=str(tmp_path / "spill"))
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    img_a = np.asarray(engine.submit(cam, scene="a").result().img)
    engine.register_scene("b", f2, c2)          # evicts a
    assert engine.store.resident_scenes() == ["b"]
    img_b = np.asarray(engine.submit(cam, scene="b").result().img)
    img_a2 = np.asarray(engine.submit(cam, scene="a").result().img)
    np.testing.assert_array_equal(img_a2, img_a)
    img_b2 = np.asarray(engine.submit(cam, scene="b").result().img)
    np.testing.assert_array_equal(img_b2, img_b)
    s = engine.stats()
    assert s["evictions"] >= 2 and s["revivals"] >= 2
    assert s["timeouts"] == 0


# -- scene-routed engine ---------------------------------------------------


def test_engine_two_scene_flush_no_cross_scene_mixups(tmp_path):
    """One flush cycle holding requests for two scenes renders each group
    from its own snapshot — every result matches a direct render of ITS
    scene's field."""
    f1, c1 = _field_and_cubes(seed=0)
    f2, c2 = _field_and_cubes(seed=7)
    engine = RenderEngine(CFG, f1, c1, scene_name="a", ray_chunk=16 * 16,
                          max_batch_views=16,
                          spill_dir=str(tmp_path / "spill"))
    engine.register_scene("b", f2, c2)
    cams = rays_lib.make_cameras(4, 16, 16)
    futs = [(n, cam, engine.submit(cam, scene=n))
            for cam in cams for n in ("a", "b")]
    engine.flush()                              # one cycle, both scenes
    for n, cam, fut in futs:
        r = fut.result()
        assert r.scene == n
        field, cubes = (f1, c1) if n == "a" else (f2, c2)
        ref, _ = rt_pipe.render_rtnerf(field.encode(), CFG, cubes, cam,
                                       chunk=8)
        psnr = float(rendering.psnr(jnp.clip(jnp.asarray(r.img), 0, 1),
                                    jnp.clip(ref, 0, 1)))
        assert psnr >= 40.0, (n, psnr)
    s = engine.stats()
    assert s["views_served"] == 8
    assert s["scenes"]["a"]["views_served"] == 4
    assert s["scenes"]["b"]["views_served"] == 4


def test_engine_concurrent_submits_across_scenes(tmp_path):
    """Producer threads hammer two resident scenes while flush cycles
    interleave: every future resolves with its own scene's image, none
    are dropped, per-scene counters add up."""
    f1, c1 = _field_and_cubes(seed=0)
    f2, c2 = _field_and_cubes(seed=7)
    engine = RenderEngine(CFG, f1, c1, scene_name="a", ray_chunk=16 * 16,
                          max_batch_views=3,
                          spill_dir=str(tmp_path / "spill"))
    engine.register_scene("b", f2, c2)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    ref = {}
    for n in ("a", "b"):
        ref[n] = np.asarray(engine.submit(cam, scene=n).result().img)
    assert float(np.abs(ref["a"] - ref["b"]).mean()) > 1e-5

    futs, errs = [], []

    def producer(tid):
        try:
            for i in range(6):
                n = ("a", "b")[(tid + i) % 2]
                futs.append((n, engine.submit(cam, scene=n)))
        except BaseException as e:            # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    engine.flush()
    assert not errs
    assert len(futs) == 18
    for n, f in futs:
        r = f.result()
        assert not r.timed_out
        assert r.scene == n
        np.testing.assert_array_equal(np.asarray(r.img), ref[n])
    s = engine.stats()
    assert s["views_served"] == 20
    assert (s["scenes"]["a"]["views_served"]
            + s["scenes"]["b"]["views_served"]) == 20


def test_finetune_attach_survives_eviction_of_other_scene(tmp_path):
    """A FineTuneLoop attached to scene 'a' keeps publishing while 'b' is
    evicted and revived under it: publishes land in 'a' only, 'b' revives
    bit-identically, nothing races."""
    res = nerf_train.train_nerf(CFG, "lego", steps=3, n_views=2,
                                image_hw=16, verbose=False)
    f2, c2 = _field_and_cubes(seed=7)
    one = res.field.factor_bytes()
    engine = RenderEngine(CFG, res.field, res.cubes, scene_name="a",
                          ray_chunk=16 * 16, max_batch_views=4,
                          max_resident_bytes=int(2.5 * one),
                          spill_dir=str(tmp_path / "spill"))
    engine.register_scene("b", f2, c2)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    img_b = np.asarray(engine.submit(cam, scene="b").result().img)

    loop = FineTuneLoop.attach(engine.store, "a", data_scene="lego",
                               steps=12, publish_every=4,
                               n_views=2, image_hw=16).start()
    evicted_once = False
    while loop.running():
        engine.store.evict("b")                 # keep forcing b cold
        evicted_once = True
        r = engine.submit(cam, scene="b").result()   # ... and reviving it
        np.testing.assert_array_equal(np.asarray(r.img), img_b)
    loop.join()
    assert evicted_once
    s = engine.stats()
    assert s["scenes"]["a"]["swaps"] >= 2       # publishes landed in a
    assert s["scenes"]["b"]["swaps"] == 0       # never in b
    assert s["timeouts"] == 0
    # b still revives bit-identically after the fine-tune round
    r = engine.submit(cam, scene="b").result()
    np.testing.assert_array_equal(np.asarray(r.img), img_b)


def test_finetune_publish_into_evicted_scene_revives_it(tmp_path):
    """Publishing into a scene that was evicted mid-round revives it
    around the refreshed field (store.publish contract)."""
    res = nerf_train.train_nerf(CFG, "lego", steps=3, n_views=2,
                                image_hw=16, verbose=False)
    store = _store(tmp_path)
    store.register("a", res.field, res.cubes)
    store.evict("a")
    f2, c2 = _field_and_cubes(seed=7)
    store.publish("a", f2, c2)
    assert store.resident_scenes() == ["a"]
    assert store.stats("a")["swaps"] == 1


# -- adaptive pair budget --------------------------------------------------


def test_adaptive_pair_budget_grows_on_drops(tmp_path):
    """A budget too small for the view drops pairs; the engine doubles it
    (recompiling once) until drops stop, and stats() reports the chosen
    budget."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          pair_budget=8, spill_dir=str(tmp_path / "spill"))
    assert engine.stats()["pair_budget"] == 8
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    engine.submit(cam).result()
    s1 = engine.stats()
    assert s1["dropped_pairs"] > 0              # 8 pairs can't cover a view
    assert s1["pair_budget"] > 8                # grew for the next flush
    assert s1["pair_budget_resizes"] >= 1
    grown = 0
    for _ in range(12):                         # keep flushing: budget
        engine.submit(cam).result()             # converges, drops stop
        s = engine.stats()
        if s["dropped_pairs"] == s1["dropped_pairs"] and \
                s["pair_budget"] == grown:
            break
        grown = s["pair_budget"]
    assert engine.stats()["pair_budget"] >= 4 * 8


def test_adaptive_pair_budget_shrinks_with_hysteresis(tmp_path):
    """Sustained low occupancy shrinks the budget — but only after 3
    consecutive low flushes, never below the observed need, and an
    explicit adaptive_pair_budget=False pins it."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          cube_chunk=8, spill_dir=str(tmp_path / "spill"))
    init = engine.stats()["pair_budget"]
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    engine.submit(cam).result()
    engine.submit(cam).result()
    assert engine.stats()["pair_budget"] == init    # hysteresis: < 3 flushes
    need = None
    for _ in range(6):
        engine.submit(cam).result()
        need = engine.stats()
    if need["pair_occupancy_last"] * init < init // 4:
        assert need["pair_budget"] <= init
    assert need["pair_budget"] >= 128
    assert need["dropped_pairs"] == 0               # shrink never drops

    pinned = RenderEngine(CFG, field, cubes, ray_chunk=16 * 16,
                          adaptive_pair_budget=False,
                          spill_dir=str(tmp_path / "spill2"))
    for _ in range(5):
        pinned.submit(cam).result()
    assert pinned.stats()["pair_budget"] == pinned.stats()[
        "pair_budget_initial"]
    assert pinned.stats()["pair_budget_resizes"] == 0


# -- ordering-cache counter continuity -------------------------------------


def test_ordering_counters_survive_update_cubes(tmp_path):
    """`update_cubes` rebuilds the ordering cache via `with_cubes`: entries
    restart empty over the new cube set, but hit/miss/nn_hit counters and
    the scene-labelled registry counters stay cumulative."""
    store = _store(tmp_path, order_mode="trajectory")
    f, c = _field_and_cubes(seed=0)
    store.register("a", f, c)
    oc = store.snapshot("a").ordering
    o0 = np.array([4.0, 0.0, 1.0])
    oc.get(o0)                                           # miss
    oc.get(o0)                                           # exact hit
    oc.get(o0 + np.array([0.3, 0.0, 0.0]))               # NN hit
    assert oc.stats() == {"hits": 2, "misses": 1, "nn_hits": 1,
                          "entries": 1}

    _, c2 = _field_and_cubes(seed=1)
    store.update_cubes("a", c2)
    oc2 = store.snapshot("a").ordering
    assert oc2 is not oc and oc2.cubes is c2
    assert oc2.scene == "a"
    s = oc2.stats()
    assert (s["hits"], s["misses"], s["nn_hits"]) == (2, 1, 1)
    assert s["entries"] == 0                             # schedules dropped
    oc2.get(o0)                                          # miss in new cache
    m = store.metrics
    assert m.counter("ordering_cache_hits", scene="a").value == 2
    assert m.counter("ordering_cache_misses", scene="a").value == 2


def test_ordering_counters_survive_evict_revive(tmp_path):
    """Evicting a scene parks its ordering counters (still visible in
    stats under field_kind=evicted, including nn_hits); revival restores
    them into the fresh cache and the registry keeps counting forward."""
    store = _store(tmp_path, order_mode="trajectory")
    f, c = _field_and_cubes(seed=0)
    store.register("a", f, c)
    oc = store.snapshot("a").ordering
    o0 = np.array([4.0, 0.0, 1.0])
    oc.get(o0)
    oc.get(o0)
    oc.get(o0 + np.array([0.3, 0.0, 0.0]))

    store.evict("a")
    parked = store.stats("a")["ordering_cache"]
    assert parked == {"hits": 2, "misses": 1, "nn_hits": 1, "entries": 0}

    oc2 = store.snapshot("a").ordering                   # transparent revive
    s = oc2.stats()
    assert (s["hits"], s["misses"], s["nn_hits"]) == (2, 1, 1)
    oc2.get(o0)                                          # fresh cache: miss
    oc2.get(o0)                                          # then exact hit
    s = store.stats("a")["ordering_cache"]
    assert (s["hits"], s["misses"], s["nn_hits"]) == (3, 2, 1)
    m = store.metrics
    assert m.counter("ordering_cache_hits", scene="a").value == 3
    assert m.counter("ordering_cache_misses", scene="a").value == 2


# -- stats surface ---------------------------------------------------------


def test_engine_stats_scene_keyed(tmp_path):
    f1, c1 = _field_and_cubes(seed=0)
    f2, c2 = _field_and_cubes(seed=7)
    engine = RenderEngine(CFG, f1, c1, scene_name="a", ray_chunk=16 * 16,
                          spill_dir=str(tmp_path / "spill"))
    engine.register_scene("b", f2, c2)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    engine.submit(cam, scene="b").result()
    agg = engine.stats()
    assert agg["n_scenes"] == 2
    assert set(agg["scenes"]) == {"a", "b"}
    assert agg["field_kind"] == "compressed"    # default scene (a)
    per = engine.stats(scene="b")
    assert per["views_served"] == 1
    assert per["scene"] == "b" and per["resident"]
    assert engine.stats(scene="a")["views_served"] == 0
    with pytest.raises(KeyError):
        engine.stats(scene="zzz")


# -- eviction vs concurrent revival (lock-ordering contract) ---------------


def test_store_concurrent_revival_races_single_unspill(tmp_path,
                                                       monkeypatch):
    """Two threads touch an evicted scene at the same instant: the store
    lock admits exactly one unspill (the second toucher finds the record
    already revived), and both renders are bit-identical to the
    pre-eviction frame — the lock-ordering contract from the PR 5 docs,
    finally under test."""
    import time

    from repro.serving import store as store_mod

    f, c = _field_and_cubes()
    engine = RenderEngine(CFG, f, c, scene_name="s", ray_chunk=16 * 16,
                          spill_dir=str(tmp_path / "spill"))
    cam = rays_lib.make_cameras(1, 16, 16)[0]
    fut = engine.submit(cam, scene="s")
    engine.flush()
    baseline = np.asarray(fut.result().img)
    engine.store.evict("s")

    real = store_mod.ckpt_lib.unspill_field
    unspills = []

    def slow_unspill(path, cfg):
        unspills.append(path)
        time.sleep(0.2)                       # widen the race window
        return real(path, cfg)

    monkeypatch.setattr(store_mod.ckpt_lib, "unspill_field", slow_unspill)

    barrier = threading.Barrier(2)
    out, errs = [None, None], []

    def toucher(i):
        try:
            barrier.wait()                    # line both touches up
            fi = engine.submit(cam, scene="s")
            engine.flush()
            out[i] = np.asarray(fi.result(timeout=120.0).img)
        except BaseException as e:            # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=toucher, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert not errs
    assert len(unspills) == 1                 # exactly one unspill ran
    assert engine.store.stats("s")["revivals"] == 1
    np.testing.assert_array_equal(out[0], baseline)
    np.testing.assert_array_equal(out[1], baseline)


# -- pin / priority (fleet-tier budget hooks) ------------------------------


def test_store_pin_blocks_budget_eviction(tmp_path):
    """A pinned scene is never a budget victim — pressure falls on the
    next candidate — and unpinning re-exposes it to LRU."""
    f1, c1 = _field_and_cubes(seed=0)
    f2, c2 = _field_and_cubes(seed=1)
    f3, c3 = _field_and_cubes(seed=2)
    one = field_lib.as_backend(f1, CFG).encode().factor_bytes()
    store = _store(tmp_path, budget=int(2.5 * one))
    store.register("a", f1, c1)
    store.register("b", f2, c2)
    store.pin("a")                             # a is the LRU candidate...
    store.register("c", f3, c3)                # ...but pressure skips it
    assert "a" in store.resident_scenes()
    assert "b" not in store.resident_scenes()
    assert store.stats("a")["pinned"]

    store.pin("a", False)                      # unpin -> plain LRU again
    store.snapshot("c")
    store.snapshot("b")                        # revive b -> evict coldest=a
    assert "a" not in store.resident_scenes()


def test_store_priority_orders_budget_victims(tmp_path):
    """Under pressure the lowest-priority resident goes first, even when
    it is the most recently used."""
    f1, c1 = _field_and_cubes(seed=0)
    f2, c2 = _field_and_cubes(seed=1)
    f3, c3 = _field_and_cubes(seed=2)
    one = field_lib.as_backend(f1, CFG).encode().factor_bytes()
    store = _store(tmp_path, budget=int(2.5 * one))
    store.register("a", f1, c1)
    store.register("b", f2, c2)
    store.set_priority("b", 5)
    store.snapshot("a")                        # a is warmest but priority 0
    store.register("c", f3, c3)
    assert "a" not in store.resident_scenes()  # lowest priority lost
    assert "b" in store.resident_scenes()
    assert store.stats("b")["priority"] == 5
