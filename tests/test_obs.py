"""Observability stack: metrics registry semantics, exposition formats,
request span tracing, and the engine/store/fine-tune integration —
bit-compatible stats(), complete span trees, and exact drop/timeout
accounting under concurrent multi-scene submits."""
import json
import threading
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import tensorf
from repro.data import rays as rays_lib
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       MetricsServer, StatsReporter, Tracer, snapshot_json,
                       to_prometheus)
from repro.obs.tracing import STAGES
from repro.serving import RenderEngine, SceneStore

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _field_and_cubes(target=0.9, seed=0):
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    field = field_lib.DenseField(params, CFG).prune(sparsity=target)
    occ = occ_lib.build_occupancy(field, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    assert cubes.count > 0
    return field, cubes


# -- registry primitives ---------------------------------------------------


def test_counter_monotone():
    reg = MetricsRegistry()
    c = reg.counter("reqs")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 3.5


def test_gauge_last_write_wins():
    g = MetricsRegistry().gauge("depth")
    g.set(4)
    g.inc()
    g.set(2)
    assert g.value == 2.0


def test_histogram_window_bound_and_alltime_max():
    """The ring keeps only `maxlen` observations for percentiles, but
    count/sum/max are all-time — the SceneRecord.swap_latencies contract
    (bounded memory, worst-case survives the window rolling over)."""
    h = MetricsRegistry().histogram("lat", maxlen=8)
    h.record(100.0)                       # the all-time max, soon evicted
    for v in range(1, 21):
        h.record(float(v))
    assert len(h.window()) == 8
    assert h.count == 21
    assert h.max == 100.0                 # evicted from the window, kept
    assert h.window().max() == 20.0       # window knows only recent values
    assert h.last == 20.0
    assert h.sum == pytest.approx(100.0 + sum(range(1, 21)))
    # percentiles cover the resident window exactly
    assert h.percentile(50) == pytest.approx(
        float(np.percentile(np.arange(13, 21, dtype=float), 50)))


def test_registry_labels_and_handle_caching():
    reg = MetricsRegistry()
    a = reg.counter("views", scene="lego")
    b = reg.counter("views", scene="chair")
    assert a is not b
    a.inc(3)
    assert reg.counter("views", scene="lego") is a      # cached handle
    assert reg.counter("views", scene="lego").value == 3
    assert b.value == 0


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_registry_snapshot_schema():
    reg = MetricsRegistry()
    reg.counter("c", scene="lego").inc(2)
    reg.gauge("g").set(1.5)
    reg.histogram("h", maxlen=4).extend([1.0, 2.0, 3.0])
    snap = reg.snapshot()
    assert snap["counters"]["c{scene=lego}"]["value"] == 2
    assert snap["gauges"]["g"]["value"] == 1.5
    h = snap["histograms"]["h"]
    assert h["count"] == 3 and h["window_len"] == 3 and h["maxlen"] == 4
    assert h["p50"] == 2.0 and h["max"] == 3.0 and h["last"] == 3.0
    # the envelope is JSON-able as-is
    json.dumps(snapshot_json(reg, extra={"fps": 1.0}))


def test_registry_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("n")
    h = reg.histogram("v", maxlen=128)

    def work():
        for i in range(500):
            c.inc()
            h.record(float(i))

    ts = [threading.Thread(target=work) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == 4000
    assert h.count == 4000
    assert len(h.window()) == 128


# -- exposition ------------------------------------------------------------


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("views_total", scene="lego").inc(5)
    reg.gauge("queue_depth").set(2)
    reg.histogram("latency_s").extend([0.1, 0.2, 0.3])
    text = to_prometheus(reg)
    assert "# TYPE views_total counter" in text
    assert 'views_total{scene="lego"} 5' in text
    assert "# TYPE queue_depth gauge" in text
    assert "# TYPE latency_s summary" in text
    assert 'latency_s{quantile="0.5"} ' in text
    assert "latency_s_count 3" in text
    assert text.endswith("\n")


def test_metrics_server_endpoints():
    reg = MetricsRegistry()
    reg.counter("hits").inc(7)
    with MetricsServer(reg, port=0,
                       extra=lambda: {"fps": 12.5}) as srv:
        base = f"http://127.0.0.1:{srv.port}"
        body = urllib.request.urlopen(f"{base}/metrics.json").read()
        snap = json.loads(body)
        assert snap["schema"] == "repro.obs/v1"
        assert snap["metrics"]["counters"]["hits"]["value"] == 7
        assert snap["stats"]["fps"] == 12.5
        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert "hits 7" in text
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope")


def test_stats_reporter_emits_and_stops(capsys):
    rep = StatsReporter(lambda: "tick", interval_s=0.02)
    time.sleep(0.1)
    rep.close()
    out = capsys.readouterr().out
    assert "tick" in out


# -- tracing ---------------------------------------------------------------


def test_tracer_span_tree_and_stage_histograms():
    reg = MetricsRegistry()
    tr = Tracer(reg)
    t = tr.start(1, "lego", t_submit=100.0)
    t.add("submit", 100.0, 100.01)
    t.add("queue", 100.0, 100.2)
    t.add("render", 100.2, 100.7, dispatch_path="fused", n_chunks=3)
    t.add("deliver", 100.7, 100.71)
    tr.finish(t, t_done=100.71)
    tree = t.tree()
    assert tree["view_id"] == 1 and tree["scene"] == "lego"
    assert tree["dur_s"] == pytest.approx(0.71)
    names = [s["name"] for s in tree["stages"]]
    assert names == ["submit", "queue", "render", "deliver"]  # t0 order
    render = tree["stages"][2]
    assert render["dispatch_path"] == "fused" and render["n_chunks"] == 3
    assert render["t0_s"] == pytest.approx(0.2)
    # stage durations folded into the shared registry
    assert reg.histogram("request_stage_s", stage="render").count == 1
    assert reg.histogram("request_stage_s", stage="render").last == \
        pytest.approx(0.5)
    assert reg.counter("render_dispatch_total", path="fused").value == 1
    assert tr.last() is t


def test_tracer_disabled_noops():
    reg = MetricsRegistry()
    tr = Tracer(reg, enabled=False)
    assert tr.start(1, "lego") is None
    tr.finish(None)                       # must not raise
    assert tr.completed() == []
    assert reg.metrics() == []


def test_tracer_completed_window_bounded():
    reg = MetricsRegistry()
    tr = Tracer(reg, max_traces=4)
    for i in range(10):
        tr.finish(tr.start(i, "s", t_submit=float(i)))
    done = tr.completed()
    assert len(done) == 4
    assert [t.view_id for t in done] == [6, 7, 8, 9]


# -- engine integration ----------------------------------------------------


def test_request_span_tree_complete():
    """Acceptance: one rendered request produces a complete span tree —
    submit through deliver, every group stage present, and the render span
    tagged with the field's dispatch path."""
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=64,
                          max_batch_views=2, encode=True)
    cam = rays_lib.make_cameras(1, 12, 12)[0]
    fut = engine.submit(cam)
    engine.flush()
    res = fut.result()
    assert res.trace is not None
    names = [s["name"] for s in res.trace["stages"]]
    for stage in STAGES:
        assert stage in names, f"stage '{stage}' missing from {names}"
    render = next(s for s in res.trace["stages"] if s["name"] == "render")
    assert render["dispatch_path"] == engine.store.snapshot(
        engine.default_scene).field.dispatch_path()
    assert render["dur_s"] > 0
    assert res.trace["dur_s"] >= render["dur_s"]
    # tracer kept the tree; stage histograms carry one observation each
    assert engine.tracer.last().view_id == res.trace["view_id"]
    for stage in STAGES:
        assert engine.metrics.histogram("request_stage_s",
                                        stage=stage).count >= 1
    br = engine.stage_breakdown()
    assert set(br) == set(STAGES)
    assert br["render"]["count"] == 1


def test_engine_stats_registry_backed_and_tracing_toggle():
    field, cubes = _field_and_cubes()
    engine = RenderEngine(CFG, field, cubes, ray_chunk=64,
                          max_batch_views=2)
    cam = rays_lib.make_cameras(1, 12, 12)[0]
    engine.render_views([cam])
    s = engine.stats()
    assert s["views_served"] == 1
    assert s["latency_p99_s"] >= s["latency_p50_s"] > 0
    # the same numbers are visible through the registry snapshot
    snap = engine.metrics.snapshot()
    assert snap["counters"]["engine_views_served"]["value"] == 1
    assert snap["histograms"]["engine_latency_s"]["count"] == 1
    # tracing off: requests render fine, no new traces minted
    engine.set_tracing(False)
    n_before = len(engine.tracer.completed())
    r = engine.render_views([cam])[0]
    assert r.trace is None and not r.timed_out and r.img is not None
    assert len(engine.tracer.completed()) == n_before
    assert engine.stats()["views_served"] == 2


def test_drop_timeout_accounting_concurrent_multiscene():
    """stats()['timeouts'] and per-future timed_out flags must agree
    exactly under concurrent submits across scenes — every future resolves
    exactly once as either served or timed out, and the registry counters
    sum to the observed outcomes."""
    field_a, cubes_a = _field_and_cubes(seed=0)
    field_b, cubes_b = _field_and_cubes(seed=1)
    engine = RenderEngine(CFG, field_a, cubes_a, scene_name="a",
                          ray_chunk=64, max_batch_views=4)
    engine.register_scene("b", field_b, cubes_b)
    cams = rays_lib.make_cameras(4, 12, 12)
    engine.render_views(cams[:1], scene="a")      # compile outside timing
    engine.render_views(cams[:1], scene="b")
    base_views = engine.stats()["views_served"]

    futs, lock = [], threading.Lock()

    def submit_stream(scene, deadline):
        mine = [engine.submit(cam, scene=scene, deadline_s=deadline)
                for cam in cams]
        with lock:
            futs.extend(mine)

    threads = [
        threading.Thread(target=submit_stream, args=("a", None)),
        threading.Thread(target=submit_stream, args=("b", None)),
        # deadline already expired at flush time: these MUST time out
        threading.Thread(target=submit_stream, args=("a", 1e-9)),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    engine.flush()
    results = [f.result() for f in futs]

    n_timed_out = sum(r.timed_out for r in results)
    n_served = sum(not r.timed_out for r in results)
    assert len(results) == 12
    assert n_timed_out == 4                      # exactly the stale stream
    s = engine.stats()
    assert s["timeouts"] == n_timed_out
    assert s["views_served"] - base_views == n_served
    assert int(engine.metrics.counter("engine_timeouts").value) == \
        n_timed_out
    # timed-out traces still close, flagged, without a render span
    to_traces = [r.trace for r in results if r.timed_out]
    assert all(t is not None for t in to_traces)
    for t in to_traces:
        deliver = [st for st in t["stages"] if st["name"] == "deliver"]
        assert deliver and deliver[0]["timed_out"] is True
        assert not any(st["name"] == "render" for st in t["stages"])
    # dropped-pair accounting: counter matches the sum over render spans
    dropped_spans = sum(
        st.get("dropped_pairs", 0)
        for r in results if r.trace is not None
        for st in r.trace["stages"] if st["name"] == "render")
    assert int(engine.metrics.counter("engine_dropped_pairs").value) == \
        dropped_spans


def test_store_and_engine_share_registry():
    """One registry per store: the engine and the store's scene records
    (and any attached fine-tuner — tests/test_finetune.py) record into the
    same registry, so exposition reads one coherent snapshot."""
    field, cubes = _field_and_cubes()
    reg = MetricsRegistry()
    store = SceneStore(CFG, registry=reg)
    store.register("lego", field, cubes)
    engine = RenderEngine(CFG, store=store, ray_chunk=64, max_batch_views=2)
    assert engine.metrics is reg and store.metrics is reg
    cam = rays_lib.make_cameras(1, 12, 12)[0]
    engine.render_views([cam], scene="lego")
    snap = reg.snapshot()
    assert snap["counters"]["scene_views_served{scene=lego}"]["value"] == 1
    assert snap["counters"]["engine_views_served"]["value"] == 1
    # scene stats() keys stay registry-sourced and bit-compatible
    sc = engine.stats(scene="lego")
    assert sc["views_served"] == 1
    assert sc["latency_p50_s"] == pytest.approx(
        snap["histograms"]["scene_latency_s{scene=lego}"]["p50"])
    assert sc["latency_p50_s"] > 0


def test_engine_registry_conflict_rejected():
    field, cubes = _field_and_cubes()
    store = SceneStore(CFG)
    store.register("lego", field, cubes)
    with pytest.raises(ValueError):
        RenderEngine(CFG, store=store, registry=MetricsRegistry(),
                     ray_chunk=64)
