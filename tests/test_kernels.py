"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle,
plus hypothesis property tests on randomly-sparse inputs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from repro.configs.rtnerf import demo_config
from repro.core import field as field_lib
from repro.core import sparse, tensorf
from repro.kernels import fused_sample, ops, ref
from repro.kernels.bitmap_decode import bitmap_gather, bitmap_matmul
from repro.kernels.coo_gather import coo_gather
from repro.kernels.flash_attention import flash_attention
from repro.kernels.volume_render import volume_render


# ---------------------------------------------------------------- bitmap ---
@pytest.mark.parametrize("rows,cols,n", [(8, 32, 4), (16, 64, 8), (32, 128, 1),
                                         (8, 96, 16)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("density", [0.05, 0.5, 1.0])
def test_bitmap_matmul_sweep(rows, cols, n, dtype, density):
    rng = np.random.RandomState(rows * cols + n)
    w = rng.randn(rows, cols).astype(dtype)
    w[rng.rand(rows, cols) >= density] = 0
    enc = sparse.encode_bitmap(w)
    x = rng.randn(cols, n).astype(dtype)
    y_pal = bitmap_matmul(enc.words, enc.rowptr, enc.values, jnp.asarray(x),
                          cols=cols, interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32), w @ x,
                               rtol=2e-2, atol=2e-2)


def test_bitmap_all_zero():
    w = np.zeros((8, 32), np.float32)
    enc = sparse.encode_bitmap(w)
    x = np.ones((32, 2), np.float32)
    y = bitmap_matmul(enc.words, enc.rowptr, enc.values, jnp.asarray(x),
                      cols=32, interpret=True)
    assert np.all(np.asarray(y) == 0)


@pytest.mark.parametrize("rows,cols,nq", [(8, 32, 128), (16, 96, 512),
                                          (40, 70, 256)])
@pytest.mark.parametrize("density", [0.0, 0.1, 0.5, 1.0])
def test_bitmap_gather_sweep(rows, cols, nq, density):
    """Pallas bitmap random-access (interpret) vs jnp oracle vs dense."""
    rng = np.random.RandomState(rows + cols + nq)
    w = rng.randn(rows, cols).astype(np.float32)
    w[rng.rand(rows, cols) >= density] = 0
    enc = sparse.encode_bitmap(w)
    q = jnp.asarray(rng.randint(0, rows * cols, nq), jnp.int32)
    got_pal = bitmap_gather(enc.words, enc.rowptr, enc.values, q,
                            cols=cols, interpret=True)
    got_ref = ref.bitmap_gather_ref(enc.words, enc.rowptr, enc.values, q,
                                    cols)
    want = w.reshape(-1)[np.asarray(q)]
    np.testing.assert_array_equal(np.asarray(got_pal), want)
    np.testing.assert_array_equal(np.asarray(got_ref), want)


def test_bitmap_gather_empty_rows():
    w = np.zeros((8, 64), np.float32)
    w[3, 10] = 2.5
    w[6, 63] = -1.0
    enc = sparse.encode_bitmap(w)
    q = jnp.arange(8 * 64, dtype=jnp.int32)
    got = bitmap_gather(enc.words, enc.rowptr, enc.values, q, cols=64,
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(got).reshape(8, 64), w)


def test_ops_bitmap_gather_ref_dispatch():
    rng = np.random.RandomState(5)
    w = rng.randn(8, 32).astype(np.float32)
    w[rng.rand(8, 32) < 0.6] = 0
    enc = sparse.encode_bitmap(w)
    q = jnp.asarray(rng.randint(0, 8 * 32, 64), jnp.int32)
    got = ops.bitmap_gather(enc.words, enc.rowptr, enc.values, q, cols=32)
    np.testing.assert_array_equal(np.asarray(got),
                                  w.reshape(-1)[np.asarray(q)])


# ------------------------------------------------------------------- coo ---
@pytest.mark.parametrize("size,nq", [(64, 128), (1000, 512), (5, 128)])
def test_coo_gather_sweep(size, nq):
    rng = np.random.RandomState(size)
    flat = rng.randn(size).astype(np.float32)
    flat[rng.rand(size) < 0.9] = 0
    enc = sparse.encode_coo(flat.reshape(1, -1))
    q = jnp.asarray(rng.randint(0, size, nq), jnp.int32)
    got = coo_gather(enc.coords, enc.values, q, interpret=True)
    np.testing.assert_allclose(np.asarray(got), flat[np.asarray(q)])


@given(st.integers(16, 200), st.floats(0.5, 1.0), st.integers(0, 10_000))
def test_coo_gather_property(size, sparsity, seed):
    rng = np.random.RandomState(seed)
    flat = rng.randn(size).astype(np.float32)
    flat[rng.rand(size) < sparsity] = 0
    enc = sparse.encode_coo(flat.reshape(1, -1))
    q = jnp.asarray(rng.randint(0, size, 128), jnp.int32)
    got = ref.coo_gather_ref(enc.coords, enc.values, q)
    np.testing.assert_allclose(np.asarray(got), flat[np.asarray(q)])


# --------------------------------------------------------- volume render ---
@pytest.mark.parametrize("r,n", [(128, 64), (256, 128), (128, 192)])
@pytest.mark.parametrize("scale", [0.1, 3.0, 50.0])
def test_volume_render_sweep(r, n, scale):
    rng = np.random.RandomState(r + n)
    sigma = jnp.asarray(np.abs(rng.randn(r, n)).astype(np.float32) * scale)
    rgb = jnp.asarray(rng.rand(r, n, 3).astype(np.float32))
    c1, t1, n1 = ref.volume_render_ref(sigma, rgb, 0.02, 1e-4)
    c2, t2, n2 = volume_render(sigma, rgb, delta=0.02, term_eps=1e-4,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), atol=1e-6)
    assert float(n1) == float(n2)


def test_volume_render_early_termination_counts():
    # opaque wall at sample 2: nearly everything after it should be skipped
    sigma = jnp.zeros((64, 64), jnp.float32).at[:, 2].set(1e4)
    rgb = jnp.ones((64, 64, 3), jnp.float32) * 0.5
    c, t, nproc = ref.volume_render_ref(sigma, rgb, 0.1, 1e-4)
    assert float(nproc) <= 64 * 4          # only the first few samples
    np.testing.assert_allclose(np.asarray(t), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(c), 0.5, atol=1e-4)


def test_volume_render_transmittance_invariants():
    rng = np.random.RandomState(0)
    sigma = jnp.asarray(np.abs(rng.randn(32, 32)).astype(np.float32))
    rgb = jnp.asarray(rng.rand(32, 32, 3).astype(np.float32))
    c, t, _ = ref.volume_render_ref(sigma, rgb, 0.05, 1e-4)
    assert np.all(np.asarray(t) >= 0) and np.all(np.asarray(t) <= 1)
    # colors bounded by max rgb (convex-ish combination + leftover T)
    assert np.all(np.asarray(c) <= 1.0 + 1e-5)


# ----------------------------------------------------------------- flash ---
@pytest.mark.parametrize("b,h,s,d", [(1, 2, 128, 64), (2, 4, 256, 64),
                                     (1, 1, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, s, d, causal):
    rng = np.random.RandomState(b * s + d)
    q = jnp.asarray(rng.randn(b, h, s, d).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.randn(b, h, s, d).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.randn(b, h, s, d).astype(np.float32))
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal)
    o_pal = flash_attention(q, k, v, causal=causal, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.RandomState(7)
    mk = lambda: jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    q, k, v = mk(), mk(), mk()
    o_ref = ref.flash_attention_ref(q, k, v)
    o_pal = flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-2, atol=3e-2)


# ------------------------------------------------- fused decode-sample ---
def _fused_case(sparsity_lvl, threshold, seed=0, zero_slices=False):
    """A tiny encoded field + cube-grouped query points for fused parity
    tests. Returns (cfg, cf, centers, cube_id, pts)."""
    cfg = demo_config(tiny=True)
    params = tensorf.init_field(cfg, jax.random.PRNGKey(seed))
    params = tensorf.prune_to_sparsity(params, sparsity_lvl)
    if zero_slices:                       # whole factor modes with nnz == 0
        params["sigma_planes"] = params["sigma_planes"].at[1].set(0.0)
        params["app_lines"] = params["app_lines"].at[2].set(0.0)
    cf = field_lib.DenseField(params, cfg).encode(threshold)
    rng = np.random.RandomState(seed)
    C = 4
    ci = rng.randint(0, cfg.cube_grid_res, size=(C, 3))
    centers = jnp.asarray(
        -cfg.scene_bound + (ci + 0.5) * cfg.cube_world(), jnp.float32)
    cid = jnp.asarray(rng.randint(0, C, 300), jnp.int32)
    half = cfg.cube_world() / 2.0
    off = jnp.asarray(rng.uniform(-half, half, (300, 3)), jnp.float32)
    pts = jnp.take(centers, cid, axis=0) + off
    return cfg, cf, centers, cid, pts


def _fused_eval(cfg, cf, centers, cid, pts, force):
    base = tensorf.window_base(cfg, centers)
    return tensorf.eval_sigma_app_hybrid(cf, cfg, pts, base, cid,
                                         force=force)


@pytest.mark.parametrize("force", ["fused_ref", "fused"])
@pytest.mark.parametrize("case,want_fmts", [
    ("bitmap", {"bitmap"}),               # below-threshold factors -> bitmap
    ("coo", {"coo"}),                     # at/above threshold -> COO
    ("mixed", {"bitmap", "coo"}),         # both formats in one field
    ("empty", {"coo"}),                   # factor modes with zero nnz
])
def test_fused_parity(case, want_fmts, force):
    """Fused streaming kernel (jnp oracle AND Pallas interpret mode) vs the
    per-op gather composition, across the codec's format space."""
    if case == "bitmap":
        cfg, cf, centers, cid, pts = _fused_case(0.6, threshold=0.99)
    elif case == "coo":
        cfg, cf, centers, cid, pts = _fused_case(0.9, threshold=0.80)
    elif case == "empty":
        cfg, cf, centers, cid, pts = _fused_case(0.9, threshold=0.80,
                                                 zero_slices=True)
    else:                                 # mixed: splice the two encodings
        cfg, bm, centers, cid, pts = _fused_case(0.6, threshold=0.99)
        co = bm.decode().encode(0.0)
        cf = field_lib.CompressedField(
            {"sigma_planes": bm.factors["sigma_planes"],
             "sigma_lines": co.factors["sigma_lines"],
             "app_planes": co.factors["app_planes"],
             "app_lines": bm.factors["app_lines"]},
            bm.extras, cfg, bm.threshold)
    fmts = {ef.fmt for efs in cf.factors.values() for ef in efs}
    assert fmts == want_fmts, f"case {case} encoded as {fmts}"
    want_sig = cf.sigma(pts)              # per-op oracle composition
    want_feat = cf.app_features(pts)
    got_sig, got_feat = _fused_eval(cfg, cf, centers, cid, pts, force)
    np.testing.assert_allclose(np.asarray(got_sig), np.asarray(want_sig),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_feat), np.asarray(want_feat),
                               rtol=1e-5, atol=1e-5)


def test_fused_multi_block_padding():
    """Point counts that are not a multiple of the kernel block exercise the
    pad-and-slice wrapper and a multi-step Pallas grid."""
    cfg, cf, centers, cid, pts = _fused_case(0.9, threshold=0.80)
    spec, streams = tensorf.fused_field_inputs(cf)
    base = tensorf.window_base(cfg, centers)
    W = tensorf.fused_window(cfg)
    want, _ = fused_sample.fused_sigma_app_ref(
        spec, streams, cf.extras["basis"], pts, base, cid,
        grid_res=cfg.grid_res, scene_bound=cfg.scene_bound, window=W,
        app_dim=cfg.app_dim)
    got, _ = fused_sample.fused_sigma_app(
        spec, streams, cf.extras["basis"], pts, base, cid,
        grid_res=cfg.grid_res, scene_bound=cfg.scene_bound, window=W,
        app_dim=cfg.app_dim, block_pts=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_fused_out_of_window_points_are_finite():
    """Points outside their cube's window read clipped entries by contract
    (callers mask them); the kernel must stay in-bounds and finite."""
    cfg, cf, centers, cid, pts = _fused_case(0.9, threshold=0.80)
    far = pts + 10.0 * cfg.cube_world()   # well outside every window
    sig, feat = _fused_eval(cfg, cf, centers, cid, far, "fused_ref")
    assert np.all(np.isfinite(np.asarray(sig)))
    assert np.all(np.isfinite(np.asarray(feat)))


def test_fused_dispatch_contract():
    """ops.fused_mode / hybrid_dispatch: fused_ref on CPU by default,
    "per-op" forces the gather composition, unsupported specs fall back."""
    cfg, cf, centers, cid, pts = _fused_case(0.9, threshold=0.80)
    assert ops.fused_mode("pallas") == "fused"
    assert ops.fused_mode("ref") == "fused_ref"
    assert ops.fused_mode("per-op") == "per-op"
    if jax.default_backend() != "tpu":
        assert tensorf.hybrid_dispatch(cf) == "fused_ref"
    spec, _ = tensorf.fused_field_inputs(cf)
    assert len(spec) == 12 and fused_sample.fused_supported(spec)
    assert not fused_sample.fused_supported(spec[:3])
    # forcing per-op still produces the same numbers through sigma_app
    want_sig, want_feat = _fused_eval(cfg, cf, centers, cid, pts, "per-op")
    got_sig, got_feat = _fused_eval(cfg, cf, centers, cid, pts, None)
    np.testing.assert_allclose(np.asarray(got_sig), np.asarray(want_sig),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_feat), np.asarray(want_feat),
                               rtol=1e-5, atol=1e-5)


def test_fused_rank_table_restores():
    """bitmap rank tables are derived state: dropping them (as a restored
    checkpoint would) routes dispatch to per-op until recomputed."""
    import dataclasses
    cfg, cf, centers, cid, pts = _fused_case(0.6, threshold=0.99)
    stripped = {}
    for k, efs in cf.factors.items():
        out = []
        for ef in efs:
            if ef.fmt == "bitmap":
                e = dataclasses.replace(ef)
                e.bitmap = sparse.BitmapEncoded(
                    ef.bitmap.shape, ef.bitmap.words, ef.bitmap.rowptr,
                    ef.bitmap.values, ef.bitmap.nnz, rank=None)
                out.append(e)
            else:
                out.append(ef)
        stripped[k] = tuple(out)
    cf2 = field_lib.CompressedField(stripped, cf.extras, cfg, cf.threshold)
    spec, streams = tensorf.fused_field_inputs(cf2)
    assert spec is None and streams is None
    assert tensorf.hybrid_dispatch(cf2) == "per-op"
    # the fallback still answers correctly
    sig, feat = _fused_eval(cfg, cf2, centers, cid, pts, None)
    np.testing.assert_allclose(np.asarray(sig), np.asarray(cf.sigma(pts)),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- ops API ---
def test_ops_dispatch_ref_on_cpu():
    rng = np.random.RandomState(1)
    w = rng.randn(8, 32).astype(np.float32)
    w[rng.rand(8, 32) < 0.5] = 0
    enc = sparse.encode_bitmap(w)
    x = jnp.asarray(rng.randn(32, 4).astype(np.float32))
    y = ops.bitmap_matmul(enc.words, enc.rowptr, enc.values, x, cols=32)
    np.testing.assert_allclose(np.asarray(y), w @ np.asarray(x), rtol=1e-5)
