"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, output shapes + finiteness (assignment requirement f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, reduced
from repro.models import transformer as tf
from repro.models.common import split_pl

ARCH_NAMES = sorted(ARCHS)


def tiny_batch(cfg, B=2, S=16):
    key = jax.random.PRNGKey(0)
    b = {}
    n_text = S - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    b["tokens"] = jax.random.randint(key, (B, n_text), 0, cfg.vocab)
    b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    b["loss_mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.frontend == "vision":
        b["frontend"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        b["enc_frames"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16)
    return b


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(ARCHS[name])
            params, _ = split_pl(tf.init_model(cfg, jax.random.PRNGKey(42)))
            cache[name] = (cfg, params)
        return cache[name]
    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_loss_finite(models, name):
    cfg, params = models(name)
    batch = tiny_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: tf.model_loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{name}: loss {loss}"
    assert float(loss) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_updates_params(models, name):
    from repro.optim import adamw
    cfg, params = models(name)
    batch = tiny_batch(cfg)
    opt = adamw(lr=1e-2)
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, _), g = jax.value_and_grad(
            lambda q: tf.model_loss(q, cfg, b), has_aux=True)(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, loss

    p2, s2, loss = step(params, state, batch)
    assert jnp.isfinite(loss)
    # at least the embedding moved
    delta = jnp.abs(p2["embed"].astype(jnp.float32)
                    - params["embed"].astype(jnp.float32)).max()
    assert float(delta) > 0
    leaves = jax.tree.leaves(p2)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32)))) for l in leaves)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_shapes(models, name):
    cfg, params = models(name)
    B, S = 2, 16
    batch = tiny_batch(cfg, B, S)
    batch.pop("labels")
    batch.pop("loss_mask")
    logits, cache = jax.jit(lambda p, b: tf.model_prefill(p, cfg, b))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    shapes, _ = tf.serve_cache_spec(cfg, B, S)
    zero = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    tok = jnp.zeros((B, 1), jnp.int32)
    lg, cache2 = jax.jit(
        lambda p, t, c: tf.model_decode(p, cfg, t, jnp.int32(3), c, seq_len=S)
    )(params, tok, zero)
    assert lg.shape == (B, 1, cfg.vocab_padded)
    assert jnp.all(jnp.isfinite(lg.astype(jnp.float32)))
    assert jax.tree.structure(cache2) == jax.tree.structure(zero)
