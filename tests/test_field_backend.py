"""Unified FieldBackend API (core/field.py): pytree registration, the
trainable-leaf view behind compressed-native training, dense-vs-compressed
training parity, and encoded-field checkpoint round-trips."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, tensorf
from repro.core import train as nerf_train
from repro.data import rays as rays_lib

CFG = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=256,
                 r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                 max_samples_per_ray=64, train_rays=256)


def _fields(target=0.9, seed=0):
    params = tensorf.init_field(CFG, jax.random.PRNGKey(seed))
    f = field_lib.DenseField(params, CFG).prune(sparsity=target)
    return f, f.encode()


# -- pytree registration ----------------------------------------------------


@pytest.mark.parametrize("which", ["dense", "compressed"])
def test_backends_are_pytrees(which):
    """flatten/unflatten round-trips and jit accepts the backend as an
    argument (the mechanism behind swap-without-retrace and device_put)."""
    f, cf = _fields()
    b = f if which == "dense" else cf
    pts = jax.random.uniform(jax.random.PRNGKey(1), (64, 3),
                             minval=-1.2, maxval=1.2)
    leaves, treedef = jax.tree.flatten(b)
    b2 = jax.tree.unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(b2.sigma(pts)),
                                  np.asarray(b.sigma(pts)))
    jf = jax.jit(lambda fb, q: fb.sigma(q))
    np.testing.assert_allclose(np.asarray(jf(b, pts)),
                               np.asarray(b.sigma(pts)),
                               rtol=1e-5, atol=1e-6)


def test_compressed_pytree_carries_codec_metadata():
    """Integer codec arrays (bitmap words / rowptr, COO coords) are leaves
    of the pytree (they must travel through device_put) but are NOT in the
    trainable view (they must not receive gradients)."""
    _, cf = _fields(0.9)
    leaves = jax.tree.leaves(cf)
    int_leaves = [x for x in leaves if not jnp.issubdtype(x.dtype,
                                                          jnp.floating)]
    assert int_leaves, "expected integer codec metadata leaves"
    t = cf.trainable()
    for v in t.values():
        assert jnp.issubdtype(v.dtype, jnp.floating)


# -- trainable view ---------------------------------------------------------


def test_with_trainable_updates_values_in_place():
    _, cf = _fields(0.9)
    t = cf.trainable()
    t2 = {k: v * 2.0 for k, v in t.items()}
    cf2 = cf.with_trainable(t2)
    # structure identical, payload doubled
    assert cf2.sparsity_report() == cf.sparsity_report()
    k = "factors/sigma_planes/0"
    np.testing.assert_allclose(np.asarray(cf2.trainable()[k]),
                               2.0 * np.asarray(t[k]))


def test_gradients_flow_to_encoded_values():
    """grad through the hybrid gather lands on the packed nnz values — the
    compressed-native training mechanism."""
    _, cf = _fields(0.9)
    pts = jax.random.uniform(jax.random.PRNGKey(2), (128, 3),
                             minval=-1.2, maxval=1.2)

    def loss(t):
        return jnp.sum(cf.with_trainable(t).sigma(pts))

    g = jax.grad(loss)(cf.trainable())
    sig = [v for k, v in g.items() if k.startswith("factors/sigma")]
    assert all(np.isfinite(np.asarray(v)).all() for v in g.values())
    assert sum(float(jnp.abs(v).sum()) for v in sig) > 0.0


def test_l1_matches_dense_semantics():
    f, cf = _fields(0.9)
    np.testing.assert_allclose(float(cf.l1()), float(f.l1()),
                               rtol=1e-5)
    np.testing.assert_allclose(float(cf.tv()), float(f.tv()), rtol=1e-5)


# -- compressed-native training ---------------------------------------------


def test_compressed_training_matches_dense_psnr():
    """Acceptance: train_nerf with the factors kept encoded between
    optimizer steps lands within 0.5 dB of the dense loop on the tiny
    scene, and actually returns an encoded field."""
    kw = dict(steps=80, n_views=4, image_hw=24, occ_every=40,
              log_every=1000, verbose=False, seed=0)
    res_c = nerf_train.train_nerf(CFG, "lego", compressed=True, **kw)
    res_d = nerf_train.train_nerf(CFG, "lego", compressed=False, **kw)
    assert res_c.field.kind == "compressed"
    assert res_d.field.kind == "dense"
    scene = rays_lib.make_scene("lego")
    cam = rays_lib.make_cameras(5, 24, 24)[1]
    gt = rays_lib.render_gt(scene, cam)
    p_c, _, _ = nerf_train.eval_view(res_c.field, CFG, res_c.cubes, cam, gt,
                                     pipeline="rtnerf", chunk=8)
    p_d, _, _ = nerf_train.eval_view(res_d.field, CFG, res_d.cubes, cam, gt,
                                     pipeline="rtnerf", chunk=8)
    assert abs(p_c - p_d) <= 0.5, (p_c, p_d)


def test_train_rebuild_uses_cfg_occ_sigma_thresh(monkeypatch):
    """The occupancy rebuild must read cfg.occ_sigma_thresh — no hard-coded
    trainer default (the old sigma_thresh=2.0 silently disagreed with the
    config constant)."""
    seen = []
    real = occ_lib.build_occupancy

    def spy(field, cfg, sigma_thresh=None, chunk=65536):
        out = real(field, cfg, sigma_thresh=sigma_thresh, chunk=chunk)
        seen.append(cfg.occ_sigma_thresh if sigma_thresh is None
                    else sigma_thresh)
        return out

    monkeypatch.setattr(nerf_train.occ_lib, "build_occupancy", spy)
    nerf_train.train_nerf(CFG, "lego", steps=2, n_views=2, image_hw=16,
                          log_every=1000, verbose=False)
    assert seen == [CFG.occ_sigma_thresh]


# -- checkpoint round-trip (encoded, no decompress) -------------------------


def test_checkpoint_roundtrips_encoded_field(tmp_path):
    """save_field/restore_field preserve the encoded representation bit for
    bit: formats, factor bytes, every codec array, and the rendered image."""
    _, cf = _fields(0.9)
    ckpt_lib.save_field(str(tmp_path), 7, cf)
    got, extra = ckpt_lib.restore_field(str(tmp_path), 7, CFG)
    assert got.kind == "compressed"
    assert extra["field_spec"]["kind"] == "compressed"

    # formats + bytes identical
    assert got.sparsity_report() == cf.sparsity_report()
    assert got.factor_bytes() == cf.factor_bytes()

    # every codec array identical (bitmap words/rowptr, coo coords, values)
    _, a0 = field_lib.field_state(cf)
    _, a1 = field_lib.field_state(got)
    assert sorted(a0) == sorted(a1)
    for k in a0:
        np.testing.assert_array_equal(np.asarray(a0[k]), np.asarray(a1[k]),
                                      err_msg=k)

    # rendered image identical -> PSNR identical by construction
    occ = occ_lib.build_occupancy(cf, CFG, sigma_thresh=0.01)
    cubes = occ_lib.extract_cubes(occ, CFG)
    cam = rays_lib.make_cameras(3, 16, 16)[0]
    img0, _ = rt_pipe.render_rtnerf(cf, CFG, cubes, cam, chunk=8)
    img1, _ = rt_pipe.render_rtnerf(got, CFG, cubes, cam, chunk=8)
    np.testing.assert_array_equal(np.asarray(img0), np.asarray(img1))


def test_checkpoint_roundtrips_dense_field(tmp_path):
    f, _ = _fields(0.9)
    ckpt_lib.save_field(str(tmp_path), 1, f)
    got, _ = ckpt_lib.restore_field(str(tmp_path), 1, CFG)
    assert got.kind == "dense"
    for k in f.params:
        np.testing.assert_array_equal(np.asarray(got.params[k]),
                                      np.asarray(f.params[k]))


def test_restore_field_rejects_plain_checkpoint(tmp_path):
    ckpt_lib.save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError, match="state-dict|field_spec"):
        ckpt_lib.restore_field(str(tmp_path), 1, CFG)


def test_cfg_mismatches_detects_other_config():
    _, cf = _fields(0.9)
    assert field_lib.cfg_mismatches(cf, CFG) == []
    other = dataclasses.replace(CFG, grid_res=16)
    assert field_lib.cfg_mismatches(cf, other)


# -- distributed placement --------------------------------------------------


def test_place_field_keeps_eval_and_replicates():
    from repro.core import distributed
    from repro.launch.mesh import make_host_mesh
    from repro.models.sharding import make_rules

    _, cf = _fields(0.9)
    rules = make_rules(make_host_mesh())
    placed = distributed.place_field(cf, rules)
    pts = jax.random.uniform(jax.random.PRNGKey(3), (64, 3),
                             minval=-1.2, maxval=1.2)
    np.testing.assert_allclose(np.asarray(placed.sigma(pts)),
                               np.asarray(cf.sigma(pts)),
                               rtol=1e-6, atol=1e-6)
    for leaf in jax.tree.leaves(placed):
        assert leaf.sharding.is_fully_replicated
