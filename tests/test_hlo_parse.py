"""Trip-weighted HLO parser: the §Perf measurement tool must itself be
correct (flops exact on scan matmuls; collective models sane)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_parse
from repro.launch.hlo_analysis import roofline_terms, model_flops


def _compile_scan_matmul(n, d=256):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y
    return jax.jit(f).lower(
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32)).compile()


@pytest.mark.parametrize("n", [1, 3, 17])
def test_flops_scale_with_trip_count(n):
    rec = hlo_parse.analyze(_compile_scan_matmul(n).as_text())
    assert rec["flops"] == pytest.approx(2 * 256 ** 3 * n, rel=1e-6)
    if n > 1:
        assert rec["trip_counts"][0] == n


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    rec = hlo_parse.analyze(c.as_text())
    assert rec["flops"] == pytest.approx(2 * 64 ** 3 * 15, rel=1e-6)


def test_collective_wire_model():
    # synthetic HLO line checks for the ring model
    txt = """
ENTRY %main (p: f32[128,8]) -> f32[128,8] {
  %p = f32[128,8]{1,0} parameter(0)
  %all-reduce.1 = f32[128,8]{1,0} all-reduce(%p), replica_groups=[4,4]<=[16], to_apply=%add
  ROOT %all-gather.2 = f32[128,8]{1,0} all-gather(%all-reduce.1), replica_groups=[2,8]<=[16], dimensions={0}
}
"""
    rec = hlo_parse.analyze(txt)
    n = 128 * 8 * 4
    assert rec["wire_all-reduce"] == pytest.approx(2 * n * 3 / 4)
    assert rec["wire_all-gather"] == pytest.approx(n * 7 / 8)
    assert rec["n_all-reduce"] == 1 and rec["n_all-gather"] == 1


def test_roofline_terms_and_model_flops():
    t = roofline_terms(197e12, 819e9, 50e9)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert model_flops(10, 0, 7, "train") == 6 * 10 * 7
    assert model_flops(10, 4, 7, "decode") == 2 * 4 * 7
