import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Diagnostic: top trip-weighted collectives in the deepseek train cell."""
import sys

from repro.configs.base import LM_SHAPES
from repro.configs.registry import get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.launch import hlo_parse
import dataclasses

overrides = {}
for kv in sys.argv[1:]:
    k, v = kv.split("=", 1)
    overrides[k] = (v == "True") if v in ("True", "False") else (
        int(v) if v.isdigit() else v)

cfg = dataclasses.replace(get_arch("deepseek-v3-671b"), **overrides)
mesh = make_production_mesh(multi_pod=False)
lowered, info = lower_cell(cfg, LM_SHAPES["train_4k"], mesh)
compiled = lowered.compile()
txt = compiled.as_text()
for wire, mult, kind, shape, name in hlo_parse.top_collectives(txt, 25):
    print(f"{wire:12.3e}  x{mult:5.0f}  {kind:18s} {shape:45s} {name}")
