"""Dev driver: run reduced configs through train loss / prefill / decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, reduced
from repro.models.common import split_pl
from repro.models import transformer as tf


def batch_for(cfg, B=2, S=16):
    b = {}
    n_text = S - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    key = jax.random.PRNGKey(0)
    b["tokens"] = jax.random.randint(key, (B, n_text), 0, cfg.vocab)
    b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    b["loss_mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.frontend == "vision":
        b["frontend"] = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        b["enc_frames"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    return b


def main(names):
    for name in names:
        cfg = reduced(ARCHS[name])
        print(f"=== {name} ({cfg.family}) ===", flush=True)
        pl = tf.init_model(cfg, jax.random.PRNGKey(42))
        params, logical = split_pl(pl)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"  params: {n/1e6:.2f}M")
        B, S = 2, 16
        batch = batch_for(cfg, B, S)
        loss, metrics = jax.jit(lambda p, b: tf.model_loss(p, cfg, b))(params, batch)
        assert jnp.isfinite(loss), f"loss not finite: {loss}"
        print(f"  train loss: {float(loss):.4f}")
        # prefill + decode
        logits, cache = jax.jit(lambda p, b: tf.model_prefill(p, cfg, b))(params, batch)
        assert jnp.all(jnp.isfinite(logits)), "prefill logits not finite"
        print(f"  prefill logits: {logits.shape}")
        tok = jnp.zeros((B, 1), jnp.int32)
        # decode against a fresh spec-shaped cache
        shapes, log = tf.serve_cache_spec(cfg, B, S)
        zero_cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        lg, cache2 = jax.jit(
            lambda p, t, c: tf.model_decode(p, cfg, t, jnp.int32(3), c, seq_len=S)
        )(params, tok, zero_cache)
        assert jnp.all(jnp.isfinite(lg)), "decode logits not finite"
        print(f"  decode logits: {lg.shape}  cache leaves: {len(jax.tree.leaves(cache2))}")
    print("OK")


if __name__ == "__main__":
    names = sys.argv[1:] or list(ARCHS)
    main(names)
