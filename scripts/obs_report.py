#!/usr/bin/env python
"""Render a serving stage-breakdown table from a metrics snapshot.

Input is a `repro.obs/v1` JSON snapshot — a file written by
`serve --metrics-dump out.json`, or a live scrape:

    PYTHONPATH=src python -m repro.launch.serve --arch rtnerf \
        --scene lego --metrics-dump /tmp/obs.json
    python scripts/obs_report.py /tmp/obs.json

    curl -s http://127.0.0.1:9100/metrics.json | \
        python scripts/obs_report.py -

The report has three sections: the per-request stage breakdown (where did
a served view's time go: queue, group, ordering, compaction, render,
deliver — from the `request_stage_s{stage=...}` histograms the tracer
folds every finished request into), the render dispatch-path counts
(`render_dispatch_total{path=...}`: fused kernel vs per-op decode vs
dense), and the headline counters/gauges.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

# canonical lifecycle order (mirrors repro.obs.tracing.REPORT_STAGES
# without importing repro — this script runs against a snapshot file
# alone); warp/mask/composite only appear on temporal-tier delta frames
STAGES = ("warp", "mask", "submit", "queue", "group", "ordering",
          "compaction", "render", "composite", "deliver")

_LABELLED = re.compile(r"^(?P<name>[^{]+)\{(?P<labels>.*)\}$")


def parse_flat(flat: str):
    """'name{k=v,...}' -> (name, {k: v}); bare names -> (name, {})."""
    m = _LABELLED.match(flat)
    if not m:
        return flat, {}
    labels = {}
    for item in m.group("labels").split(","):
        if item:
            k, _, v = item.partition("=")
            labels[k] = v
    return m.group("name"), labels


def stage_table(hists) -> str:
    rows = []
    by_stage = {}
    for flat, snap in hists.items():
        name, labels = parse_flat(flat)
        if name == "request_stage_s" and "stage" in labels:
            by_stage[labels["stage"]] = snap
    known = [s for s in STAGES if s in by_stage]
    extra = sorted(set(by_stage) - set(STAGES))
    if not by_stage:
        return "  (no request_stage_s histograms — tracing off or no " \
               "requests served)"
    hdr = (f"  {'stage':>10s} {'count':>6s} {'p50_ms':>9s} {'p95_ms':>9s} "
           f"{'p99_ms':>9s} {'total_s':>8s}")
    rows.append(hdr)
    rows.append("  " + "-" * (len(hdr) - 2))
    for st in known + extra:
        s = by_stage[st]
        rows.append(f"  {st:>10s} {s['count']:>6d} "
                    f"{s['p50'] * 1e3:>9.2f} {s['p95'] * 1e3:>9.2f} "
                    f"{s['p99'] * 1e3:>9.2f} {s['sum']:>8.3f}")
    return "\n".join(rows)


def dispatch_table(counters) -> str:
    rows = []
    for flat, snap in sorted(counters.items()):
        name, labels = parse_flat(flat)
        if name == "render_dispatch_total" and "path" in labels:
            rows.append(f"  {labels['path']:>10s} {int(snap['value']):>6d}")
    return "\n".join(rows) if rows else "  (no dispatch counts)"


def headline(snapshot) -> str:
    rows = []
    stats = snapshot.get("stats") or {}
    for k in ("views_served", "fps", "latency_p50_s", "latency_p99_s",
              "timeouts", "dropped_pairs", "field_swaps", "evictions",
              "revivals"):
        if k in stats:
            v = stats[k]
            rows.append(f"  {k:>16s} = {v:.3f}" if isinstance(v, float)
                        else f"  {k:>16s} = {v}")
    if not rows:
        counters = snapshot["metrics"]["counters"]
        for flat in sorted(counters):
            rows.append(f"  {flat:>32s} = {counters[flat]['value']:g}")
    return "\n".join(rows) if rows else "  (none)"


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot",
                    help="path to a repro.obs/v1 JSON snapshot, or '-' "
                         "to read it from stdin")
    args = ap.parse_args()
    if args.snapshot == "-":
        snap = json.load(sys.stdin)
    else:
        with open(args.snapshot) as f:
            snap = json.load(f)
    if snap.get("schema") != "repro.obs/v1":
        sys.exit(f"not a repro.obs/v1 snapshot "
                 f"(schema={snap.get('schema')!r})")

    print("== request stage breakdown ==")
    print(stage_table(snap["metrics"]["histograms"]))
    print("\n== render dispatch paths ==")
    print(dispatch_table(snap["metrics"]["counters"]))
    print("\n== headline ==")
    print(headline(snap))


if __name__ == "__main__":
    main()
