"""Dev driver: train a tiny NeRF on one scene, compare pipelines."""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.rtnerf import NeRFConfig
from repro.core import train as nerf_train
from repro.core import rendering
from repro.data import rays as rays_lib

cfg = NeRFConfig(grid_res=48, occ_res=48, cube_size=4, max_cubes=1024,
                 r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                 max_samples_per_ray=128, train_rays=1024)

t0 = time.time()
res = nerf_train.train_nerf(cfg, "lego", steps=300, n_views=10, image_hw=64,
                            log_every=100)
print(f"train time {time.time()-t0:.1f}s  cubes={res.cubes.count}")

scene = rays_lib.make_scene("lego")
cam = rays_lib.make_cameras(7, 64, 64)[3]
gt = rays_lib.render_gt(scene, cam)

for pl, kw in [("uniform", {}), ("rtnerf", {"order_mode": "octant"}),
               ("rtnerf", {"order_mode": "distance"})]:
    t0 = time.time()
    p, stats, img = nerf_train.eval_view(res.field, cfg, res.cubes, cam, gt,
                                         pipeline=pl, **kw)
    print(f"{pl:8s} {kw}: psnr={p:.2f} dt={time.time()-t0:.1f}s "
          f"occ_accesses={stats['occ_accesses']:.0f} "
          f"processed={stats['processed_samples']:.0f}")
