"""repro-lint CLI — AST invariant checks for concurrency + JIT contracts.

    python scripts/repro_lint.py src/              # what CI runs
    python scripts/repro_lint.py src/repro/serving # narrow to a subtree
    python scripts/repro_lint.py src/ --rule lock-discipline
    python scripts/repro_lint.py src/ --write-baseline  # grandfather all

Exits 0 iff there are no unwaived, un-baselined findings. The baseline
(lint_baseline.json at the repo root, auto-loaded when present) holds
grandfathered findings by line-stable fingerprint; inline waivers use
``# lint: waive(<rule>) — <reason>`` and require a reason.

Stdlib only — no dependencies beyond the Python that runs the tests.
"""
from __future__ import annotations

import argparse
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import base, runner  # noqa: E402

DEFAULT_BASELINE = os.path.join(REPO_ROOT, "lint_baseline.json")


def main(argv) -> int:
    ap = argparse.ArgumentParser(prog="repro_lint")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to check (default: src)")
    ap.add_argument("--rule", action="append", dest="rules",
                    choices=list(base.ALL_RULES),
                    help="restrict to the given rule id (repeatable)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: lint_baseline.json at "
                         "the repo root, when it exists)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current unwaived findings to the baseline "
                         "and exit 0")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print waived/baselined findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv[1:])

    if args.list_rules:
        for r in base.ALL_RULES:
            print(r)
        return 0

    baseline = None
    if not args.no_baseline and not args.write_baseline:
        baseline = args.baseline or (
            DEFAULT_BASELINE if os.path.exists(DEFAULT_BASELINE) else None)

    # Relative paths resolve against the caller's cwd (standard CLI
    # behavior); display paths and baseline fingerprints are cwd-relative,
    # which equals repo-relative for the canonical `repro_lint.py src/`
    # invocation from the repo root.
    report = runner.run(args.paths or ["src"], root=os.getcwd(),
                        baseline=baseline, rules=args.rules)

    if args.write_baseline:
        path = args.baseline or DEFAULT_BASELINE
        n = base.write_baseline(path, report.findings)
        print(f"repro-lint: wrote {n} fingerprint(s) to "
              f"{os.path.relpath(path, REPO_ROOT)}")
        return 0

    print(report.format(show_waived=args.show_waived))
    return 1 if report.gating else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
