"""Check that internal links in the repo's markdown docs resolve.

Validates, for each given markdown file (default: README.md and
docs/*.md):
  * relative links point at files/directories that exist in the repo;
  * #fragment links (same-file or cross-file) match a real heading,
    using GitHub's anchor slug rules.
External (scheme://) links are skipped — CI must not depend on the
network. Exit non-zero listing every broken link.

`--require FILE` (repeatable) additionally fails if FILE is absent —
docs/*.md is a glob, so a deleted guide would otherwise just silently
drop out of the check. CI pins the load-bearing guides this way.
`--require FILE.md#anchor` further pins a heading inside the file
(GitHub slug rules), so a renamed section breaks the build instead of
silently orphaning the runbooks that deep-link it.

    python scripts/check_doc_links.py [files...]
    python scripts/check_doc_links.py --require docs/kernels.md \
        --require docs/architecture.md#fleet-tier
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"^```.*?^```", re.MULTILINE | re.DOTALL)


def strip_fences(text: str) -> str:
    """Drop fenced code blocks: '#'-prefixed shell comments inside them are
    not headings (fake anchors would mask broken links), and their brackets
    are not rendered links."""
    return FENCE_RE.sub("", text)


def slugify(heading: str) -> str:
    """GitHub's markdown anchor rule: lowercase, drop punctuation,
    spaces -> dashes (backticks and markdown emphasis stripped first)."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        return {slugify(m.group(1))
                for m in HEADING_RE.finditer(strip_fences(f.read()))}


def check(path: str, root: str) -> list:
    errors = []
    with open(path, encoding="utf-8") as f:
        text = strip_fences(f.read())
    for m in LINK_RE.finditer(text):
        target = m.group(0 + 1)
        if "://" in target or target.startswith("mailto:"):
            continue
        file_part, _, frag = target.partition("#")
        if file_part:
            dest = os.path.normpath(
                os.path.join(os.path.dirname(path), file_part))
            if not os.path.exists(dest):
                errors.append(f"{path}: broken link {target!r} "
                              f"({dest} does not exist)")
                continue
        else:
            dest = path
        if frag and dest.endswith(".md"):
            if slugify(frag) not in anchors_of(dest):
                errors.append(f"{path}: broken anchor {target!r} "
                              f"(no heading #{frag} in {dest})")
    return errors


def main(argv) -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*")
    ap.add_argument("--require", action="append", default=[],
                    help="repo-relative file that must exist (repeatable); "
                         "required .md files also join the checked set; "
                         "FILE.md#anchor additionally requires a matching "
                         "heading in the file")
    args = ap.parse_args(argv[1:])
    files = args.files or ["README.md"] + sorted(
        glob.glob(os.path.join(root, "docs", "*.md")))
    errors = []
    checked = {os.path.abspath(x if os.path.isabs(x)
                               else os.path.join(root, x)) for x in files}
    for req in args.require:
        f, _, frag = req.partition("#")
        path = os.path.abspath(f if os.path.isabs(f)
                               else os.path.join(root, f))
        if not os.path.exists(path):
            errors.append(f"{f}: required doc is missing")
            continue
        if frag:
            if not f.endswith(".md"):
                errors.append(f"{req}: anchor requires a .md file")
            elif slugify(frag) not in anchors_of(path):
                errors.append(f"{req}: required anchor missing "
                              f"(no heading #{frag} in {f})")
        if f.endswith(".md") and path not in checked:
            checked.add(path)
            files.append(f)
    for f in files:
        path = f if os.path.isabs(f) else os.path.join(root, f)
        if not os.path.exists(path):
            errors.append(f"{f}: file not found")
            continue
        errors.extend(check(path, root))
    for e in errors:
        print(f"BROKEN: {e}")
    if not errors:
        print(f"doc links OK ({len(files)} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
