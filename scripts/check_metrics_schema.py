#!/usr/bin/env python
"""Validate a `repro.obs/v1` metrics snapshot (CI metrics-smoke gate).

Checks the JSON envelope produced by `serve --metrics-dump` or the
`/metrics.json` endpoint against the schema contract documented in
docs/observability.md:

  * envelope: `schema == "repro.obs/v1"`, numeric `ts_unix_s`, a
    `metrics` object with `counters` / `gauges` / `histograms` maps;
  * every counter/gauge snapshot has a numeric `value` (counters >= 0);
  * every histogram snapshot has integer `count`/`window_len`/`maxlen`,
    numeric `sum`/`max`/`last`/`mean`/`p50`/`p95`/`p99`, with
    `window_len <= min(count, maxlen)` and `p50 <= p95 <= p99 <= max`
    (when the window is non-empty);
  * flat names parse as `name` or `name{k=v,...}`.

`--expect-counter NAME` / `--expect-gauge NAME` / `--expect-histogram
NAME` (repeatable) assert a metric of that base name exists — CI uses
them to pin the serving-stack names (engine_views_served,
request_stage_s, fleet_requests_total, ...) so a rename cannot land
without updating the docs and this gate. `--expect-prefix-complete
PREFIX` additionally flags metrics under that prefix that are NOT
pinned — so a new fleet_* family cannot land undocumented either.
Pin violations are collected and reported as one readable diff
(`- missing ...` / `+ unexpected ...`), not a bare first-failure assert;
structural envelope violations still exit on first hit.

    python scripts/check_metrics_schema.py /tmp/obs.json \
        --expect-counter engine_views_served \
        --expect-histogram engine_latency_s \
        --expect-counter fleet_requests_total \
        --expect-prefix-complete fleet_
"""
from __future__ import annotations

import argparse
import json
import re
import sys

FLAT = re.compile(r"^[A-Za-z_:][A-Za-z0-9_:.]*(\{[^{}]*\})?$")


def fail(msg: str):
    sys.exit(f"metrics schema violation: {msg}")


def base_name(flat: str) -> str:
    return flat.split("{", 1)[0]


def need_num(obj, key, where, *, integer=False):
    v = obj.get(key)
    ok = isinstance(v, int) if integer \
        else isinstance(v, (int, float)) and not isinstance(v, bool)
    if not ok:
        fail(f"{where}: '{key}' must be {'an integer' if integer else 'a number'}, got {v!r}")
    return v


def check(snap, expect_counters, expect_gauges, expect_histograms,
          prefix_complete):
    if snap.get("schema") != "repro.obs/v1":
        fail(f"schema must be 'repro.obs/v1', got {snap.get('schema')!r}")
    need_num(snap, "ts_unix_s", "envelope")
    metrics = snap.get("metrics")
    if not isinstance(metrics, dict):
        fail("'metrics' must be an object")
    for kind in ("counters", "gauges", "histograms"):
        if not isinstance(metrics.get(kind), dict):
            fail(f"metrics.{kind} must be an object")

    for kind in ("counters", "gauges"):
        for flat, m in metrics[kind].items():
            if not FLAT.match(flat):
                fail(f"{kind} name {flat!r} does not parse")
            v = need_num(m, "value", f"{kind}[{flat}]")
            if kind == "counters" and v < 0:
                fail(f"counters[{flat}]: negative value {v}")

    for flat, h in metrics["histograms"].items():
        where = f"histograms[{flat}]"
        if not FLAT.match(flat):
            fail(f"histogram name {flat!r} does not parse")
        count = need_num(h, "count", where, integer=True)
        wlen = need_num(h, "window_len", where, integer=True)
        maxlen = need_num(h, "maxlen", where, integer=True)
        for k in ("sum", "max", "last", "mean", "p50", "p95", "p99"):
            need_num(h, k, where)
        if wlen > maxlen:
            fail(f"{where}: window_len {wlen} > maxlen {maxlen}")
        if wlen > count:
            fail(f"{where}: window_len {wlen} > all-time count {count}")
        if wlen > 0 and not (h["p50"] <= h["p95"] <= h["p99"]
                             <= h["max"] + 1e-9):
            fail(f"{where}: percentiles not ordered "
                 f"(p50={h['p50']} p95={h['p95']} p99={h['p99']} "
                 f"max={h['max']})")

    # -- name pins: collect everything, fail once with a readable diff --
    have = {kind: {base_name(f) for f in metrics[kind]}
            for kind in ("counters", "gauges", "histograms")}
    expected = {"counters": set(expect_counters),
                "gauges": set(expect_gauges),
                "histograms": set(expect_histograms)}
    diff = []
    for kind in ("counters", "gauges", "histograms"):
        for name in sorted(expected[kind] - have[kind]):
            diff.append(f"- missing {kind[:-1]} {name}")
    pinned = set().union(*expected.values())
    for prefix in prefix_complete:
        for kind in ("counters", "gauges", "histograms"):
            for name in sorted(have[kind]):
                if name.startswith(prefix) and name not in pinned:
                    diff.append(f"+ unexpected {kind[:-1]} {name} "
                                f"(matches --expect-prefix-complete "
                                f"{prefix!r} but is not pinned)")
    if diff:
        sys.exit("metrics schema violation: pinned names do not match "
                 "the snapshot:\n  " + "\n  ".join(diff)
                 + "\n(update the --expect-* pins AND "
                 "docs/observability.md together)")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", help="snapshot JSON path, or '-' for stdin")
    ap.add_argument("--expect-counter", action="append", default=[],
                    metavar="NAME", help="require a counter of this base "
                    "name (repeatable)")
    ap.add_argument("--expect-gauge", action="append", default=[],
                    metavar="NAME", help="require a gauge of this base "
                    "name (repeatable)")
    ap.add_argument("--expect-histogram", action="append", default=[],
                    metavar="NAME", help="require a histogram of this base "
                    "name (repeatable)")
    ap.add_argument("--expect-prefix-complete", action="append",
                    default=[], metavar="PREFIX",
                    help="flag metrics under PREFIX that are not pinned "
                    "by an --expect-* flag (repeatable)")
    args = ap.parse_args()
    if args.snapshot == "-":
        snap = json.load(sys.stdin)
    else:
        with open(args.snapshot) as f:
            snap = json.load(f)
    check(snap, args.expect_counter, args.expect_gauge,
          args.expect_histogram, args.expect_prefix_complete)
    n = sum(len(snap["metrics"][k]) for k in ("counters", "gauges",
                                              "histograms"))
    print(f"ok: repro.obs/v1 snapshot with {n} metrics "
          f"({len(snap['metrics']['histograms'])} histograms)")


if __name__ == "__main__":
    main()
