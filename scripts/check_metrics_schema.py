#!/usr/bin/env python
"""Validate a `repro.obs/v1` metrics snapshot (CI metrics-smoke gate).

Checks the JSON envelope produced by `serve --metrics-dump` or the
`/metrics.json` endpoint against the schema contract documented in
docs/observability.md:

  * envelope: `schema == "repro.obs/v1"`, numeric `ts_unix_s`, a
    `metrics` object with `counters` / `gauges` / `histograms` maps;
  * every counter/gauge snapshot has a numeric `value` (counters >= 0);
  * every histogram snapshot has integer `count`/`window_len`/`maxlen`,
    numeric `sum`/`max`/`last`/`mean`/`p50`/`p95`/`p99`, with
    `window_len <= min(count, maxlen)` and `p50 <= p95 <= p99 <= max`
    (when the window is non-empty);
  * flat names parse as `name` or `name{k=v,...}`.

`--expect-counter NAME` / `--expect-histogram NAME` (repeatable) assert a
metric of that base name exists — CI uses them to pin the serving-stack
names (engine_views_served, request_stage_s, ...) so a rename cannot land
without updating the docs and this gate. Exits non-zero with a pointed
message on the first violation.

    python scripts/check_metrics_schema.py /tmp/obs.json \
        --expect-counter engine_views_served \
        --expect-histogram engine_latency_s
"""
from __future__ import annotations

import argparse
import json
import re
import sys

FLAT = re.compile(r"^[A-Za-z_:][A-Za-z0-9_:.]*(\{[^{}]*\})?$")


def fail(msg: str):
    sys.exit(f"metrics schema violation: {msg}")


def base_name(flat: str) -> str:
    return flat.split("{", 1)[0]


def need_num(obj, key, where, *, integer=False):
    v = obj.get(key)
    ok = isinstance(v, int) if integer \
        else isinstance(v, (int, float)) and not isinstance(v, bool)
    if not ok:
        fail(f"{where}: '{key}' must be {'an integer' if integer else 'a number'}, got {v!r}")
    return v


def check(snap, expect_counters, expect_histograms):
    if snap.get("schema") != "repro.obs/v1":
        fail(f"schema must be 'repro.obs/v1', got {snap.get('schema')!r}")
    need_num(snap, "ts_unix_s", "envelope")
    metrics = snap.get("metrics")
    if not isinstance(metrics, dict):
        fail("'metrics' must be an object")
    for kind in ("counters", "gauges", "histograms"):
        if not isinstance(metrics.get(kind), dict):
            fail(f"metrics.{kind} must be an object")

    for kind in ("counters", "gauges"):
        for flat, m in metrics[kind].items():
            if not FLAT.match(flat):
                fail(f"{kind} name {flat!r} does not parse")
            v = need_num(m, "value", f"{kind}[{flat}]")
            if kind == "counters" and v < 0:
                fail(f"counters[{flat}]: negative value {v}")

    for flat, h in metrics["histograms"].items():
        where = f"histograms[{flat}]"
        if not FLAT.match(flat):
            fail(f"histogram name {flat!r} does not parse")
        count = need_num(h, "count", where, integer=True)
        wlen = need_num(h, "window_len", where, integer=True)
        maxlen = need_num(h, "maxlen", where, integer=True)
        for k in ("sum", "max", "last", "mean", "p50", "p95", "p99"):
            need_num(h, k, where)
        if wlen > maxlen:
            fail(f"{where}: window_len {wlen} > maxlen {maxlen}")
        if wlen > count:
            fail(f"{where}: window_len {wlen} > all-time count {count}")
        if wlen > 0 and not (h["p50"] <= h["p95"] <= h["p99"]
                             <= h["max"] + 1e-9):
            fail(f"{where}: percentiles not ordered "
                 f"(p50={h['p50']} p95={h['p95']} p99={h['p99']} "
                 f"max={h['max']})")

    counters = {base_name(f) for f in metrics["counters"]}
    hists = {base_name(f) for f in metrics["histograms"]}
    for name in expect_counters:
        if name not in counters:
            fail(f"expected counter '{name}' missing "
                 f"(have: {sorted(counters)})")
    for name in expect_histograms:
        if name not in hists:
            fail(f"expected histogram '{name}' missing "
                 f"(have: {sorted(hists)})")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", help="snapshot JSON path, or '-' for stdin")
    ap.add_argument("--expect-counter", action="append", default=[],
                    metavar="NAME", help="require a counter of this base "
                    "name (repeatable)")
    ap.add_argument("--expect-histogram", action="append", default=[],
                    metavar="NAME", help="require a histogram of this base "
                    "name (repeatable)")
    args = ap.parse_args()
    if args.snapshot == "-":
        snap = json.load(sys.stdin)
    else:
        with open(args.snapshot) as f:
            snap = json.load(f)
    check(snap, args.expect_counter, args.expect_histogram)
    n = sum(len(snap["metrics"][k]) for k in ("counters", "gauges",
                                              "histograms"))
    print(f"ok: repro.obs/v1 snapshot with {n} metrics "
          f"({len(snap['metrics']['histograms'])} histograms)")


if __name__ == "__main__":
    main()
