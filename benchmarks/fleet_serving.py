"""Fleet serving benchmark: aggregate FPS + p95 under a zipfian
scene-popularity workload, single worker vs sharded fleet.

    PYTHONPATH=src python benchmarks/fleet_serving.py --tiny --check

What it measures
----------------
S scenes, each exported once (`serving.fleet.export_scene`), served
through `FleetRouter` twice with the SAME per-worker memory budget and
the SAME request sequence: once with 1 worker, once with `--workers N`
(default 2). The budget holds ~S/N scenes, so the single worker LRU-
thrashes — every touch of a non-resident scene pays a spill + revive
cycle — while the sharded fleet keeps each worker's shard fully
resident. That residency locality is the fleet tier's core claim (and
RT-NeRF's: hybrid encodings pay off when hot scenes stay near their
requests), and it is what the `--check` gate certifies:

  * aggregate FPS at N workers >= 1.5x the single worker,
  * zero dropped non-deadline requests in either run.

On multi-core CI runners the fleet additionally wins from real process
parallelism; on a single-core box the gate is carried by churn avoidance
alone, which is why the workload is closed-loop (one request in flight,
as an interactive AR/VR client would be) — back-pressure batching would
let the single worker amortise its churn across a flush group and hide
the locality signal this benchmark exists to expose.

Scenes are random-init pruned fields (`--no-train` is implicit): the
workload exercises the serving path — routing, residency, eviction,
revival, wire framing — where radiance quality is irrelevant; training
would add minutes of setup to measure the same path. Scene names are
chosen so the consistent-hash ring splits them evenly across the fleet
(a 3/1 split would leave one worker over budget and the comparison
meaningless); popularity ranks alternate workers so each holds hot and
cold scenes.
"""
import argparse
import json
import os
import shutil
import sys
import tempfile
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

TINY = dict(grid_res=16, occ_res=16, cube_size=8, max_cubes=16,
            r_sigma=2, r_color=4, app_dim=4, mlp_hidden=8,
            max_samples_per_ray=16, train_rays=256)
FULL = dict(grid_res=24, occ_res=24, cube_size=8, max_cubes=64,
            r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
            max_samples_per_ray=32, train_rays=256)


def pick_balanced_scenes(n_scenes, n_workers):
    """Scene names the ring splits evenly across `n_workers`, popularity
    ranks alternating workers (each worker gets hot AND cold scenes)."""
    from repro.serving import HashRing

    ring = HashRing([f"w{i}" for i in range(n_workers)])
    per_worker = n_scenes // n_workers
    buckets = {f"w{i}": [] for i in range(n_workers)}
    i = 0
    while any(len(b) < per_worker for b in buckets.values()):
        name = f"scene_{i:03d}"
        owner = ring.owner(name)
        if len(buckets[owner]) < per_worker:
            buckets[owner].append(name)
        i += 1
        if i > 10_000:          # pragma: no cover - sha1 would have to be
            raise RuntimeError("could not balance scene names")  # broken
    # rank r -> worker r % n_workers, so popularity alternates owners
    return [buckets[f"w{r % n_workers}"][r // n_workers]
            for r in range(n_scenes)]


def export_scenes(cfg, names, root):
    import jax

    from repro.core import field as field_lib
    from repro.core import occupancy as occ_lib
    from repro.core import tensorf
    from repro.serving import export_scene

    paths = {}
    for i, name in enumerate(names):
        params = tensorf.init_field(cfg, jax.random.PRNGKey(i))
        field = field_lib.DenseField(params, cfg).prune(sparsity=0.9)
        occ = occ_lib.build_occupancy(field, cfg,
                                      sigma_thresh=0.01)
        cubes = occ_lib.extract_cubes(occ, cfg)
        paths[name] = export_scene(os.path.join(root, name), field.encode(),
                                   cubes, scene=name)
    one = field_lib.as_backend(
        field_lib.DenseField(tensorf.init_field(cfg, jax.random.PRNGKey(0)),
                             cfg).prune(sparsity=0.9), cfg
    ).encode().factor_bytes()
    return paths, one


def zipf_pmf(n, s):
    p = 1.0 / np.arange(1, n + 1) ** s
    return p / p.sum()


def build_workload(names, n_requests, n_streams, zipf_s, seed):
    """Round-robin interleave of `n_streams` closed-loop users, each
    drawing its scene iid from the zipf popularity law. The interleave is
    what defeats single-worker LRU: consecutive requests rarely repeat a
    scene, so a budget of S/N scenes misses on most touches."""
    rng = np.random.default_rng(seed)
    pmf = zipf_pmf(len(names), zipf_s)
    per = int(np.ceil(n_requests / n_streams))
    draws = [rng.choice(len(names), size=per, p=pmf)
             for _ in range(n_streams)]
    seq = []
    for t in range(per):
        for u in range(n_streams):
            seq.append((u, names[draws[u][t]]))
    return seq[:n_requests]


def run_fleet(cfg, paths, names, workload, cams, *, n_workers, budget,
              res, warmup_rounds=4):
    from repro.serving import FleetRouter

    router = FleetRouter(cfg, paths, n_workers=n_workers,
                         engine_kwargs=dict(max_resident_bytes=budget,
                                            ray_chunk=res * res))
    try:
        # warm every (scene, viewpoint): registers scenes on their owners,
        # compiles each worker's jit step, settles the adaptive pair
        # budget — the timed loop then measures steady-state serving.
        for _ in range(warmup_rounds):
            for name in names:
                for cam in cams:
                    router.submit(cam, scene=name).result(timeout=300.0)

        # best-of-2 timed passes (the steady_state idiom): one-core boxes
        # timeshare noisily, and the gate compares two measured numbers.
        drops, wall, latencies = 0, None, None
        for _ in range(2):
            lat = []
            t0 = time.perf_counter()
            for user, name in workload:
                r = router.submit(cams[user % len(cams)],
                                  scene=name).result(timeout=300.0)
                if r.timed_out or r.img is None:
                    drops += 1
                lat.append(r.latency_s)
            w = time.perf_counter() - t0
            if wall is None or w < wall:
                wall, latencies = w, lat

        stats = router.stats()
        lat = np.asarray(latencies)
        return {
            "workers": n_workers,
            "aggregate_fps": len(workload) / wall,
            "wall_s": wall,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "dropped": drops,
            "requests": len(workload),
            "routing_version": stats["routing_version"],
            "replays": stats["replays_total"],
            "worker_stats": {
                w: {k: s[k] for k in ("views_served", "fps", "evictions",
                                      "revivals", "resident_scenes",
                                      "queue_depth")}
                for w, s in stats["workers"].items()},
        }, router
    except BaseException:
        router.close()
        raise


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tiny", action="store_true",
                    help="tiny shapes (CI gate)")
    ap.add_argument("--workers", type=int, default=2,
                    help="fleet size to compare against 1 worker")
    ap.add_argument("--scenes", type=int, default=None,
                    help="number of scenes (default 4 tiny / 6 full)")
    ap.add_argument("--requests", type=int, default=None,
                    help="timed requests (default 120 tiny / 300 full)")
    ap.add_argument("--streams", type=int, default=6,
                    help="interleaved closed-loop user streams")
    ap.add_argument("--zipf", type=float, default=0.9,
                    help="zipf popularity exponent")
    ap.add_argument("--res", type=int, default=None,
                    help="view resolution (default 8 tiny / 16 full)")
    ap.add_argument("--budget-scenes", type=float, default=None,
                    help="per-worker budget in units of one scene's "
                         "factor bytes (default: scenes/workers + 0.5)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_fleet.json"))
    ap.add_argument("--metrics-dump", default=None,
                    help="write the fleet run's obs registry snapshot")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the fleet gate holds")
    args = ap.parse_args()

    from repro.configs.rtnerf import NeRFConfig
    from repro.data import rays as rays_lib
    from repro.obs import snapshot_json

    shape = TINY if args.tiny else FULL
    cfg = NeRFConfig(**shape)
    n_scenes = args.scenes or (4 if args.tiny else 6)
    n_requests = args.requests or (160 if args.tiny else 300)
    res = args.res or (8 if args.tiny else 16)
    budget_scenes = (args.budget_scenes if args.budget_scenes is not None
                     else n_scenes / args.workers + 0.5)

    names = pick_balanced_scenes(n_scenes, args.workers)
    root = tempfile.mkdtemp(prefix="fleet_bench_")
    try:
        t0 = time.perf_counter()
        paths, one_scene_bytes = export_scenes(cfg, names, root)
        export_s = time.perf_counter() - t0
        budget = int(budget_scenes * one_scene_bytes)
        workload = build_workload(names, n_requests, args.streams,
                                  args.zipf, args.seed)
        cams = rays_lib.make_cameras(3, res, res)

        runs = {}
        dump_router = None
        for w in (1, args.workers):
            t0 = time.perf_counter()
            result, router = run_fleet(cfg, paths, names, workload, cams,
                                       n_workers=w, budget=budget, res=res)
            result["setup_plus_run_s"] = time.perf_counter() - t0
            runs[str(w)] = result
            print(f"[fleet] {w} worker(s): "
                  f"{result['aggregate_fps']:.2f} req/s, "
                  f"p95 {result['latency_p95_s'] * 1000:.1f} ms, "
                  f"dropped {result['dropped']}, "
                  f"revivals {sum(s['revivals'] for s in result['worker_stats'].values())}")
            if w == args.workers and args.metrics_dump:
                snap = snapshot_json(router.registry,
                                     extra=router.stats())
                with open(args.metrics_dump, "w") as f:
                    json.dump(snap, f, indent=2)
                print(f"[obs] metrics snapshot written to "
                      f"{args.metrics_dump}")
            router.close()

        single, fleet = runs["1"], runs[str(args.workers)]
        speedup = fleet["aggregate_fps"] / single["aggregate_fps"]
        report = {
            "mode": "tiny" if args.tiny else "full",
            "config": shape,
            "scenes": names,
            "one_scene_bytes": one_scene_bytes,
            "per_worker_budget_bytes": budget,
            "budget_scenes": budget_scenes,
            "requests": n_requests,
            "streams": args.streams,
            "zipf_s": args.zipf,
            "res": res,
            "export_s": export_s,
            "runs": runs,
            "fleet_speedup": speedup,
            "notes": "closed-loop zipfian workload; same per-worker "
                     "budget both runs — the single worker thrashes its "
                     "LRU across all scenes while the sharded fleet "
                     "keeps each shard resident (plus real process "
                     "parallelism on multi-core hosts)",
        }
        out = os.path.abspath(args.out)
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
        print(json.dumps({k: v for k, v in report.items()
                          if k not in ("config", "notes")}, indent=2))
        print(f"report -> {out}")

        if args.check:
            failures = []
            if speedup < 1.5:
                failures.append(
                    f"fleet speedup {speedup:.2f}x < 1.5x "
                    f"({fleet['aggregate_fps']:.2f} vs "
                    f"{single['aggregate_fps']:.2f} req/s)")
            for w, r in runs.items():
                if r["dropped"]:
                    failures.append(f"{r['dropped']} dropped non-deadline "
                                    f"requests at {w} worker(s)")
                if r["replays"]:
                    failures.append(f"{r['replays']} replays at {w} "
                                    f"worker(s) — no worker should die "
                                    f"in this benchmark")
            if failures:
                print("CHECK FAILED: " + "; ".join(failures))
                sys.exit(1)
            print(f"CHECK OK: fleet speedup {speedup:.2f}x >= 1.5x, "
                  f"zero dropped requests")
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
