"""Batched multi-view serving (serving.RenderEngine) vs the sequential
per-view loop it replaced, from the same resident compressed field.

Sequential = the pre-engine `serve --arch rtnerf` path: one
`eval_view`/`render_rtnerf` call per camera (re-traced per view, every
(cube, pixel) pair evaluated). Batched = the engine: one jitted
micro-batched ray step with active-pair compaction, octant-cached cube
orderings, and the encoded streams resident. Both render the same cameras
against sphere-traced ground truth, so the FPS ratio is at equal PSNR.

    PYTHONPATH=src python benchmarks/serving_throughput.py
    PYTHONPATH=src python benchmarks/serving_throughput.py --tiny --check

Emits BENCH_serving.json (FPS, p50/p95 latency, factor bytes) so the perf
trajectory is tracked across PRs. --check exits non-zero unless batched
FPS >= 1.5x sequential at PSNR parity (within 0.5 dB).

CPU wall-clock is a relative signal (TPU is the compile target), but the
batched/sequential *ratio* is the claim under test: what the engine
amortises — compilation, encode, ordering — and what compaction skips.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.core import occupancy as occ_lib
from repro.core import train as nerf_train
from repro.data import rays as rays_lib
from repro.serving import RenderEngine


def pctl(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--res", type=int, default=56)
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--prune", type=float, default=0.9)
    ap.add_argument("--dense", action="store_true",
                    help="serve the raw factor arrays instead of the "
                         "hybrid encoding")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: 20 steps, 32^2, 5 views")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless batched FPS >= 1.5x the "
                         "sequential loop at PSNR parity (0.5 dB)")
    args = ap.parse_args()
    if args.tiny:
        args.steps, args.res, args.views = 20, 32, 5

    if args.tiny:
        cfg = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=320,
                         r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                         max_samples_per_ray=64, train_rays=512)
    else:
        cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                         r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                         max_samples_per_ray=112, train_rays=1024)

    res = nerf_train.train_nerf(cfg, args.scene, steps=args.steps, n_views=8,
                                image_hw=args.res, log_every=10_000,
                                verbose=False)
    field = res.field.prune(sparsity=args.prune)
    if args.dense:
        field = field.decode()
    occ = occ_lib.build_occupancy(field, cfg)
    cubes = occ_lib.extract_cubes(occ, cfg)

    scene = rays_lib.make_scene(args.scene)
    cams = rays_lib.make_cameras(args.views, args.res, args.res)
    gts = [rays_lib.render_gt(scene, cam) for cam in cams]

    # -- sequential per-view loop (the replaced serve path) ----------------
    seq_lat, seq_psnr = [], []
    t_seq = time.time()
    for cam, gt in zip(cams, gts):
        t0 = time.time()
        p, stats, _ = nerf_train.eval_view(field, cfg, cubes, cam, gt,
                                           pipeline="rtnerf", chunk=8)
        seq_lat.append(time.time() - t0)
        seq_psnr.append(p)
    seq_total = time.time() - t_seq
    seq_fps = args.views / seq_total

    # -- batched engine over the same resident field -----------------------
    engine = RenderEngine(cfg, field, cubes, encode=not args.dense,
                          ray_chunk=args.res * args.res,
                          max_batch_views=args.views)
    t_bat = time.time()
    results = engine.render_views(cams, gts)
    bat_total = time.time() - t_bat
    bat_fps = args.views / bat_total
    bat_psnr = [r.psnr for r in results]
    bat_lat = [r.latency_s for r in results]
    es = engine.stats()

    speedup = bat_fps / max(seq_fps, 1e-9)
    report = {
        "scene": args.scene, "views": args.views, "res": args.res,
        "prune": args.prune, "field_kind": es["field_kind"],
        "factor_bytes": es["factor_bytes"],
        "factor_bytes_dense": es["factor_bytes_dense"],
        "occ_accesses_per_view": es["occ_accesses_per_view"],
        "dropped_pairs": es["dropped_pairs"],
        "ordering_cache": es["ordering_cache"],
        "sequential": {
            "fps": seq_fps, "total_s": seq_total,
            "latency_p50_s": pctl(seq_lat, 50),
            "latency_p95_s": pctl(seq_lat, 95),
            "psnr_mean": float(np.mean(seq_psnr)),
        },
        "batched": {
            "fps": bat_fps, "total_s": bat_total,
            "latency_p50_s": pctl(bat_lat, 50),
            "latency_p95_s": pctl(bat_lat, 95),
            "psnr_mean": float(np.mean(bat_psnr)),
        },
        "speedup": speedup,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    if args.check:
        failures = []
        if speedup < 1.5:
            failures.append(f"batched speedup {speedup:.2f}x < 1.5x")
        dp = float(np.mean(bat_psnr)) - float(np.mean(seq_psnr))
        if dp < -0.5:
            failures.append(f"batched psnr {np.mean(bat_psnr):.2f} more "
                            f"than 0.5 dB below sequential "
                            f"{np.mean(seq_psnr):.2f}")
        if es["dropped_pairs"] > 0:
            failures.append(f"{es['dropped_pairs']} ray-cube pairs dropped "
                            "(pair budget too small)")
        if failures:
            print("CHECK FAILED: " + "; ".join(failures))
            sys.exit(1)
        print(f"CHECK OK: {speedup:.2f}x FPS over the sequential loop at "
              f"PSNR parity ({np.mean(bat_psnr):.2f} vs "
              f"{np.mean(seq_psnr):.2f} dB)")


if __name__ == "__main__":
    main()
