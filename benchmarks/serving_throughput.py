"""Batched multi-view serving (serving.RenderEngine) vs the sequential
per-view loop it replaced — plus the multi-scene case: per-scene FPS when
several scenes are resident in one SceneStore and flush in the same
cycles.

Sequential = the pre-engine `serve --arch rtnerf` path: one
`eval_view`/`render_rtnerf` call per camera (re-traced per view, every
(cube, pixel) pair evaluated). Batched = the engine: one jitted
micro-batched ray step with active-pair compaction, octant-cached cube
orderings, and the encoded streams resident. Both render the same cameras
against sphere-traced ground truth, so the FPS ratio is at equal PSNR.

With `--scenes a,b` the same engine then serves an interleaved stream
across all scenes from one store, and the claim under test becomes the
multi-scene acceptance bar: every scene's per-scene FPS — its render-rate
FPS, views over the time spent rendering that scene's flush groups — must
stay >= 0.7x the single-scene batched baseline measured in the same run
(scene routing, per-scene snapshots, and cross-scene flush grouping must
not eat the engine's amortisation wins; wall-clock per-scene FPS is
reported too, but with N scenes fairly sharing one engine it sits near
baseline/N by construction).

    PYTHONPATH=src python benchmarks/serving_throughput.py
    PYTHONPATH=src python benchmarks/serving_throughput.py --tiny --check
    PYTHONPATH=src python benchmarks/serving_throughput.py \
        --tiny --check --scenes lego,chair          # nightly 2-scene gate

Emits BENCH_serving.json (FPS, p50/p95/p99 latency + timeout counts,
factor bytes, a trace-derived per-stage latency table from the engine's
request tracer, the instrumentation self-overhead, per-scene multi-scene
table) so the perf trajectory is tracked across PRs. Both the sequential
and batched rows use the shared best-of-iters steady-state methodology
(`benchmarks.common.steady_state`): the warmup/compile pass is recorded
separately as `compile_s`, so the FPS ratio excludes compile on both
sides. A repeated-view segment re-serves the same cameras and records
the ordering-cache counters across it (`repeat` +
`ordering_cache_after_repeat`), so schedule reuse is exercised — not
perpetually 0 — on every benchmark run. --check exits non-zero unless
batched FPS >= 1.5x sequential at PSNR parity (within 0.5 dB), tracing
costs < 2% FPS (traced vs `set_tracing(False)` passes on the same warmed
engine), the repeated-view segment scores ordering-cache hits — and,
when >1 scene is served, unless every scene's FPS >= 0.7x the
single-scene baseline.

CPU wall-clock is a relative signal (TPU is the compile target), but the
batched/sequential *ratio* is the claim under test: what the engine
amortises — compilation, encode, ordering — and what compaction skips.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import steady_state  # noqa: E402

from repro.configs.rtnerf import NeRFConfig  # noqa: E402
from repro.core import occupancy as occ_lib  # noqa: E402
from repro.core import train as nerf_train  # noqa: E402
from repro.data import rays as rays_lib  # noqa: E402
from repro.serving import RenderEngine  # noqa: E402


def pctl(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def trained_field(cfg, scene, steps, res, prune, dense):
    res_t = nerf_train.train_nerf(cfg, scene, steps=steps, n_views=8,
                                  image_hw=res, log_every=10_000,
                                  verbose=False)
    field = res_t.field.prune(sparsity=prune)
    if dense:
        field = field.decode()
    occ = occ_lib.build_occupancy(field, cfg)
    cubes = occ_lib.extract_cubes(occ, cfg)
    return field, cubes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--scenes", default=None,
                    help="comma-separated list for the multi-scene case "
                         "(e.g. lego,chair); the first is also the "
                         "single-scene baseline")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--res", type=int, default=56)
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=3,
                    help="multi-scene: passes over the interleaved stream")
    ap.add_argument("--prune", type=float, default=0.9)
    ap.add_argument("--dense", action="store_true",
                    help="serve the raw factor arrays instead of the "
                         "hybrid encoding")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: 20 steps, 32^2, 5 views")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless batched FPS >= 1.5x the "
                         "sequential loop at PSNR parity (0.5 dB), "
                         "instrumentation overhead < 2% FPS, and — "
                         "multi-scene — per-scene render-rate FPS >= 0.7x "
                         "the single-scene baseline")
    args = ap.parse_args()
    if args.tiny:
        args.steps, args.res, args.views = 20, 32, 5

    scene_names = ([s for s in args.scenes.split(",") if s]
                   if args.scenes else [args.scene])
    base_scene = scene_names[0]

    if args.tiny:
        cfg = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=320,
                         r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                         max_samples_per_ray=64, train_rays=512)
    else:
        cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                         r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                         max_samples_per_ray=112, train_rays=1024)

    fields = {n: trained_field(cfg, n, args.steps, args.res, args.prune,
                               args.dense) for n in scene_names}
    field, cubes = fields[base_scene]

    cams = rays_lib.make_cameras(args.views, args.res, args.res)
    gt_scenes = {n: rays_lib.make_scene(n) for n in scene_names}
    gts = {n: [rays_lib.render_gt(gt_scenes[n], cam) for cam in cams]
           for n in scene_names}

    # -- sequential per-view loop (the replaced serve path) ----------------
    # shared best-of-iters methodology (common.steady_state): the first
    # pass warms op caches and is reported as compile_s; the steady pass
    # is the serving-relevant number — same exclusion every BENCH family
    # applies, so the batched/sequential ratio is compile-free on BOTH
    # sides
    def seq_pass():
        lat, ps = [], []
        for cam, gt in zip(cams, gts[base_scene]):
            t0 = time.time()
            p, _, _ = nerf_train.eval_view(field, cfg, cubes, cam, gt,
                                           pipeline="rtnerf", chunk=8)
            lat.append(time.time() - t0)
            ps.append(p)
        return lat, ps

    seq_total, seq_compile, (seq_lat, seq_psnr) = steady_state(seq_pass,
                                                               iters=1)
    seq_fps = args.views / seq_total

    # -- batched engine over the same resident field -----------------------
    engine = RenderEngine(cfg, field, cubes, scene_name=base_scene,
                          encode=not args.dense,
                          ray_chunk=args.res * args.res,
                          max_batch_views=args.views)
    bat_total, bat_compile, results = steady_state(
        lambda: engine.render_views(cams, gts[base_scene]), iters=1)
    bat_fps = args.views / bat_total
    bat_psnr = [r.psnr for r in results]
    bat_lat = [r.latency_s for r in results]
    es = engine.stats()

    # -- instrumentation self-overhead: traced vs tracing-off passes -------
    # Same warmed engine, same cameras; best-of-2 per mode so one scheduler
    # hiccup on a shared CI box doesn't decide the gate. The claim under
    # test: per-request span tracing + registry recording must cost < 2%
    # FPS — observability that taxes the serving path defeats its purpose.
    def timed_pass():
        t0 = time.time()
        engine.render_views(cams, gts[base_scene])
        return time.time() - t0

    engine.set_tracing(True)
    timed_pass()                                     # symmetric warm pass
    t_traced = min(timed_pass() for _ in range(2))
    engine.set_tracing(False)
    timed_pass()
    t_plain = min(timed_pass() for _ in range(2))
    engine.set_tracing(True)
    fps_traced = args.views / t_traced
    fps_plain = args.views / t_plain
    overhead_frac = max(0.0, 1.0 - fps_traced / max(fps_plain, 1e-9))

    # -- repeated-view segment: ordering-cache reuse under a looping -------
    # workload (a camera path revisiting poses — the hits-perpetually-0
    # blind spot this segment closes: every re-served camera must be an
    # exact ordering-cache hit, visible both here and in the
    # ordering_cache_hits registry counters)
    oc_pre = engine.stats()["ordering_cache"]
    t0 = time.time()
    engine.render_views(cams, gts[base_scene])
    repeat_total = time.time() - t0
    oc_post = engine.stats()["ordering_cache"]
    repeat = {
        "fps": args.views / repeat_total,
        "hits_delta": oc_post["hits"] - oc_pre["hits"],
        "misses_delta": oc_post["misses"] - oc_pre["misses"],
    }

    speedup = bat_fps / max(seq_fps, 1e-9)
    report = {
        "scene": base_scene, "views": args.views, "res": args.res,
        "prune": args.prune, "field_kind": es["field_kind"],
        "factor_bytes": es["factor_bytes"],
        "factor_bytes_dense": es["factor_bytes_dense"],
        "occ_accesses_per_view": es["occ_accesses_per_view"],
        "dropped_pairs": es["dropped_pairs"],
        "pair_budget": es["pair_budget"],
        "pair_budget_initial": es["pair_budget_initial"],
        "pair_budget_resizes": es["pair_budget_resizes"],
        "ordering_cache": es["ordering_cache"],
        "ordering_cache_after_repeat": oc_post,
        "sequential": {
            "fps": seq_fps, "total_s": seq_total,
            "compile_s": seq_compile,
            "latency_p50_s": pctl(seq_lat, 50),
            "latency_p95_s": pctl(seq_lat, 95),
            "latency_p99_s": pctl(seq_lat, 99),
            "timeouts": 0,          # the per-view loop has no deadline path
            "psnr_mean": float(np.mean(seq_psnr)),
        },
        "batched": {
            "fps": bat_fps, "total_s": bat_total,
            "compile_s": bat_compile,
            "latency_p50_s": pctl(bat_lat, 50),
            "latency_p95_s": pctl(bat_lat, 95),
            "latency_p99_s": pctl(bat_lat, 99),
            "timeouts": es["timeouts"],
            "psnr_mean": float(np.mean(bat_psnr)),
        },
        "repeat": repeat,
        # trace-derived per-stage latency columns (queue/group/ordering/
        # compaction/render/deliver) from the engine's request tracer
        "stages": engine.stage_breakdown(),
        "overhead": {
            "fps_traced": fps_traced, "fps_untraced": fps_plain,
            "overhead_frac": overhead_frac,
        },
        "speedup": speedup,
    }

    # -- multi-scene: interleaved stream over N resident scenes ------------
    multi = None
    if len(scene_names) > 1:
        for n in scene_names[1:]:
            engine.register_scene(n, *fields[n])
        # warm every scene's compiled variant + ordering caches so the
        # measured ratio is steady-state routing cost, not first-touch
        for n in scene_names:
            engine.render_views(cams[:1], gts[n][:1], scene=n)
        # per-scene telemetry is cumulative since engine construction —
        # snapshot it here so the ratio below covers ONLY the multi-scene
        # window (the baseline + warmup renders would dilute it)
        pre = {n: engine.stats(scene=n) for n in scene_names}
        t0 = time.time()
        futs = [(n, engine.submit(cam, gt, scene=n))
                for _ in range(args.rounds)
                for n in scene_names
                for cam, gt in zip(cams, gts[n])]
        engine.flush()
        per_scene_psnr = {n: [] for n in scene_names}
        for n, f in futs:
            per_scene_psnr[n].append(f.result().psnr)
        multi_total = time.time() - t0
        n_served = len(futs)
        ms = engine.stats()
        per_scene = {}
        for n in scene_names:
            sc = ms["scenes"][n]
            # fps_render: views over render time attributed to this scene
            # WITHIN the multi-scene window (delta of the cumulative
            # per-scene counters taken across it); fps_wall: the scene's
            # share of the interleaved stream over shared wall-clock
            d_views = sc["views_served"] - pre[n]["views_served"]
            d_render = sc["render_s"] - pre[n]["render_s"]
            per_scene[n] = {
                "views": len(per_scene_psnr[n]),
                "fps_wall": len(per_scene_psnr[n]) / multi_total,
                "fps_render": d_views / max(d_render, 1e-9),
                "psnr_mean": float(np.mean(per_scene_psnr[n])),
                "latency_p50_s": sc["latency_p50_s"],
                "latency_p95_s": sc["latency_p95_s"],
                "latency_p99_s": sc["latency_p99_s"],
            }
        # the acceptance ratio: a scene's render-rate FPS (views / time
        # spent rendering THAT scene's flush groups) vs the single-scene
        # batched baseline — scene routing, per-scene snapshots, and
        # cross-scene flush grouping must not slow the renders themselves.
        # fps_wall is reported alongside: with N scenes fairly sharing
        # one engine it sits near baseline/N by construction.
        ratios = {n: per_scene[n]["fps_render"] / max(bat_fps, 1e-9)
                  for n in scene_names}
        multi = {
            "scenes": scene_names, "rounds": args.rounds,
            "views_total": n_served, "total_s": multi_total,
            "fps_total": n_served / multi_total,
            "per_scene": per_scene,
            "fps_render_per_scene_vs_single_ratio": ratios,
            "evictions": ms["evictions"], "revivals": ms["revivals"],
            "timeouts": ms["timeouts"],
        }
        report["multi_scene"] = multi

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    if args.check:
        failures = []
        if speedup < 1.5:
            failures.append(f"batched speedup {speedup:.2f}x < 1.5x")
        dp = float(np.mean(bat_psnr)) - float(np.mean(seq_psnr))
        if dp < -0.5:
            failures.append(f"batched psnr {np.mean(bat_psnr):.2f} more "
                            f"than 0.5 dB below sequential "
                            f"{np.mean(seq_psnr):.2f}")
        if es["dropped_pairs"] > 0 and es["pair_budget_resizes"] == 0:
            failures.append(f"{es['dropped_pairs']} ray-cube pairs dropped "
                            "and the adaptive budget never grew")
        if overhead_frac > 0.02:
            failures.append(
                f"instrumentation overhead {overhead_frac * 100:.1f}% "
                f"FPS >= 2% (traced {fps_traced:.3f} vs untraced "
                f"{fps_plain:.3f})")
        if repeat["hits_delta"] <= 0 or oc_post["hits"] <= 0:
            failures.append(
                f"repeated-view segment produced no ordering-cache hits "
                f"(hits_delta={repeat['hits_delta']}, "
                f"total hits={oc_post['hits']}) — schedule reuse is broken")
        if multi is not None:
            for n, ratio in \
                    multi["fps_render_per_scene_vs_single_ratio"].items():
                if ratio < 0.7:
                    failures.append(
                        f"scene '{n}' per-scene render-rate FPS ratio "
                        f"{ratio:.2f} < 0.7x the single-scene baseline")
        if failures:
            print("CHECK FAILED: " + "; ".join(failures))
            sys.exit(1)
        msg = (f"CHECK OK: {speedup:.2f}x FPS over the sequential loop at "
               f"PSNR parity ({np.mean(bat_psnr):.2f} vs "
               f"{np.mean(seq_psnr):.2f} dB); tracing overhead "
               f"{overhead_frac * 100:.1f}% FPS")
        if multi is not None:
            worst = min(
                multi["fps_render_per_scene_vs_single_ratio"].values())
            msg += (f"; {len(scene_names)} resident scenes at >= "
                    f"{worst:.2f}x per-scene render-rate FPS vs "
                    f"single-scene")
        print(msg)


if __name__ == "__main__":
    main()
