"""Paper Tab. 2: rendering quality (PSNR) — uniform/TensoRF baseline vs the
RT-NeRF pipeline, including the paper-faithful ball intersection (the
paper's reported -0.21 PSNR) and our box-clipped fix."""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK_SCENES, get_trained, row
from repro.core import train as nerf_train
from repro.data import rays as rays_lib

RES = 48


def main(scenes=QUICK_SCENES):
    deltas_ball, deltas_box = [], []
    for scene in scenes:
        cfg, params, cubes = get_trained(scene)
        sc = rays_lib.make_scene(scene)
        cam = rays_lib.make_cameras(9, RES, RES)[4]   # held-out-ish view
        gt = rays_lib.render_gt(sc, cam)
        p_u, _, _ = nerf_train.eval_view(params, cfg, cubes, cam, gt,
                                         pipeline="uniform")
        p_ball, _, _ = nerf_train.eval_view(params, cfg, cubes, cam, gt,
                                            pipeline="rtnerf",
                                            intersect="ball", chunk=8)
        p_box, _, _ = nerf_train.eval_view(params, cfg, cubes, cam, gt,
                                           pipeline="rtnerf",
                                           intersect="box", chunk=8)
        deltas_ball.append(p_ball - p_u)
        deltas_box.append(p_box - p_u)
        row(f"tab2_{scene}", 0.0,
            f"uniform={p_u:.2f};rtnerf_ball={p_ball:.2f};rtnerf_box={p_box:.2f}")
    row("tab2_avg_delta", 0.0,
        f"ball={np.mean(deltas_ball):+.2f};box={np.mean(deltas_box):+.2f};"
        f"paper_ball_delta=-0.21")


if __name__ == "__main__":
    main()
