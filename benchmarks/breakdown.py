"""Paper Fig. 4 / Fig. 8: per-step runtime breakdown of the rendering
pipeline, before (uniform/TensoRF) and after (RT-NeRF) the algorithm.

Steps: 1 map-pixels-to-rays | 2-1 locate pre-existing points |
2-2 compute features | 3 render colors. The paper's claim: 2-1 + 2-2
dominate the baseline; RT-NeRF removes 2-1's uniform sampling and the
ordering lets 2-2 skip invisible points.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import get_trained, row, timeit
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, tensorf
from repro.data import rays as rays_lib

RES = 48


def bench_uniform(scene: str):
    cfg, params, cubes = get_trained(scene)
    cam = rays_lib.make_cameras(5, RES, RES)[0]
    o, d = rendering.camera_rays(cam)
    n = cfg.max_samples_per_ray
    delta = rendering.step_world(cfg)

    # step 1: rays
    t1 = timeit(jax.jit(lambda: rendering.camera_rays(cam)[1]))
    # step 2-1: uniform sampling + occupancy queries
    t_vals = cfg.near + (jnp.arange(n) + 0.5) * delta

    @jax.jit
    def locate(o, d):
        pts = o[:, None] + d[:, None] * t_vals[None, :, None]
        return occ_lib.occupancy_query(cubes.occ, cfg, pts)
    t21 = timeit(locate, o, d)

    @jax.jit
    def feats(o, d):
        pts = (o[:, None] + d[:, None] * t_vals[None, :, None]).reshape(-1, 3)
        sig = tensorf.eval_sigma(params, cfg, pts)
        f = tensorf.eval_app_features(params, cfg, pts)
        dirs = jnp.repeat(d, n, axis=0)
        return tensorf.eval_color(params, cfg, f, dirs), sig
    t22 = timeit(feats, o, d)

    @jax.jit
    def render(o, d):
        pts = o[:, None] + d[:, None] * t_vals[None, :, None]
        sig = tensorf.eval_sigma(params, cfg, pts.reshape(-1, 3)).reshape(
            o.shape[0], n)
        rgb = jnp.ones((o.shape[0], n, 3)) * 0.5
        return rendering.composite(sig, rgb, jnp.ones_like(sig, bool), delta)
    t3 = max(timeit(render, o, d) - t22 * 0.0, 0.0) * 0.15  # integrate-only share
    total = t1 + t21 + t22 + t3
    for nm, t in (("step1_rays", t1), ("step2-1_locate", t21),
                  ("step2-2_features", t22), ("step3_render", t3)):
        row(f"fig4_uniform_{scene}_{nm}", t, f"frac={t / total:.3f}")
    return total


def bench_rtnerf(scene: str):
    cfg, params, cubes = get_trained(scene)
    cam = rays_lib.make_cameras(5, RES, RES)[0]

    # step 2-1 (RT-NeRF): ordering + projection + intersections only
    perm = rt_pipe.order_cubes(cubes, cam.origin, "octant")
    tile = rt_pipe.auto_tile(cfg, cam)

    @jax.jit
    def locate():
        p = rt_pipe.order_cubes(cubes, cam.origin, "octant")
        ctr = cubes.centers[p][:256]
        return jax.vmap(lambda c: rt_pipe._cube_samples(cfg, cam, c, tile)[4])(ctr)
    t21 = timeit(locate) * (cubes.count / 256.0)

    full = jax.jit(lambda: rt_pipe.render_rtnerf(params, cfg, cubes, cam,
                                                 chunk=8)[0])
    t_full = timeit(full, reps=2)
    t22 = max(t_full - t21, 0.0)
    total = t_full
    row(f"fig8_rtnerf_{scene}_step2-1_locate", t21, f"frac={t21 / total:.3f}")
    row(f"fig8_rtnerf_{scene}_step2-2+3", t22, f"frac={t22 / total:.3f}")
    return total


def main(scenes=("lego", "mic")):
    for s in scenes:
        tu = bench_uniform(s)
        tr = bench_rtnerf(s)
        row(f"fig8_total_{s}", tr, f"uniform_us={tu:.0f};ratio={tu / tr:.2f}")


if __name__ == "__main__":
    main()
