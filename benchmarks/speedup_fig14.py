"""Paper Fig. 14: relative speedup of the RT-NeRF pipeline.

We cannot reproduce ASIC-vs-GPU wall clocks; we reproduce the MECHANISM
ratios the speedups are built from, on identical hardware:
  * occupancy-structure accesses (paper: ~100x fewer)       [algorithmic]
  * points processed in Step 2-2 (sparsity + early-term)    [algorithmic]
  * CPU wall time per frame for both pipelines              [relative]
plus the sparse-kernel decode throughput vs dense matmul on the factor
matrices (the accelerator's Step 2-2 advantage, interpret-mode Pallas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_trained, row, timeit
from repro.core import rendering, sparse
from repro.core import train as nerf_train
from repro.data import rays as rays_lib

RES = 48


def main(scenes=("lego", "mic")):
    for scene in scenes:
        cfg, params, cubes = get_trained(scene)
        sc = rays_lib.make_scene(scene)
        cam = rays_lib.make_cameras(9, RES, RES)[4]
        gt = rays_lib.render_gt(sc, cam)

        t_u = timeit(lambda: nerf_train.eval_view(
            params, cfg, cubes, cam, gt, pipeline="uniform")[2], reps=2)
        t_r = timeit(lambda: nerf_train.eval_view(
            params, cfg, cubes, cam, gt, pipeline="rtnerf", chunk=8)[2],
            reps=2)
        _, s_u, _ = nerf_train.eval_view(params, cfg, cubes, cam, gt,
                                         pipeline="uniform")
        _, s_r, _ = nerf_train.eval_view(params, cfg, cubes, cam, gt,
                                         pipeline="rtnerf", chunk=8)
        ratio = s_u["occ_accesses"] / max(s_r["occ_accesses"], 1.0)
        row(f"fig14_{scene}_occ_access_ratio", 0.0,
            f"uniform={s_u['occ_accesses']:.0f};rtnerf={s_r['occ_accesses']:.0f};"
            f"ratio={ratio:.0f}x")
        row(f"fig14_{scene}_processed_points", 0.0,
            f"uniform={s_u['processed_samples']:.0f};"
            f"rtnerf={s_r['processed_samples']:.0f}")
        row(f"fig14_{scene}_frame_walltime", t_r,
            f"uniform_us={t_u:.0f};cpu_ratio={t_u / t_r:.2f}x")

    # sparse decode vs dense matmul on a pruned factor (Step 2-2 engine)
    cfg, params, cubes = get_trained(scenes[0])
    w = np.asarray(params["app_planes"])[0].reshape(
        params["app_planes"].shape[1], -1)
    s = sparse.sparsity(w)
    enc = sparse.encode_bitmap(w)
    x = jnp.asarray(np.random.RandomState(0).randn(w.shape[1], 8),
                    jnp.float32)
    from repro.kernels import ops
    t_dense = timeit(jax.jit(lambda a, b: a @ b), jnp.asarray(w), x)
    t_ref = timeit(lambda: ops.bitmap_matmul(enc.words, enc.rowptr,
                                             enc.values, x,
                                             cols=w.shape[1], force="ref"))
    dense_b = sparse.storage_bytes(w.shape, enc.nnz, "dense")
    bm_b = sparse.storage_bytes(w.shape, enc.nnz, "bitmap")
    row("fig14_bitmap_decode_matmul", t_ref,
        f"dense_us={t_dense:.0f};sparsity={s:.2f};"
        f"hbm_bytes_ratio={dense_b / bm_b:.2f}x")


if __name__ == "__main__":
    main()
