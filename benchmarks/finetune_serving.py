"""Serving under online fine-tuning: swap latency, FPS while training, and
PSNR vs wall-clock (the train->serve loop, serving/finetune.py).

One RenderEngine serves a continuous view stream through its background
flush thread while a FineTuneLoop trains on a second thread and publishes
refreshed hybrid-encoded fields via `swap_field`. Measured:

  * swap latency     — engine-lock hold time per publication (the stall a
                       producer could observe). The claim under test: a
                       swap costs less than one flush interval, i.e. field
                       refreshes hide inside the serving cadence — no
                       recompilation stalls (cf. Re-ReND's cross-device
                       constraint), because the jitted step takes the field
                       as a pytree argument.
  * FPS during training — served-view throughput while the trainer
                       competes for the host (vs an idle-trainer baseline).
  * PSNR vs wall-clock — served (not train-batch) PSNR timeline, showing
                       quality climbing across swaps.

    PYTHONPATH=src python benchmarks/finetune_serving.py
    PYTHONPATH=src python benchmarks/finetune_serving.py --tiny --check

Emits BENCH_finetune.json, including the trace-derived per-stage latency
table (`stages`) and the fine-tuner's full publication-cost histogram
(`finetune_publish_s`: snapshot + occupancy rebuild + swap) from the
shared metrics registry. --check exits non-zero unless max swap latency
< one flush interval, every future resolved (zero timeouts/drops), >= 2
swaps landed, and PSNR improved from the first swap epoch to the last.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import os

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import steady_state  # noqa: E402

from repro.configs.rtnerf import demo_config
from repro.core import train as nerf_train
from repro.data import rays as rays_lib
from repro.serving import FineTuneLoop, RenderEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--res", type=int, default=48)
    ap.add_argument("--warmup-steps", type=int, default=5)
    ap.add_argument("--finetune-steps", type=int, default=200)
    ap.add_argument("--publish-every", type=int, default=40)
    ap.add_argument("--flush-interval", type=float, default=0.25)
    ap.add_argument("--out", default="BENCH_finetune.json")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: small field, 60 steps, 24^2")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless swaps hide inside one flush "
                         "interval, nothing timed out, and PSNR improved")
    args = ap.parse_args()

    if args.tiny:
        args.res = min(args.res, 24)
        args.finetune_steps, args.publish_every = 60, 15
    cfg = demo_config(tiny=args.tiny)

    res = nerf_train.train_nerf(cfg, args.scene, steps=args.warmup_steps,
                                n_views=8, image_hw=args.res, verbose=False)
    engine = RenderEngine(cfg, res.field, res.cubes,
                          ray_chunk=args.res * args.res, max_batch_views=4,
                          auto_flush_interval=args.flush_interval)
    scene = rays_lib.make_scene(args.scene)
    cams = rays_lib.make_cameras(6, args.res, args.res)
    gts = [rays_lib.render_gt(scene, c) for c in cams]

    # warm the compiled step so the streamed FPS is steady-state; the
    # shared methodology (common.steady_state) records the compile pass
    # separately — every BENCH family excludes compile time the same way
    warm_s, compile_s, _ = steady_state(
        lambda: engine.render_views(cams[:1], gts[:1]), iters=1)

    loop = FineTuneLoop(engine, args.scene, steps=args.finetune_steps,
                        publish_every=args.publish_every, n_views=8,
                        image_hw=args.res)
    timeline = []                          # (t_wall, psnr, swaps_seen)
    stream_errs = []
    t0 = time.perf_counter()

    def stream():
        try:
            i = 0
            while loop.running():
                r = engine.submit(cams[i % len(cams)],
                                  gts[i % len(cams)]).result(timeout=600)
                timeline.append((time.perf_counter() - t0, r.psnr,
                                 engine.stats()["field_swaps"], r.timed_out))
                i += 1
        except BaseException as e:   # a dead consumer must fail the gate
            stream_errs.append(e)

    loop.start()
    consumer = threading.Thread(target=stream)
    consumer.start()
    loop.join()
    consumer.join()
    serve_wall = time.perf_counter() - t0
    engine.close()
    if stream_errs:
        raise stream_errs[0]

    s = engine.stats()
    swap_lat = [sw["swap_s"] for sw in loop.swaps]
    by_epoch = {}
    for _, p, sw, _ in timeline:
        by_epoch.setdefault(sw, []).append(p)
    epochs = sorted(by_epoch)
    psnr_first = float(np.mean(by_epoch[epochs[0]]))
    psnr_last = float(np.mean(by_epoch[epochs[-1]]))
    report = {
        "scene": args.scene, "res": args.res,
        "finetune_steps": args.finetune_steps,
        "publish_every": args.publish_every,
        "flush_interval_s": args.flush_interval,
        "swaps": len(loop.swaps),
        "swap_latency_s_max": max(swap_lat) if swap_lat else 0.0,
        "swap_latency_s_mean": float(np.mean(swap_lat)) if swap_lat else 0.0,
        "engine_swap_latency_s_max": s["swap_latency_s_max"],
        "fps_during_training": len(timeline) / max(serve_wall, 1e-9),
        "compile_s": compile_s,
        "warm_view_s": warm_s,
        "views_served": len(timeline),
        "timeouts": s["timeouts"],
        "latency_p50_s": s["latency_p50_s"],
        "latency_p95_s": s["latency_p95_s"],
        "latency_p99_s": s["latency_p99_s"],
        # trace-derived per-stage latency table: where a served view's time
        # went (queue/group/ordering/compaction/render/deliver) while the
        # trainer competed for the host
        "stages": engine.stage_breakdown(),
        "finetune_publish_s": engine.metrics.histogram(
            "finetune_publish_s", scene=loop.scene).snapshot(),
        "psnr_epoch_first": psnr_first,
        "psnr_epoch_last": psnr_last,
        "psnr_vs_wall_clock": [
            {"t_s": round(t, 3), "psnr": round(float(p), 3),
             "swaps_seen": int(sw)} for t, p, sw, _ in timeline],
        "train_psnr_at_swap": [
            {"step": sw["step"], "train_psnr": round(sw["train_psnr"], 3),
             "t_s": round(sw["t_wall"], 3)} for sw in loop.swaps],
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps({k: v for k, v in report.items()
                      if k != "psnr_vs_wall_clock"}, indent=2))

    if args.check:
        failures = []
        if report["swap_latency_s_max"] >= args.flush_interval:
            failures.append(
                f"max swap latency {report['swap_latency_s_max'] * 1e3:.1f}"
                f"ms >= flush interval {args.flush_interval * 1e3:.0f}ms — "
                f"swaps no longer hide inside the serving cadence")
        if s["timeouts"] or any(to for *_, to in timeline):
            failures.append(f"{s['timeouts']} futures timed out under swap")
        if len(loop.swaps) < 2:
            failures.append(f"only {len(loop.swaps)} swaps landed (< 2)")
        if psnr_last <= psnr_first:
            failures.append(f"served PSNR did not improve "
                            f"({psnr_first:.2f} -> {psnr_last:.2f} dB)")
        if failures:
            print("CHECK FAILED: " + "; ".join(failures))
            sys.exit(1)
        print(f"CHECK OK: {len(loop.swaps)} swaps, max "
              f"{report['swap_latency_s_max'] * 1e3:.1f}ms < "
              f"{args.flush_interval * 1e3:.0f}ms flush interval, PSNR "
              f"{psnr_first:.2f} -> {psnr_last:.2f} dB, 0 drops")


if __name__ == "__main__":
    main()
