"""Roofline reporter: reads experiments/dryrun/*.json (written by
launch/dryrun.py) and prints the per-(arch x shape x mesh) three-term table,
dominant bottleneck, MODEL_FLOPS ratio, and the hillclimb-cell selection."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh="pod"):
    cells = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        cells.append(json.load(open(f)))
    return cells


def main():
    cells = load_cells("pod")
    if not cells:
        row("roofline_missing", 0.0,
            "run: python -m repro.launch.dryrun --all")
        return
    worst = None
    most_coll = None
    for d in cells:
        key = f"roofline_{d['arch']}_{d['shape']}"
        if d["status"] == "skip":
            row(key, 0.0, f"SKIP:{d['skip_reason'][:40]}")
            continue
        if d["status"] != "ok":
            row(key, 0.0, f"STATUS={d['status']}")
            continue
        r = d["roofline"]
        peak = d.get("memory_analysis", {}).get("peak_bytes", 0) / 1e9
        useful = r["useful_flops_ratio"]
        row(key, r["bound_s"] * 1e6,
            f"dom={r['dominant']};compute_s={r['compute_s']:.3g};"
            f"memory_s={r['memory_s']:.3g};collective_s={r['collective_s']:.3g};"
            f"useful_ratio={useful:.2f};peak_gb={peak:.1f}")
        frac = r["compute_s"] / max(r["bound_s"], 1e-12)
        if worst is None or frac < worst[1]:
            worst = (key, frac)
        cf = r["collective_s"] / max(r["bound_s"], 1e-12)
        if most_coll is None or cf > most_coll[1]:
            most_coll = (key, cf)
    row("roofline_worst_fraction_cell", 0.0, f"{worst[0]};frac={worst[1]:.4f}")
    row("roofline_most_collective_cell", 0.0,
        f"{most_coll[0]};coll_share={most_coll[1]:.3f}")
    n_multi = len([d for d in load_cells("multipod") if d["status"] == "ok"])
    row("roofline_multipod_cells_ok", 0.0, f"n={n_multi}")


if __name__ == "__main__":
    main()
