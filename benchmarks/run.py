"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. CPU wall-clock is a relative
signal; paper-mechanism counters and dry-run roofline terms carry the
absolute claims (see EXPERIMENTS.md).

    PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 8 scenes (slow); default: 4-scene quick mode")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (breakdown, encoding_table, psnr_table2, roofline,
                            sparsity_fig5, speedup_fig14)
    from benchmarks.common import ALL_SCENES, QUICK_SCENES

    scenes = ALL_SCENES if args.full else QUICK_SCENES
    suites = [
        ("fig4_fig8_breakdown", lambda: breakdown.main(scenes[:2])),
        ("fig5_sparsity", lambda: sparsity_fig5.main(scenes)),
        ("tab2_psnr", lambda: psnr_table2.main(scenes)),
        ("fig14_speedup", lambda: speedup_fig14.main(scenes[:2])),
        ("enc_storage", lambda: encoding_table.main(scenes)),
        ("roofline", roofline.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"suite_{name},{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"suite_{name},0,FAILED", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
