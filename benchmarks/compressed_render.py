"""Dense vs hybrid (bitmap/COO) compressed-field rendering (paper Sec. 4.2.2)
plus the prune-level vs scene-PSNR trade-off sweep (ROADMAP quality/size
curve).

Trains a small TensoRF field (compressed-native, core/train.py), magnitude-
prunes it to several sparsity levels, and for each level renders the same
novel view through the RT-NeRF pipeline twice — once from the raw factor
arrays (`FieldBackend.decode()`), once straight from the hybrid encoding —
reporting the factor bytes the hot loop reads (sparse.storage_bytes size
model), wall-clock, hybrid-vs-dense parity PSNR, AND the scene PSNR against
ground truth per prune level (the quality/size trade-off curve). The whole
sweep is written to BENCH_compressed.json for the cross-PR trajectory.

    PYTHONPATH=src python benchmarks/compressed_render.py
    PYTHONPATH=src python benchmarks/compressed_render.py --tiny --check  # CI

CPU wall-clock is a relative signal only (TPU is the compile target; the
CPU hybrid path decodes via the jnp oracles) — the paper-claim column is
factor_bytes, the DRAM-traffic proxy.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax.numpy as jnp

from repro.configs.rtnerf import NeRFConfig
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering
from repro.core import train as nerf_train
from repro.data import rays as rays_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--res", type=int, default=56)
    ap.add_argument("--levels", default="0.5,0.8,0.9,0.95")
    ap.add_argument("--out", default="BENCH_compressed.json")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: 20 steps, 32^2 render, one level")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the paper-claim row holds "
                         "(>=3x bytes at 0.9 sparsity, PSNR >= 40 dB)")
    args = ap.parse_args()
    if args.tiny:
        args.steps, args.res, args.levels = 20, 32, "0.9"
    levels = [float(x) for x in args.levels.split(",")]

    if args.tiny:
        cfg = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=320,
                         r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                         max_samples_per_ray=64, train_rays=512)
    else:
        cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                         r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                         max_samples_per_ray=112, train_rays=1024)
    res = nerf_train.train_nerf(cfg, args.scene, steps=args.steps, n_views=8,
                                image_hw=args.res, log_every=10_000,
                                verbose=False)
    scene = rays_lib.make_scene(args.scene)
    cam = rays_lib.make_cameras(7, args.res, args.res)[2]
    gt = rays_lib.render_gt(scene, cam)

    if args.check and not any(lv >= 0.9 for lv in levels):
        print("CHECK FAILED: --check needs at least one level >= 0.9 "
              f"(got {args.levels})")
        sys.exit(2)

    print("sparsity,dense_bytes,hybrid_bytes,ratio,psnr_hybrid_vs_dense,"
          "psnr_scene,dense_s,hybrid_s,formats")
    failures = []
    rows = []
    for level in levels:
        # the trade-off curve point: prune the trained field to `level`
        # (re-encoded internally), rebuild occupancy at the shared cutoff
        cf = res.field.prune(sparsity=level)
        dense = cf.decode()
        occ = occ_lib.build_occupancy(cf, cfg)
        cubes = occ_lib.extract_cubes(occ, cfg)

        t0 = time.time()
        img_d, st_d = rt_pipe.render_rtnerf(dense, cfg, cubes, cam, chunk=8)
        img_d.block_until_ready()
        dt_d = time.time() - t0
        t0 = time.time()
        img_h, st_h = rt_pipe.render_rtnerf(cf, cfg, cubes, cam, chunk=8)
        img_h.block_until_ready()
        dt_h = time.time() - t0

        bytes_d = int(st_d["factor_bytes"])
        bytes_h = int(st_h["factor_bytes"])
        ratio = bytes_d / max(bytes_h, 1)
        psnr = float(rendering.psnr(jnp.clip(img_h, 0, 1),
                                    jnp.clip(img_d, 0, 1)))
        psnr_scene = float(rendering.psnr(jnp.clip(img_h, 0, 1), gt))
        fmts = sorted({v["format"] for v in cf.sparsity_report().values()})
        print(f"{level:.2f},{bytes_d},{bytes_h},{ratio:.2f},{psnr:.1f},"
              f"{psnr_scene:.2f},{dt_d:.2f},{dt_h:.2f},{'|'.join(fmts)}")
        rows.append({
            "sparsity": level, "dense_bytes": bytes_d,
            "hybrid_bytes": bytes_h, "ratio": ratio,
            "psnr_hybrid_vs_dense": psnr, "psnr_scene": psnr_scene,
            "dense_s": dt_d, "hybrid_s": dt_h, "formats": fmts,
            "n_cubes": cubes.count,
        })
        if level >= 0.9:
            if ratio < 3.0:
                failures.append(f"ratio {ratio:.2f} < 3x at {level}")
            if psnr < 40.0:
                failures.append(f"psnr {psnr:.1f} < 40 dB at {level}")

    report = {
        "scene": args.scene, "steps": args.steps, "res": args.res,
        "train_field_kind": res.field.kind,
        # the quality/size trade-off curve (ROADMAP sweep item): one row
        # per prune level, scene PSNR against GT alongside the byte ratio
        "sweep": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} sweep rows)")

    if args.check and failures:
        print("CHECK FAILED: " + "; ".join(failures))
        sys.exit(1)
    if args.check:
        print("CHECK OK: >=3x factor-byte reduction at >=0.9 sparsity, "
              "hybrid-vs-dense PSNR >= 40 dB")


if __name__ == "__main__":
    main()
