"""Dense vs hybrid (bitmap/COO) compressed-field rendering (paper Sec. 4.2.2)
plus the prune-level vs scene-PSNR trade-off sweep across ALL scenes
(ROADMAP quality/size curve, aggregated — not one scene per run).

For every scene in `benchmarks.common.ALL_SCENES` (or --scenes): train a
small TensoRF field (compressed-native, core/train.py), magnitude-prune it
to several sparsity levels, and for each level render the same novel view
through the RT-NeRF pipeline twice — once from the raw factor arrays
(`FieldBackend.decode()`), once straight from the hybrid encoding —
reporting the factor bytes the hot loop reads (sparse.storage_bytes size
model), wall-clock, hybrid-vs-dense parity PSNR, AND the scene PSNR against
ground truth per prune level. BENCH_compressed.json gets the per-scene
sweep tables plus the cross-scene aggregate (mean/min scene PSNR and mean
byte ratio per prune level) for the cross-PR trajectory.

    PYTHONPATH=src python benchmarks/compressed_render.py             # all scenes
    PYTHONPATH=src python benchmarks/compressed_render.py --scenes lego,mic
    PYTHONPATH=src python benchmarks/compressed_render.py --tiny --check  # CI

Timing methodology (docs/benchmarks.md): the render is jitted once per
(field structure, cube set), the first call is recorded separately as
`*_compile_s`, and `dense_s` / `hybrid_s` are best-of-`--iters`
steady-state wall-clocks — the serving-relevant number (the engine
compiles once and serves many frames). Each row also records which
dispatch path the hybrid eval actually took (`path_hybrid`: fused /
fused_ref / per-op, from `FieldBackend.dispatch_path()`) so cross-PR bench
trajectories are apples-to-apples. The paper-claim column for memory is
factor_bytes, the DRAM-traffic proxy.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import ALL_SCENES, steady_state  # noqa: E402

from repro.configs.rtnerf import NeRFConfig  # noqa: E402
from repro.core import occupancy as occ_lib  # noqa: E402
from repro.core import pipeline as rt_pipe  # noqa: E402
from repro.core import rendering  # noqa: E402
from repro.core import train as nerf_train  # noqa: E402
from repro.data import rays as rays_lib  # noqa: E402


def timed_render(field, cfg: NeRFConfig, cubes, cam, *, iters: int):
    """(img, steady_s, compile_s): jit the full-view render with the field
    as the only argument (same trace-once-serve-many shape the serving
    engine uses); timing via the shared best-of-iters methodology
    (`common.steady_state` — compile paid and recorded on the first
    call)."""
    run = jax.jit(lambda f: rt_pipe.render_rtnerf(f, cfg, cubes, cam,
                                                  chunk=8)[0])
    best, compile_s, img = steady_state(lambda: run(field), iters=iters)
    return img, best, compile_s


def sweep_scene(cfg: NeRFConfig, scene_name: str, levels, steps: int,
                res: int, check: bool, iters: int):
    """One scene's prune-level curve -> (rows, failures)."""
    tr = nerf_train.train_nerf(cfg, scene_name, steps=steps, n_views=8,
                               image_hw=res, log_every=10_000,
                               verbose=False)
    scene = rays_lib.make_scene(scene_name)
    cam = rays_lib.make_cameras(7, res, res)[2]
    gt = rays_lib.render_gt(scene, cam)

    rows, failures = [], []
    for level in levels:
        # the trade-off curve point: prune the trained field to `level`
        # (re-encoded internally), rebuild occupancy at the shared cutoff
        cf = tr.field.prune(sparsity=level)
        dense = cf.decode()
        occ = occ_lib.build_occupancy(cf, cfg)
        cubes = occ_lib.extract_cubes(occ, cfg)

        img_d, dt_d, comp_d = timed_render(dense, cfg, cubes, cam,
                                           iters=iters)
        img_h, dt_h, comp_h = timed_render(cf, cfg, cubes, cam, iters=iters)
        path_h = cf.dispatch_path()

        bytes_d = dense.factor_bytes()
        bytes_h = cf.factor_bytes()
        ratio = bytes_d / max(bytes_h, 1)
        psnr = float(rendering.psnr(jnp.clip(img_h, 0, 1),
                                    jnp.clip(img_d, 0, 1)))
        psnr_scene = float(rendering.psnr(jnp.clip(img_h, 0, 1), gt))
        fmts = sorted({v["format"] for v in cf.sparsity_report().values()})
        print(f"{scene_name},{level:.2f},{bytes_d},{bytes_h},{ratio:.2f},"
              f"{psnr:.1f},{psnr_scene:.2f},{dt_d:.3f},{dt_h:.3f},"
              f"{path_h},{'|'.join(fmts)}", flush=True)
        rows.append({
            "sparsity": level, "dense_bytes": bytes_d,
            "hybrid_bytes": bytes_h, "ratio": ratio,
            "psnr_hybrid_vs_dense": psnr, "psnr_scene": psnr_scene,
            "dense_s": dt_d, "hybrid_s": dt_h,
            "dense_compile_s": comp_d, "hybrid_compile_s": comp_h,
            "path_dense": dense.dispatch_path(), "path_hybrid": path_h,
            "formats": fmts, "n_cubes": cubes.count,
        })
        if check and level >= 0.9:
            if ratio < 3.0:
                failures.append(
                    f"{scene_name}: ratio {ratio:.2f} < 3x at {level}")
            if psnr < 40.0:
                failures.append(
                    f"{scene_name}: psnr {psnr:.1f} < 40 dB at {level}")
            if dt_h > dt_d:
                failures.append(
                    f"{scene_name}: hybrid_s {dt_h:.3f} > dense_s "
                    f"{dt_d:.3f} at {level} (path={path_h})")
    return rows, failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenes", default="all",
                    help="comma-separated scene list, or 'all' for the "
                         "shared ALL_SCENES set")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--res", type=int, default=56)
    ap.add_argument("--levels", default="0.5,0.8,0.9,0.95")
    ap.add_argument("--iters", type=int, default=3,
                    help="steady-state timing iterations per render "
                         "(best-of; compile time is recorded separately)")
    ap.add_argument("--out", default="BENCH_compressed.json")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: 20 steps, 32^2 render, one "
                         "level, two scenes")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the paper-claim row holds "
                         "for EVERY swept scene (>=3x bytes at 0.9 "
                         "sparsity, PSNR >= 40 dB, steady-state "
                         "hybrid_s <= dense_s)")
    args = ap.parse_args()
    if args.tiny:
        args.steps, args.res, args.levels = 20, 32, "0.9"
        if args.scenes == "all":
            args.scenes = "lego,mic"
    scenes = ALL_SCENES if args.scenes == "all" \
        else tuple(s for s in args.scenes.split(",") if s)
    levels = [float(x) for x in args.levels.split(",")]

    if args.tiny:
        cfg = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=320,
                         r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                         max_samples_per_ray=64, train_rays=512)
    else:
        cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                         r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                         max_samples_per_ray=112, train_rays=1024)

    if args.check and not any(lv >= 0.9 for lv in levels):
        print("CHECK FAILED: --check needs at least one level >= 0.9 "
              f"(got {args.levels})")
        sys.exit(2)

    print("scene,sparsity,dense_bytes,hybrid_bytes,ratio,"
          "psnr_hybrid_vs_dense,psnr_scene,dense_s,hybrid_s,path,formats")
    failures = []
    per_scene = {}
    for name in scenes:
        rows, fails = sweep_scene(cfg, name, levels, args.steps, args.res,
                                  args.check, args.iters)
        per_scene[name] = rows
        failures.extend(fails)

    # cross-scene aggregate: one row per prune level (ROADMAP "aggregate
    # across ALL_SCENES" — min PSNR names the worst scene, the one a
    # quality budget must be set against)
    aggregate = []
    for i, level in enumerate(levels):
        at = {name: per_scene[name][i] for name in scenes}
        worst = min(at, key=lambda n: at[n]["psnr_scene"])
        aggregate.append({
            "sparsity": level,
            "psnr_scene_mean": sum(r["psnr_scene"] for r in at.values())
            / len(at),
            "psnr_scene_min": at[worst]["psnr_scene"],
            "psnr_scene_min_scene": worst,
            "psnr_hybrid_vs_dense_mean": sum(
                r["psnr_hybrid_vs_dense"] for r in at.values()) / len(at),
            "ratio_mean": sum(r["ratio"] for r in at.values()) / len(at),
            "hybrid_over_dense_s_mean": sum(
                r["hybrid_s"] / max(r["dense_s"], 1e-9)
                for r in at.values()) / len(at),
            "paths": sorted({r["path_hybrid"] for r in at.values()}),
        })
    print("level,psnr_scene_mean,psnr_scene_min(worst),ratio_mean")
    for a in aggregate:
        print(f"{a['sparsity']:.2f},{a['psnr_scene_mean']:.2f},"
              f"{a['psnr_scene_min']:.2f}({a['psnr_scene_min_scene']}),"
              f"{a['ratio_mean']:.2f}")

    report = {
        "scenes": list(scenes), "steps": args.steps, "res": args.res,
        "levels": levels,
        # per-scene quality/size trade-off curves + the cross-scene
        # aggregate table (one row per prune level)
        "sweep": per_scene,
        "aggregate": aggregate,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out} ({len(scenes)} scenes x {len(levels)} levels)")

    if args.check and failures:
        print("CHECK FAILED: " + "; ".join(failures))
        sys.exit(1)
    if args.check:
        print(f"CHECK OK across {len(scenes)} scenes: >=3x factor-byte "
              "reduction at >=0.9 sparsity, hybrid-vs-dense PSNR >= 40 dB, "
              "steady-state hybrid_s <= dense_s")


if __name__ == "__main__":
    main()
