"""Shared benchmark infrastructure: trained-field cache + timers.

CPU wall-clock here is a *relative* signal (TPU is the compile target);
paper-claim benchmarks therefore report algorithmic counters (occupancy
accesses, processed points, bytes) alongside time.
"""
from __future__ import annotations

import os
import pickle
import time
from typing import Tuple

import jax
import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.core import train as nerf_train

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "cache")

BENCH_CFG = NeRFConfig(grid_res=48, occ_res=48, cube_size=4, max_cubes=1024,
                       r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                       max_samples_per_ray=128, train_rays=1024)

QUICK_SCENES = ("lego", "mic", "chair", "materials")
ALL_SCENES = ("chair", "drums", "ficus", "hotdog", "lego", "materials",
              "mic", "ship")


def get_trained(scene: str, steps: int = 250, image_hw: int = 56):
    """Train (or load cached) small field for `scene`."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"{scene}_{steps}_{image_hw}.pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            params, cubes_data = pickle.load(f)
        params = jax.tree.map(jax.numpy.asarray, params)
        from repro.core.occupancy import CubeSet
        cubes = CubeSet(jax.numpy.asarray(cubes_data[0]),
                        jax.numpy.asarray(cubes_data[1]), cubes_data[2],
                        cubes_data[3], jax.numpy.asarray(cubes_data[4]))
        return BENCH_CFG, params, cubes
    # occupancy rebuilds read BENCH_CFG.occ_sigma_thresh (thin scenes like
    # mic need the low cutoff); the dense params cache keeps the older
    # table benchmarks (encoding_table, psnr_table2, ...) dict-based
    res = nerf_train.train_nerf(BENCH_CFG, scene, steps=steps, n_views=8,
                                image_hw=image_hw, log_every=10_000,
                                verbose=False)
    params = res.field.decode().params
    with open(path, "wb") as f:
        pickle.dump((jax.tree.map(np.asarray, params),
                     (np.asarray(res.cubes.centers),
                      np.asarray(res.cubes.valid), res.cubes.count,
                      res.cubes.radius, np.asarray(res.cubes.occ))), f)
    return BENCH_CFG, params, res.cubes


def steady_state(fn, *, iters: int = 3) -> Tuple[float, float, object]:
    """Best-of-`iters` steady-state wall-clock for a zero-arg pass.

    The shared timing methodology of every BENCH family
    (docs/benchmarks.md): call `fn` once first — that call pays jit
    compilation / cache warmup and is reported separately as `compile_s` —
    then report the best of `iters` further calls as the steady-state
    time. Blocks on jax arrays in the output (pytree-aware; host-side
    outputs pass through). Returns (best_s, compile_s, last_out).
    """
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    best = float("inf")
    for _ in range(max(int(iters), 1)):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, compile_s, out


def timeit(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
