"""Paper Fig. 5: sparsity of the VM factors (density/appearance planes and
lines) across scenes — the imbalanced, scene-dependent pattern that
motivates the hybrid encoding."""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK_SCENES, get_trained, row
from repro.core import sparse


def main(scenes=QUICK_SCENES):
    names = ("sigma_planes", "sigma_lines", "app_planes", "app_lines")
    spread = []
    for scene in scenes:
        cfg, params, cubes = get_trained(scene)
        for k in names:
            w = np.asarray(params[k])
            for m in range(3):
                s = sparse.sparsity(w[m])
                spread.append(s)
                row(f"fig5_{scene}_{k}[{m}]", 0.0,
                    f"sparsity={s:.3f};format={sparse.choose_format(s)}")
    row("fig5_sparsity_range", 0.0,
        f"min={min(spread):.3f};max={max(spread):.3f}")


if __name__ == "__main__":
    main()
