"""Frame-coherent streaming on a synthetic camera path: the temporal tier
(serving/temporal.py + RenderEngine.submit_delta) vs full re-rendering.

Real AR/VR traffic is a head-tracked video stream — consecutive cameras
nearly identical. This benchmark renders a smooth orbit path twice with
the same engine:

  * full pass      — every frame through `submit` (the stateless path:
                     every ray of every frame rendered),
  * delta pass     — a keyframe every `--keyframe-every` frames through
                     `submit` (prev=None), every other frame through
                     `submit_delta(cam, prev=<previous result>)`: the
                     previous frame's radiance is forward-warped to the
                     new camera and only the low-confidence rays render.

Both passes are frame-by-frame (submit -> result per frame — a stream
cannot batch future cameras) and use the shared best-of-iters
steady-state methodology (`benchmarks.common.steady_state`; the
warmup/compile pass is recorded separately), over an engine in
trajectory ordering mode so quantised-pose keys + NN fallback reuse the
`order_cubes` schedules along the path.

Emits BENCH_trajectory.json: effective FPS for both passes and their
ratio, per-frame warp fraction, per-stage wall-clock from the PR 7
tracer (warp/mask/render/composite among them), PSNR tables (each pass
vs ground truth, delta vs full per frame) and the mean PSNR drift.
--check gates the temporal tier's contract:

  * delta-path effective FPS >= 2x the full-render pass,
  * mean PSNR drift (full-vs-gt minus delta-vs-gt) <= 0.5 dB,
  * keyframes bit-identical to `submit` renders of the same cameras.

    PYTHONPATH=src python benchmarks/trajectory_serving.py
    PYTHONPATH=src python benchmarks/trajectory_serving.py --tiny --check
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import steady_state  # noqa: E402

from repro.configs.rtnerf import NeRFConfig  # noqa: E402
from repro.core import occupancy as occ_lib  # noqa: E402
from repro.core import train as nerf_train  # noqa: E402
from repro.core.rendering import look_at_camera  # noqa: E402
from repro.data import rays as rays_lib  # noqa: E402
from repro.serving import RenderEngine  # noqa: E402


def path_cams(n: int, res: int, *, radius: float = 4.0,
              elevation: float = 0.5, step: float = 0.04):
    """A smooth orbit segment: `step` radians of azimuth per frame at the
    training orbit's radius/elevation (same look-at/focal convention as
    data.rays.make_cameras, so gt renders are comparable)."""
    cams = []
    for i in range(n):
        a = step * i
        o = np.array([radius * np.cos(a) * np.cos(elevation),
                      radius * np.sin(a) * np.cos(elevation),
                      radius * np.sin(elevation)], np.float32)
        cams.append(look_at_camera(o, [0, 0, 0], 1.2 * res, res, res))
    return cams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--res", type=int, default=48)
    ap.add_argument("--frames", type=int, default=24)
    ap.add_argument("--keyframe-every", type=int, default=8)
    ap.add_argument("--step-rad", type=float, default=0.04,
                    help="azimuth step per frame along the orbit")
    ap.add_argument("--prune", type=float, default=0.9)
    ap.add_argument("--iters", type=int, default=2,
                    help="steady-state timing iterations per pass "
                         "(best-of; compile recorded separately)")
    ap.add_argument("--out", default="BENCH_trajectory.json")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: 20 steps, 32^2, 16 frames")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless delta-path effective FPS "
                         ">= 2x full renders at <= 0.5 dB mean PSNR "
                         "drift, keyframes bit-identical to submit")
    args = ap.parse_args()
    if args.tiny:
        args.steps, args.res, args.frames = 20, 32, 16

    if args.tiny:
        cfg = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=320,
                         r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                         max_samples_per_ray=64, train_rays=512)
    else:
        cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                         r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                         max_samples_per_ray=112, train_rays=1024)

    res_t = nerf_train.train_nerf(cfg, args.scene, steps=args.steps,
                                  n_views=8, image_hw=args.res,
                                  log_every=10_000, verbose=False)
    field = res_t.field.prune(sparsity=args.prune)
    occ = occ_lib.build_occupancy(field, cfg)
    cubes = occ_lib.extract_cubes(occ, cfg)

    cams = path_cams(args.frames, args.res, step=args.step_rad)
    scene = rays_lib.make_scene(args.scene)
    gts = [np.asarray(rays_lib.render_gt(scene, c)) for c in cams]

    # one full frame is a handful of chunks, a delta frame ideally one —
    # on CPU the jitted step cost is per *chunk*, so the chunk size IS the
    # delta-ray granularity knob. adaptive_pair_budget off: a mid-pass
    # budget resize rebuilds the jitted step and would break the
    # keyframe-bit-identity contract between passes.
    ray_chunk = max(args.res * args.res // 4, 64)
    engine = RenderEngine(cfg, field, cubes, scene_name=args.scene,
                          ray_chunk=ray_chunk,
                          delta_ray_bucket=max(ray_chunk // 4, 32),
                          order_mode="trajectory",
                          adaptive_pair_budget=False,
                          max_batch_views=10 ** 9)   # stream: explicit flush

    def full_pass():
        return [engine.submit(c).result() for c in cams]

    def delta_pass():
        out, prev = [], None
        for i, c in enumerate(cams):
            if i % args.keyframe_every == 0:
                r = engine.submit_delta(c, prev=None).result()  # keyframe
            else:
                r = engine.submit_delta(c, prev=prev).result()
            out.append(r)
            prev = r
        return out

    # warm: compile the jitted step and populate the trajectory ordering
    # cache over the whole path, so BOTH timed passes run against the same
    # steady cache state (a pose that NN-hits a neighbour's schedule does
    # so identically in either pass — keyframe bit-identity depends on it)
    full_s, full_compile, full_out = steady_state(full_pass,
                                                  iters=args.iters)
    delta_s, delta_compile, delta_out = steady_state(delta_pass,
                                                     iters=args.iters)
    fps_full = args.frames / full_s
    fps_delta = args.frames / delta_s
    ratio = fps_delta / max(fps_full, 1e-9)

    # quality: both passes vs gt; drift = how much the temporal tier loses
    def p(img, ref):
        mse = float(np.mean((np.clip(np.asarray(img), 0, 1)
                             - np.asarray(ref)) ** 2))
        return -10.0 * np.log10(max(mse, 1e-10))

    psnr_full = [p(r.img, g) for r, g in zip(full_out, gts)]
    psnr_delta = [p(r.img, g) for r, g in zip(delta_out, gts)]
    psnr_delta_vs_full = [p(d.img, np.clip(np.asarray(f.img), 0, 1))
                          for d, f in zip(delta_out, full_out)]
    drift = float(np.mean(np.asarray(psnr_full) - np.asarray(psnr_delta)))

    key_ids = list(range(0, args.frames, args.keyframe_every))
    keyframes_identical = all(
        np.array_equal(delta_out[i].img, full_out[i].img) for i in key_ids)
    warp_fracs = [delta_out[i].warp_fraction for i in range(args.frames)]

    es = engine.stats()
    report = {
        "scene": args.scene, "res": args.res, "frames": args.frames,
        "keyframe_every": args.keyframe_every, "step_rad": args.step_rad,
        "prune": args.prune, "iters": args.iters,
        "ray_chunk": ray_chunk,
        "delta_ray_bucket": engine.delta_ray_bucket,
        "full": {"fps": fps_full, "total_s": full_s,
                 "compile_s": full_compile,
                 "psnr_mean": float(np.mean(psnr_full))},
        "delta": {"fps_effective": fps_delta, "total_s": delta_s,
                  "compile_s": delta_compile,
                  "psnr_mean": float(np.mean(psnr_delta)),
                  "warp_fraction_mean": float(np.mean(
                      [w for i, w in enumerate(warp_fracs)
                       if i not in key_ids] or [0.0])),
                  "warp_fraction_min": float(np.min(
                      [w for i, w in enumerate(warp_fracs)
                       if i not in key_ids] or [0.0])),
                  "engine": es["delta"]},
        "speedup_effective": ratio,
        "psnr_drift_db": drift,
        "psnr_per_frame": [
            {"frame": i, "keyframe": i in key_ids,
             "psnr_full": round(psnr_full[i], 3),
             "psnr_delta": round(psnr_delta[i], 3),
             "psnr_delta_vs_full": round(psnr_delta_vs_full[i], 2),
             "warp_fraction": round(warp_fracs[i], 4)}
            for i in range(args.frames)],
        "keyframes_bit_identical": bool(keyframes_identical),
        "ordering_cache": es["ordering_cache"],
        # per-stage wall-clock from the request tracer: warp/mask run on
        # the submit thread, composite on the flush thread — this table is
        # where the temporal tier's win (and its overhead) is itemised
        "stages": engine.stage_breakdown(),
    }
    engine.close()

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps({k: v for k, v in report.items()
                      if k not in ("psnr_per_frame", "stages")}, indent=2))
    print(f"wrote {args.out}")

    if args.check:
        failures = []
        if ratio < 2.0:
            failures.append(
                f"delta-path effective FPS ratio {ratio:.2f}x < 2x "
                f"(full {fps_full:.3f} fps, delta {fps_delta:.3f} fps)")
        if drift > 0.5:
            failures.append(
                f"mean PSNR drift {drift:.3f} dB > 0.5 dB "
                f"(full {np.mean(psnr_full):.2f}, "
                f"delta {np.mean(psnr_delta):.2f})")
        if not keyframes_identical:
            failures.append("keyframes not bit-identical to submit renders")
        if es["ordering_cache"]["hits"] <= 0:
            failures.append("trajectory ordering cache never hit along "
                            "the path")
        for st in ("warp", "mask", "render", "composite"):
            if st not in report["stages"]:
                failures.append(f"stage '{st}' missing from the trace-"
                                f"derived breakdown")
        if failures:
            print("CHECK FAILED: " + "; ".join(failures))
            sys.exit(1)
        print(f"CHECK OK: {ratio:.2f}x effective FPS on the path "
              f"(keyframe every {args.keyframe_every}), PSNR drift "
              f"{drift:.3f} dB, keyframes bit-identical, warp fraction "
              f"mean {report['delta']['warp_fraction_mean']:.3f}")


if __name__ == "__main__":
    main()
