"""Paper Sec. 4.2.2 storage claims: bytes per encoding format per factor,
the hybrid scheme's savings, and the measured byte-model crossover (which
lands ABOVE the paper's 80% — see DESIGN.md §3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK_SCENES, get_trained, row
from repro.core import sparse


def main(scenes=QUICK_SCENES):
    tot = {"dense": 0, "bitmap": 0, "coo": 0, "hybrid": 0}
    n_bitmap = n_coo = 0
    for scene in scenes:
        cfg, params, cubes = get_trained(scene)
        rep = sparse.factor_report(params)
        for k, v in rep.items():
            tot["dense"] += v["dense_bytes"]
            tot["bitmap"] += v["bitmap_bytes"]
            tot["coo"] += v["coo_bytes"]
            tot["hybrid"] += v["chosen_bytes"]
            if v["format"] == "bitmap":
                n_bitmap += 1
            else:
                n_coo += 1
    row("enc_total_bytes", 0.0,
        f"dense={tot['dense']};bitmap={tot['bitmap']};coo={tot['coo']};"
        f"hybrid={tot['hybrid']}")
    row("enc_hybrid_saving", 0.0,
        f"vs_dense={tot['dense'] / max(tot['hybrid'], 1):.2f}x;"
        f"bitmap_share={n_bitmap / max(n_bitmap + n_coo, 1):.2f};"
        f"paper_share=0.68")

    # measured pure-storage crossover for fp32 values
    shape = (256, 256)
    total = shape[0] * shape[1]
    cross = None
    for s in np.linspace(0.5, 0.999, 200):
        nnz = int(total * (1 - s))
        if sparse.storage_bytes(shape, nnz, "coo") < \
                sparse.storage_bytes(shape, nnz, "bitmap"):
            cross = s
            break
    row("enc_byte_crossover", 0.0,
        f"measured={cross:.3f};paper_threshold=0.80;"
        f"gap_explained=decode-latency (DESIGN.md §3)")


if __name__ == "__main__":
    main()
