from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, adafactor, sgd, pick_optimizer, clip_by_global_norm)
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_topk, decompress_topk, quantize_int8, dequantize_int8)
