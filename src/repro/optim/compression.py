"""Gradient compression for cross-pod all-reduce (distributed-opt tricks).

Top-k sparsification reuses the paper's COO insight on gradients: at high
sparsity, (index, value) streams beat dense exchange. int8 quantization is
the bitmap-regime analogue (dense but narrow). Used by launch/train.py when
``--grad-compression`` is set; error feedback keeps convergence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_topk(g: jax.Array, frac: float = 0.01) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Keep the top `frac` entries by magnitude. Returns (idx, vals, residual)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.shape[0] * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return idx.astype(jnp.int32), vals, residual


def decompress_topk(idx: jax.Array, vals: jax.Array, shape) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return jnp.zeros((n,), jnp.float32).at[idx].add(vals).reshape(shape)


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
