"""Pure-JAX optimizers (no optax in this container).

`Optimizer` is a pair of pure functions (init, update) over pytrees.
AdamW keeps fp32 m/v (+ optional fp32 master for bf16 params); Adafactor
keeps a factored second moment so DeepSeek-V3-scale archs fit 16GB/chip
(DESIGN.md §7). `pick_optimizer` applies the size rule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    name: str = "opt"


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, wd: float = 0.0,
          schedule: Optional[Callable] = None) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, _loss=None):
        step = state["step"] + 1
        lr_t = lr * (schedule(step) if schedule is not None else 1.0)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            if wd:
                u = u + wd * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr_t * u
            return m2, v2, p2.astype(p.dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        p = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return p, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, "adamw")


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_thresh: float = 1.0,
              schedule: Optional[Callable] = None) -> Optimizer:
    """Factored 2nd moment (row/col) for >=2D params; no momentum, no master."""

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(one, params)}

    def update(grads, state, params, _loss=None):
        step = state["step"] + 1
        lr_t = lr * (schedule(step) if schedule is not None else 1.0)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g * jax.lax.rsqrt(vr[..., None] / denom[..., None])
                u = u * jax.lax.rsqrt(vc[..., None, :])
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = g * jax.lax.rsqrt(nv["v"])
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            p2 = p.astype(jnp.float32) - lr_t * u
            return p2.astype(p.dtype), nv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        p = tdef.unflatten([o[0] for o in outs])
        v = tdef.unflatten([o[1] for o in outs])
        return p, {"step": step, "v": v}

    return Optimizer(init, update, "adafactor")


def sgd(lr: float = 1e-2) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _loss=None):
        p = jax.tree.map(
            lambda pp, g: (pp.astype(jnp.float32)
                           - lr * g.astype(jnp.float32)).astype(pp.dtype),
            params, grads)
        return p, {"step": state["step"] + 1}

    return Optimizer(init, update, "sgd")


ADAFACTOR_PARAM_THRESHOLD = 30_000_000_000  # 30B


def pick_optimizer(n_params: int, lr: float = 1e-4,
                   schedule: Optional[Callable] = None) -> Optimizer:
    """AdamW below 30B params; Adafactor at/above (HBM budget, DESIGN §7)."""
    if n_params >= ADAFACTOR_PARAM_THRESHOLD:
        return adafactor(lr=lr, schedule=schedule)
    return adamw(lr=lr, schedule=schedule)
