"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Decode state per layer: {"shift_t", "shift_c": (B,D), "wkv": (B,H,hd,hd)} —
constant-size, which is what makes rwkv6 the long_500k reference arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Maker, layer_norm

DDLERP_RANK = 32
DECAY_RANK = 64
N_MIX = 5  # r, k, v, g, w


def init_rwkv6(mk: Maker, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    hd = cfg.resolved_head_dim
    return {
        "ln1_g": mk.ones((d,), ("embed",)), "ln1_b": mk.z((d,), ("embed",)),
        "ln2_g": mk.ones((d,), ("embed",)), "ln2_b": mk.z((d,), ("embed",)),
        # --- time mix ---
        "mu_base": mk.z((d,), ("embed",)),
        "mu": mk.z((N_MIX, d), (None, "embed")),
        "w_a1": mk.w((d, N_MIX * DDLERP_RANK), ("embed", None), fan_in=d),
        "w_a2": mk.w((N_MIX, DDLERP_RANK, d), (None, None, "embed"), fan_in=DDLERP_RANK),
        "wr": mk.w((d, h, hd), ("embed", "heads", "head_dim"), fan_in=d),
        "wk": mk.w((d, h, hd), ("embed", "heads", "head_dim"), fan_in=d),
        "wv": mk.w((d, h, hd), ("embed", "heads", "head_dim"), fan_in=d),
        "wg": mk.w((d, h, hd), ("embed", "heads", "head_dim"), fan_in=d),
        "w0": mk.const(jnp.zeros(d) - 4.0, ("embed",)),        # decay bias
        "ww1": mk.w((d, DECAY_RANK), ("embed", None), fan_in=d),
        "ww2": mk.w((DECAY_RANK, d), (None, "embed"), fan_in=DECAY_RANK),
        "u": mk.z((h, hd), ("heads", "head_dim")),             # bonus
        "gn_g": mk.ones((h, hd), ("heads", "head_dim")),
        "gn_b": mk.z((h, hd), ("heads", "head_dim")),
        "wo": mk.w((h, hd, d), ("heads", "head_dim", "embed"), fan_in=d),
        # --- channel mix ---
        "cmu_k": mk.z((d,), ("embed",)),
        "cmu_r": mk.z((d,), ("embed",)),
        "cwk": mk.w((d, cfg.d_ff), ("embed", "mlp"), fan_in=d),
        "cwv": mk.w((cfg.d_ff, d), ("mlp", "embed"), fan_in=cfg.d_ff),
        "cwr": mk.w((d, d), ("embed", "embed"), fan_in=d),
    }


def _ddlerp(p, x, xx):
    """Data-dependent token-shift mixes. x,xx (B,S,D) -> 5 mixed tensors."""
    base = x + xx * p["mu_base"]
    a = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, p["w_a1"]).astype(jnp.float32))
    a = a.reshape(*a.shape[:-1], N_MIX, DDLERP_RANK)
    off = jnp.einsum("bsmr,mrd->bsmd", a.astype(x.dtype), p["w_a2"])
    mix = p["mu"][None, None] + off                        # (B,S,5,D)
    return [x + xx * mix[..., i, :] for i in range(N_MIX)]


def _decay(p, xw):
    w = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsd,dr->bsr", xw, p["ww1"]).astype(jnp.float32) @ p["ww2"].astype(jnp.float32)
    return jnp.exp(-jnp.exp(w))                            # (B,S,D) in (0,1)


def _group_norm(y, g, b, eps):
    """Per-head layer norm. y (B,S,H,hd)."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.mean((yf - mu) ** 2, axis=-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + eps)
    return (yf * g.astype(jnp.float32) + b.astype(jnp.float32)).astype(y.dtype)


def _time_mix(p, cfg, x, shift_prev, wkv0):
    """x (B,S,D) post-ln. Returns (out, last_x, wkv_state)."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    x_prev = jnp.concatenate([shift_prev[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xr, xk, xv, xg, xw = _ddlerp(p, x, xx)
    r = jnp.einsum("bsd,dhe->bshe", xr, p["wr"])
    k = jnp.einsum("bsd,dhe->bshe", xk, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", xv, p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,dhe->bshe", xg, p["wg"]).astype(jnp.float32))
    w = _decay(p, xw).reshape(B, S, H, hd)

    def step(s_wkv, inp):
        rt, kt, vt, wt = inp                              # (B,H,hd) fp32
        att = s_wkv + (p["u"].astype(jnp.float32) * kt)[..., :, None] * vt[..., None, :]
        yt = jnp.einsum("bhij,bhi->bhj", att, rt)
        s_wkv = wt[..., :, None] * s_wkv + kt[..., :, None] * vt[..., None, :]
        return s_wkv, yt

    tr = lambda t: t.transpose(1, 0, 2, 3).astype(jnp.float32)
    s_last, ys = jax.lax.scan(step, wkv0, (tr(r), tr(k), tr(v), tr(w)))
    y = ys.transpose(1, 0, 2, 3)                          # (B,S,H,hd) fp32
    y = _group_norm(y, p["gn_g"], p["gn_b"], cfg.norm_eps)
    y = (y.astype(jnp.float32) * g).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", y, p["wo"])
    return out, x[:, -1], s_last


def _channel_mix(p, x, shift_prev):
    x_prev = jnp.concatenate([shift_prev[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * p["cmu_k"]
    xr = x + xx * p["cmu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, p["cwk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, p["cwv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cwr"]).astype(jnp.float32))
    return (r * kv.astype(jnp.float32)).astype(x.dtype), x[:, -1]


def rwkv6_forward(p, cfg: ModelConfig, x, state=None):
    """x (B,S,D). state None (train) or decode state dict. Returns (x, state)."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    if state is None:
        state = {
            "shift_t": jnp.zeros((B, D), x.dtype),
            "shift_c": jnp.zeros((B, D), x.dtype),
            "wkv": jnp.zeros((B, H, hd, hd), jnp.float32),
        }
    h1 = layer_norm(x, p["ln1_g"], p["ln1_b"], cfg.norm_eps)
    att, sh_t, wkv = _time_mix(p, cfg, h1, state["shift_t"], state["wkv"])
    x = x + att
    h2 = layer_norm(x, p["ln2_g"], p["ln2_b"], cfg.norm_eps)
    ffn, sh_c = _channel_mix(p, h2, state["shift_c"])
    x = x + ffn
    return x, {"shift_t": sh_t, "shift_c": sh_c, "wkv": wkv}


def rwkv6_state_shape(cfg: ModelConfig, batch: int):
    hd = cfg.resolved_head_dim
    return {
        "shift_t": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.bfloat16),
        "shift_c": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.bfloat16),
        "wkv": jax.ShapeDtypeStruct((batch, cfg.n_heads, hd, hd), jnp.float32),
    }
