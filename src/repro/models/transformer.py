"""Model assembly for all 10 assigned architectures.

Every trunk is a `lax.scan` over stacked layer params (compile time O(1) in
depth; remat policy applied to the scan body). Entry points:

  init_model(cfg, key)                       -> PL tree (params + logical)
  model_loss(params, cfg, batch)             -> (loss, metrics)      [train]
  model_prefill(params, cfg, batch)          -> (last_logits, cache) [serve]
  model_decode(params, cfg, token, pos, cache) -> (logits, cache)    [serve]
  serve_cache_spec(cfg, batch, seq)          -> (shape_tree, logical_tree)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (Maker, PL, cross_entropy, geglu, is_pl,
                                 rms_norm, split_pl, swiglu)
from repro.models.sharding import shard_act

# window kicks in only for long-context decode (DESIGN.md §5, zamba2 deviation)
WINDOW_MIN_SEQ = 131_072


# --------------------------------------------------------------------------
# layer init
# --------------------------------------------------------------------------


def _init_mlp(mk: Maker, cfg: ModelConfig, d_ff: int):
    d = cfg.d_model
    p = {"w1": mk.w((d, d_ff), ("embed", "mlp"), fan_in=d),
         "w2": mk.w((d_ff, d), ("mlp", "embed"), fan_in=d_ff)}
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = mk.w((d, d_ff), ("embed", "mlp"), fan_in=d)
    return p


def _init_dense_layer(key, cfg: ModelConfig, *, cross: bool = False):
    mk = Maker(key)
    p = {"ln1": mk.ones((cfg.d_model,), ("embed",)),
         "attn": attn_lib.init_attention(mk, cfg),
         "ln2": mk.ones((cfg.d_model,), ("embed",)),
         "mlp": _init_mlp(mk, cfg, cfg.d_ff)}
    if cross:
        p["lnx"] = mk.ones((cfg.d_model,), ("embed",))
        p["xattn"] = attn_lib.init_gqa(mk, cfg)
    return p


def _init_moe_layer(key, cfg: ModelConfig):
    mk = Maker(key)
    return {"ln1": mk.ones((cfg.d_model,), ("embed",)),
            "attn": attn_lib.init_attention(mk, cfg),
            "ln2": mk.ones((cfg.d_model,), ("embed",)),
            "moe": moe_lib.init_moe(mk, cfg)}


def _init_mamba_layer(key, cfg: ModelConfig):
    mk = Maker(key)
    return {"ln": mk.ones((cfg.d_model,), ("embed",)),
            "mamba": ssm_lib.init_mamba2(mk, cfg)}


def _init_rwkv_layer(key, cfg: ModelConfig):
    mk = Maker(key)
    return rwkv_lib.init_rwkv6(mk, cfg)


def _init_stack(key, cfg, layer_init, n: int):
    """Stacked layer params via vmap; logical gets a leading 'stack' axis."""
    keys = jax.random.split(key, n)
    one = layer_init(keys[0], cfg)
    _, logical = split_pl(one)
    arrays = jax.vmap(lambda k: split_pl(layer_init(k, cfg))[0])(keys)
    return jax.tree.map(
        lambda a, s: PL(a, ("stack",) + tuple(x if x else None for x in s.split("|"))),
        arrays, logical)


def init_model(cfg: ModelConfig, key) -> Dict[str, Any]:
    mk = Maker(jax.random.fold_in(key, 0))
    d, Vp = cfg.d_model, cfg.vocab_padded
    p: Dict[str, Any] = {
        "embed": mk.w((Vp, d), ("vocab", "embed"), fan_in=d),
        "final_norm": mk.ones((d,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        p["head"] = mk.w((d, Vp), ("embed", "vocab"), fan_in=d)

    kt = jax.random.fold_in(key, 1)
    if cfg.family == "ssm":
        p["layers"] = _init_stack(kt, cfg, _init_rwkv_layer, cfg.n_layers)
    elif cfg.family == "hybrid":
        p["mamba"] = _init_stack(kt, cfg, _init_mamba_layer, cfg.n_layers)
        p["shared"] = _init_dense_layer(jax.random.fold_in(key, 2), cfg)
    elif cfg.enc_dec:
        p["enc"] = _init_stack(kt, cfg, _init_dense_layer, cfg.n_enc_layers)
        p["enc_norm"] = mk.ones((d,), ("embed",))
        p["dec"] = _init_stack(
            jax.random.fold_in(key, 2), cfg,
            functools.partial(_init_dense_layer, cross=True), cfg.n_layers)
    elif cfg.is_moe:
        nd = cfg.n_dense_layers
        if nd:
            p["dense_layers"] = _init_stack(kt, cfg, _init_dense_layer, nd)
        p["moe_layers"] = _init_stack(
            jax.random.fold_in(key, 2), cfg, _init_moe_layer, cfg.n_layers - nd)
    else:
        p["layers"] = _init_stack(kt, cfg, _init_dense_layer, cfg.n_layers)

    if cfg.mtp:
        mk2 = Maker(jax.random.fold_in(key, 3))
        p["mtp"] = {
            "norm_h": mk2.ones((d,), ("embed",)),
            "norm_e": mk2.ones((d,), ("embed",)),
            "proj": mk2.w((2 * d, d), ("embed", "embed"), fan_in=2 * d),
            "layer": _init_dense_layer(jax.random.fold_in(key, 4), cfg),
        }
    return p


# --------------------------------------------------------------------------
# layer forward
# --------------------------------------------------------------------------


def _mlp_fwd(p, cfg: ModelConfig, x):
    h1 = jnp.einsum("bsd,df->bsf", x, p["w1"])
    h1 = shard_act(h1, "batch", "seq", "mlp")
    if "w3" in p:
        act = geglu if cfg.act == "geglu" else swiglu
        h = act(h1, jnp.einsum("bsd,df->bsf", x, p["w3"]))
    else:
        h = jax.nn.gelu(h1.astype(jnp.float32)).astype(h1.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


def _dense_layer_fwd(lp, cfg, x, positions, *, causal=True, window=0,
                     memory=None, return_cache=False):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, cache = attn_lib.attention_forward(
        lp["attn"], cfg, h, positions, causal=causal, window=window,
        return_cache=return_cache)
    x = x + a
    if memory is not None:
        xh = rms_norm(x, lp["lnx"], cfg.norm_eps)
        x = x + attn_lib.cross_forward(lp["xattn"], cfg, xh, memory)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + _mlp_fwd(lp["mlp"], cfg, h)
    x = shard_act(x, "batch", "seq", None)
    return x, cache


def _moe_layer_fwd(lp, cfg, x, positions, *, return_cache=False):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, cache = attn_lib.attention_forward(lp["attn"], cfg, h, positions,
                                          return_cache=return_cache)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, aux = moe_lib.moe_forward(lp["moe"], cfg, h)
    x = x + m
    x = shard_act(x, "batch", "seq", None)
    return x, aux, cache


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------
# trunks (train / prefill): return (h, aux, cache_tree)
# --------------------------------------------------------------------------


def _scan_dense(stack, cfg, x, positions, *, memory=None, window=0,
                collect_cache=False):
    def body(carry, lp):
        y, cache = _dense_layer_fwd(lp, cfg, carry, positions, window=window,
                                    memory=memory, return_cache=collect_cache)
        return y, cache
    x, caches = jax.lax.scan(_remat(body, cfg), x, stack)
    return x, caches


def _scan_moe(stack, cfg, x, positions, *, collect_cache=False):
    def body(carry, lp):
        y, aux, cache = _moe_layer_fwd(lp, cfg, carry[0], positions,
                                       return_cache=collect_cache)
        return (y, carry[1] + aux), cache
    (x, aux), caches = jax.lax.scan(_remat(body, cfg), (x, jnp.float32(0)), stack)
    return x, aux, caches


def _scan_encoder(stack, cfg, x, positions):
    def body(carry, lp):
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        a, _ = attn_lib.gqa_forward(lp["attn"], cfg, h, positions, causal=False)
        y = carry + a
        h = rms_norm(y, lp["ln2"], cfg.norm_eps)
        y = y + _mlp_fwd(lp["mlp"], cfg, h)
        return y, None
    x, _ = jax.lax.scan(_remat(body, cfg), x, stack)
    return x


def _hybrid_groups(cfg: ModelConfig):
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    trailing = cfg.n_layers - n_groups * g
    return g, n_groups, trailing


def _split_hybrid_stack(stack, cfg):
    g, n_groups, trailing = _hybrid_groups(cfg)
    grouped = jax.tree.map(
        lambda a: a[: n_groups * g].reshape(n_groups, g, *a.shape[1:]), stack)
    tail = jax.tree.map(lambda a: a[n_groups * g:], stack)
    return grouped, tail


def _mamba_block(lp, cfg, x, impl):
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    y, state = ssm_lib.mamba2_forward(lp["mamba"], cfg, h, impl=impl)
    return x + y, state


def _hybrid_trunk(params, cfg, x, positions, impl=None):
    impl = impl or cfg.ssm_impl
    grouped, tail = _split_hybrid_stack(params["mamba"], cfg)
    shared = params["shared"]

    def inner(carry, lp):
        y, _ = _mamba_block(lp, cfg, carry, impl)
        return y, None

    def group_body(carry, lp_group):
        y, _ = jax.lax.scan(inner, carry, lp_group)
        y, _ = _dense_layer_fwd(shared, cfg, y, positions)
        return y, None

    x, _ = jax.lax.scan(_remat(group_body, cfg), x, grouped)
    _, _, trailing = _hybrid_groups(cfg)
    if trailing:
        x, _ = jax.lax.scan(_remat(inner, cfg), x, tail)
    return x


def _rwkv_trunk(params, cfg, x):
    def body(carry, lp):
        y, _ = rwkv_lib.rwkv6_forward(lp, cfg, carry)
        return y, None
    x, _ = jax.lax.scan(_remat(body, cfg), x, params["layers"])
    return x


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------


def _embed(params, cfg, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    return shard_act(e, "batch", "seq", None)


def _logits(params, cfg, h):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        lg = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        lg = jnp.einsum("bsd,dv->bsv", h, params["head"])
    return shard_act(lg, "batch", "seq", "vocab")


def _assemble_input(params, cfg, batch):
    """tokens (+ stub frontend embeddings) -> (x, positions)."""
    x = _embed(params, cfg, batch["tokens"])
    if cfg.frontend and "frontend" in batch:
        x = jnp.concatenate([batch["frontend"].astype(x.dtype), x], axis=1)
    S = x.shape[1]
    return x, jnp.arange(S)


def _trunk(params, cfg, x, positions, *, memory=None, window=0):
    """Train/prefill trunk dispatch. Returns (h, aux_loss, caches|None)."""
    aux = jnp.float32(0)
    caches = None
    if cfg.family == "ssm":
        h = _rwkv_trunk(params, cfg, x)
    elif cfg.family == "hybrid":
        h = _hybrid_trunk(params, cfg, x, positions)
    elif cfg.enc_dec:
        h, caches = _scan_dense(params["dec"], cfg, x, positions, memory=memory)
    elif cfg.is_moe:
        if cfg.n_dense_layers:
            x, _ = _scan_dense(params["dense_layers"], cfg, x, positions)
        h, aux, caches = _scan_moe(params["moe_layers"], cfg, x, positions)
    else:
        h, caches = _scan_dense(params["layers"], cfg, x, positions, window=window)
    return h, aux, caches


# --------------------------------------------------------------------------
# training loss
# --------------------------------------------------------------------------

MOE_AUX_WEIGHT = 0.01
MTP_WEIGHT = 0.3


def model_loss(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    memory = None
    if cfg.enc_dec:
        frames = shard_act(batch["enc_frames"].astype(jnp.bfloat16),
                           "batch", "seq", None)
        memory = _scan_encoder(params["enc"], cfg, frames,
                               jnp.arange(frames.shape[1]))
        memory = rms_norm(memory, params["enc_norm"], cfg.norm_eps)
    x, positions = _assemble_input(params, cfg, batch)
    h, aux, _ = _trunk(params, cfg, x, positions, memory=memory)
    logits = _logits(params, cfg, h)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    ce = cross_entropy(logits, labels, mask)
    loss = ce + MOE_AUX_WEIGHT * aux

    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp:
        mtp = params["mtp"]
        hn = rms_norm(h[:, :-1], mtp["norm_h"], cfg.norm_eps)
        # teacher token t+1 embedding predicts token t+2
        nxt = _embed(params, cfg, batch["tokens"][:, 1:])
        if cfg.frontend and "frontend" in batch:   # align to h positions
            nxt = jnp.concatenate([batch["frontend"].astype(nxt.dtype), nxt],
                                  axis=1)[:, : hn.shape[1]]
        en = rms_norm(nxt[:, : hn.shape[1]], mtp["norm_e"], cfg.norm_eps)
        hm = jnp.einsum("bsd,de->bse", jnp.concatenate([hn, en], axis=-1),
                        mtp["proj"])
        hm, _ = _dense_layer_fwd(mtp["layer"], cfg, hm, positions[:-1])
        mtp_logits = _logits(params, cfg, hm)
        mtp_labels = labels[:, 1:]
        mtp_mask = mask[:, 1:] if mask is not None else None
        mtp_ce = cross_entropy(mtp_logits, mtp_labels, mtp_mask)
        loss = loss + MTP_WEIGHT * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------


def model_prefill(params, cfg: ModelConfig, batch):
    """Full-prompt forward; returns (last_logits, cache)."""
    memory = None
    if cfg.enc_dec:
        frames = batch["enc_frames"].astype(jnp.bfloat16)
        memory = _scan_encoder(params["enc"], cfg, frames,
                               jnp.arange(frames.shape[1]))
        memory = rms_norm(memory, params["enc_norm"], cfg.norm_eps)
    x, positions = _assemble_input(params, cfg, batch)

    if cfg.family == "ssm":
        def body(carry, lp):
            y, st = rwkv_lib.rwkv6_forward(lp, cfg, carry)
            return y, st
        h, states = jax.lax.scan(body, x, params["layers"])
        cache = {"layers": states, "memory": None}
    elif cfg.family == "hybrid":
        h, cache = _hybrid_prefill(params, cfg, x, positions)
    elif cfg.enc_dec:
        def ed_body(carry, lp):
            y, kv = _dense_layer_fwd(lp, cfg, carry, positions, memory=memory,
                                     return_cache=True)
            xkv = attn_lib.cross_kv(lp["xattn"], memory)
            return y, (kv, xkv)
        h, (kv, xkv) = jax.lax.scan(ed_body, x, params["dec"])
        cache = {"layers": kv, "xkv": {"k": xkv[0], "v": xkv[1]}, "memory": None}
    elif cfg.is_moe:
        nd = cfg.n_dense_layers
        dkv = None
        if nd:
            x, dkv = _scan_dense(params["dense_layers"], cfg, x, positions,
                                 collect_cache=True)
        h, _, mkv = _scan_moe(params["moe_layers"], cfg, x, positions,
                              collect_cache=True)
        cache = {"dense": dkv, "moe": mkv, "memory": None}
    else:
        h, kv = _scan_dense(params["layers"], cfg, x, positions,
                            collect_cache=True)
        cache = {"layers": kv, "memory": None}
    logits = _logits(params, cfg, h[:, -1:])
    return logits, cache


def _hybrid_prefill(params, cfg, x, positions):
    grouped, tail = _split_hybrid_stack(params["mamba"], cfg)
    shared = params["shared"]

    def inner(carry, lp):
        y, st = _mamba_block(lp, cfg, carry, "scan")
        return y, st

    def group_body(carry, lp_group):
        y, sts = jax.lax.scan(inner, carry, lp_group)
        y, kv = _dense_layer_fwd(shared, cfg, y, positions, return_cache=True)
        return y, (sts, kv)

    x, (m_states, a_kv) = jax.lax.scan(group_body, x, grouped)
    _, _, trailing = _hybrid_groups(cfg)
    if trailing:
        x, t_states = jax.lax.scan(inner, x, tail)
    else:
        t_states = None
    return x, {"mamba_g": m_states, "attn": a_kv, "mamba_t": t_states,
               "memory": None}


def _decode_window(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.window and seq_len > WINDOW_MIN_SEQ:
        return cfg.window
    return 0


def model_decode(params, cfg: ModelConfig, token, pos, cache, *,
                 seq_len: int):
    """One-token step. token (B,1) int32; pos scalar int32."""
    x = _embed(params, cfg, token)
    window = _decode_window(cfg, seq_len)

    if cfg.family == "ssm":
        def body(carry, xs):
            lp, st = xs
            y, st2 = rwkv_lib.rwkv6_forward(lp, cfg, carry, state=st)
            return y, st2
        h, states = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": states, "memory": None}
    elif cfg.family == "hybrid":
        h, new_cache = _hybrid_decode(params, cfg, x, pos, cache, window)
    elif cfg.enc_dec:
        def body(carry, xs):
            lp, kv, xk, xv = xs
            hh = rms_norm(carry, lp["ln1"], cfg.norm_eps)
            a, kv2 = attn_lib.attention_decode(lp["attn"], cfg, hh, pos, kv)
            y = carry + a
            yh = rms_norm(y, lp["lnx"], cfg.norm_eps)
            y = y + attn_lib.cross_forward(lp["xattn"], cfg, yh, kv=(xk, xv))
            hh = rms_norm(y, lp["ln2"], cfg.norm_eps)
            y = y + _mlp_fwd(lp["mlp"], cfg, hh)
            return y, kv2
        h, kv = jax.lax.scan(body, x, (params["dec"], cache["layers"],
                                       cache["xkv"]["k"], cache["xkv"]["v"]))
        new_cache = {"layers": kv, "xkv": cache["xkv"], "memory": None}
    elif cfg.is_moe:
        nd = cfg.n_dense_layers
        dkv = None
        if nd:
            def dbody(carry, xs):
                lp, kv = xs
                hh = rms_norm(carry, lp["ln1"], cfg.norm_eps)
                a, kv2 = attn_lib.attention_decode(lp["attn"], cfg, hh, pos, kv)
                y = carry + a
                hh = rms_norm(y, lp["ln2"], cfg.norm_eps)
                y = y + _mlp_fwd(lp["mlp"], cfg, hh)
                return y, kv2
            x, dkv = jax.lax.scan(dbody, x, (params["dense_layers"],
                                             cache["dense"]))
        def mbody(carry, xs):
            lp, kv = xs
            hh = rms_norm(carry, lp["ln1"], cfg.norm_eps)
            a, kv2 = attn_lib.attention_decode(lp["attn"], cfg, hh, pos, kv)
            y = carry + a
            hh = rms_norm(y, lp["ln2"], cfg.norm_eps)
            m, _ = moe_lib.moe_forward(lp["moe"], cfg, hh)
            return y + m, kv2
        h, mkv = jax.lax.scan(mbody, x, (params["moe_layers"], cache["moe"]))
        new_cache = {"dense": dkv, "moe": mkv, "memory": None}
    else:
        def body(carry, xs):
            lp, kv = xs
            hh = rms_norm(carry, lp["ln1"], cfg.norm_eps)
            a, kv2 = attn_lib.attention_decode(lp["attn"], cfg, hh, pos, kv,
                                               window=window)
            y = carry + a
            hh = rms_norm(y, lp["ln2"], cfg.norm_eps)
            y = y + _mlp_fwd(lp["mlp"], cfg, hh)
            return y, kv2
        h, kv = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": kv, "memory": cache.get("memory")}

    logits = _logits(params, cfg, h)
    return logits, new_cache


def _hybrid_decode(params, cfg, x, pos, cache, window):
    grouped, tail = _split_hybrid_stack(params["mamba"], cfg)
    shared = params["shared"]

    def inner(carry, xs):
        lp, st = xs
        h = rms_norm(carry, lp["ln"], cfg.norm_eps)
        y, st2 = ssm_lib.mamba2_decode(lp["mamba"], cfg, h, st)
        return carry + y, st2

    def group_body(carry, xs):
        lp_group, m_st, kv = xs
        y, m_st2 = jax.lax.scan(inner, carry, (lp_group, m_st))
        hh = rms_norm(y, shared["ln1"], cfg.norm_eps)
        a, kv2 = attn_lib.attention_decode(shared["attn"], cfg, hh, pos, kv,
                                           window=window)
        y = y + a
        hh = rms_norm(y, shared["ln2"], cfg.norm_eps)
        y = y + _mlp_fwd(shared["mlp"], cfg, hh)
        return y, (m_st2, kv2)

    x, (m_states, a_kv) = jax.lax.scan(
        group_body, x, (grouped, cache["mamba_g"], cache["attn"]))
    _, _, trailing = _hybrid_groups(cfg)
    t_states = None
    if trailing:
        x, t_states = jax.lax.scan(inner, x, (tail, cache["mamba_t"]))
    return x, {"mamba_g": m_states, "attn": a_kv, "mamba_t": t_states,
               "memory": None}


# --------------------------------------------------------------------------
# cache specs (for dry-run decode cells: ShapeDtypeStruct + logical axes)
# --------------------------------------------------------------------------


def _with_stack(tree, n):
    shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)
    return shapes


def serve_cache_spec(cfg: ModelConfig, batch: int, seq_len: int,
                     enc_len: int = 0):
    """(ShapeDtypeStruct tree, logical-string tree) for the decode cache.

    enc_len: actual encoder-memory length for enc-dec archs (defaults to
    cfg.enc_memory_len). Cross-KV must be allocated at the REAL encoder
    output length — zero-padded cross slots are attended with score 0, not
    masked (caught by tests/test_decode_parity.py)."""
    window = _decode_window(cfg, seq_len)
    seq_ax = "seq" if attn_lib.heads_shardable(cfg) else "seq_model"
    kv_log = {"k": f"stack|batch|{seq_ax}|kv_heads|head_dim",
              "v": f"stack|batch|{seq_ax}|kv_heads|head_dim"}
    mla_log = {"c": "stack|batch|seq|", "kr": "stack|batch|seq|"}
    att_log = mla_log if cfg.attention == "mla" else kv_log

    def kv(n):
        return _with_stack(attn_lib.attention_cache_shape(
            cfg, batch, seq_len, window=window), n)

    if cfg.family == "ssm":
        st = rwkv_lib.rwkv6_state_shape(cfg, batch)
        shapes = {"layers": _with_stack(st, cfg.n_layers), "memory": None}
        log = {"layers": {"shift_t": "stack|batch|",
                          "shift_c": "stack|batch|",
                          "wkv": "stack|batch|heads||"},
               "memory": None}
        return shapes, log
    if cfg.family == "hybrid":
        g, n_groups, trailing = _hybrid_groups(cfg)
        mst = ssm_lib.mamba2_state_shape(cfg, batch)
        m_log = {"h": "stack|stack2|batch|||", "conv": "stack|stack2|batch||mlp"}
        shapes = {
            "mamba_g": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_groups, g) + s.shape, s.dtype), mst),
            "attn": kv(n_groups),
            "mamba_t": (_with_stack(mst, trailing) if trailing else None),
            "memory": None,
        }
        log = {"mamba_g": m_log,
               "attn": {k: v for k, v in att_log.items()},
               "mamba_t": ({"h": "stack|batch|||", "conv": "stack|batch||mlp"}
                           if trailing else None),
               "memory": None}
        return shapes, log
    if cfg.enc_dec:
        M = enc_len or cfg.enc_memory_len
        hd = cfg.resolved_head_dim
        xkv = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, M, cfg.n_kv_heads, hd), jnp.bfloat16)
        shapes = {"layers": kv(cfg.n_layers),
                  "xkv": {"k": xkv, "v": xkv}, "memory": None}
        log = {"layers": att_log,
               "xkv": {"k": "stack|batch|seq|kv_heads|head_dim",
                       "v": "stack|batch|seq|kv_heads|head_dim"},
               "memory": None}
        return shapes, log
    if cfg.is_moe:
        nd = cfg.n_dense_layers
        shapes = {"dense": (kv(nd) if nd else None),
                  "moe": kv(cfg.n_layers - nd), "memory": None}
        log = {"dense": (att_log if nd else None), "moe": att_log,
               "memory": None}
        return shapes, log
    shapes = {"layers": kv(cfg.n_layers), "memory": None}
    return shapes, {"layers": att_log, "memory": None}
