"""Mixture-of-Experts with RT-NeRF-style *hybrid sparse dispatch*.

The paper (H1) encodes sparse factors as bitmap (<80% sparsity) or COO
(>=80%). The token->expert assignment matrix is exactly such a factor with
sparsity 1 - top_k/E, so the framework offers both dispatch modes:

  "coo"    — sort/gather dispatch (GShard-style, groups = sequences so the
             expert resharding lowers to all-to-all, not all-gather).
             DeepSeek-V3: 96.9% sparse -> COO regime.
  "bitmap" — dense-masked: every token through every expert, gate weights
             zero out unrouted pairs (seq-chunked so the (T,E,F) intermediate
             stays bounded). Grok-1: 75% sparse -> bitmap regime per the
             paper's rule. §Perf revisits whether the 80% ASIC-storage
             threshold survives TPU compute economics.

Both are numerically equivalent up to capacity drops (property-tested).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Maker, swiglu, geglu
from repro.models.sharding import shard_act

BITMAP_CHUNK = 256          # tokens per chunk in dense-masked mode


def init_moe(mk: Maker, cfg: ModelConfig):
    d = cfg.d_model
    dff = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    p = {
        "router": mk.w((d, e), ("embed", "experts"), fan_in=d),
        "w1": mk.w((e, d, dff), ("experts", "embed", "mlp"), fan_in=d),
        "w2": mk.w((e, dff, d), ("experts", "mlp", "embed"), fan_in=dff),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = mk.w((e, d, dff), ("experts", "embed", "mlp"), fan_in=d)
    if cfg.n_shared_experts:
        sdff = dff * cfg.n_shared_experts
        p["sw1"] = mk.w((d, sdff), ("embed", "mlp"), fan_in=d)
        p["sw2"] = mk.w((sdff, d), ("mlp", "embed"), fan_in=sdff)
        if cfg.act in ("swiglu", "geglu"):
            p["sw3"] = mk.w((d, sdff), ("embed", "mlp"), fan_in=d)
    return p


def _act_fn(cfg):
    return geglu if cfg.act == "geglu" else swiglu


def _expert_ffn(p, cfg: ModelConfig, xin):
    """xin (..., E, C, D) -> (..., E, C, D), batched over experts."""
    h1 = jnp.einsum("...ecd,edf->...ecf", xin, p["w1"])
    if "w3" in p:
        h = _act_fn(cfg)(h1, jnp.einsum("...ecd,edf->...ecf", xin, p["w3"]))
    else:
        h = jax.nn.gelu(h1.astype(jnp.float32)).astype(h1.dtype)
    return jnp.einsum("...ecf,efd->...ecd", h, p["w2"])


def _router_scores(p, cfg: ModelConfig, x):
    """x (..., D) -> (vals, idx, aux): top-k gates + load-balance aux loss."""
    logits = jnp.einsum("...d,de->...e", x, p["router"]).astype(jnp.float32)
    if cfg.name.startswith("deepseek"):
        scores = jax.nn.sigmoid(logits)            # DeepSeek-V3 sigmoid gates
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(scores, cfg.top_k)
    vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    # switch-style load-balance aux: E * mean_e(frac_tokens_e * mean_prob_e)
    probs = jax.nn.softmax(logits, axis=-1)
    e = cfg.n_experts
    sel = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)   # primary expert
    frac = jnp.mean(sel.reshape(-1, e), axis=0)
    mprob = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(frac * mprob)
    return vals, idx, aux


# --------------------------------------------------------------------------
# COO mode — sort/gather dispatch, grouped per sequence
# --------------------------------------------------------------------------


def _route_one_group(idx, vals, S: int, E: int, C: int):
    """idx/vals (S,k) -> buf (E,C) token-index (S = empty), wbuf (E,C)."""
    k = idx.shape[-1]
    e_flat = idx.reshape(-1)
    w_flat = vals.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)
    order = jnp.argsort(e_flat, stable=True)
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts
    posn = jnp.arange(S * k) - starts[e_s]
    valid = posn < C
    e_tgt = jnp.where(valid, e_s, E)               # row E = drop
    p_tgt = jnp.clip(posn, 0, C - 1)
    buf = jnp.full((E + 1, C), S, jnp.int32).at[e_tgt, p_tgt].set(t_s, mode="drop")
    wbuf = jnp.zeros((E + 1, C), w_flat.dtype).at[e_tgt, p_tgt].set(w_s, mode="drop")
    return buf[:E], wbuf[:E]


def moe_forward_coo(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,D). Groups = sequences -> all-to-all dispatch under GSPMD."""
    B, S, D = x.shape
    if S == 1:                                     # decode: one group of B
        out, aux = _moe_coo_grouped(p, cfg, x.reshape(1, B, D), B)
        return out.reshape(B, S, D), aux
    return _moe_coo_grouped(p, cfg, x, S)


def _moe_coo_grouped(p, cfg, xg, S):
    G, _, D = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(S * k / E * cfg.capacity_factor), k)
    vals, idx, aux = _router_scores(p, cfg, xg)
    buf, wbuf = jax.vmap(lambda i, v: _route_one_group(i, v, S, E, C))(idx, vals)
    x_pad = jnp.concatenate([xg, jnp.zeros((G, 1, D), xg.dtype)], axis=1)
    # keep the gather source and combine target pinned to batch sharding;
    # an unconstrained scatter output otherwise becomes a REPLICATED global
    # (G, S+1, D) fp32 buffer + all-reduce — the dominant collective in the
    # baseline deepseek train cell (EXPERIMENTS.md §Perf iteration 3)
    x_pad = shard_act(x_pad, "batch", "seq", None)
    xin = jnp.take_along_axis(
        x_pad, buf.reshape(G, E * C, 1), axis=1).reshape(G, E, C, D)
    xin = shard_act(xin, "batch", "experts", "cap", None)
    y = _expert_ffn(p, cfg, xin)                   # (G,E,C,D)
    y = y * wbuf[..., None].astype(y.dtype)
    out0 = shard_act(jnp.zeros((G, S + 1, D), y.dtype), "batch", "seq", None)
    out = out0.at[
        jnp.arange(G)[:, None], buf.reshape(G, E * C)
    ].add(y.reshape(G, E * C, D))
    out = shard_act(out, "batch", "seq", None)
    return out[:, :S], aux


# --------------------------------------------------------------------------
# Bitmap mode — dense-masked (all experts), seq-chunked
# --------------------------------------------------------------------------


def moe_forward_bitmap(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    vals, idx, aux = _router_scores(p, cfg, x)     # (B,S,k)
    # dense gate matrix (B,S,E) — the "bitmap" with weights
    gates = jnp.zeros((B, S, E), jnp.float32).at[
        jnp.arange(B)[:, None, None],
        jnp.arange(S)[None, :, None],
        idx,
    ].set(vals)

    Cc = min(BITMAP_CHUNK, S)
    n = (S + Cc - 1) // Cc
    Sp = n * Cc
    if Sp != S:
        x = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0)))
        gates = jnp.pad(gates, ((0, 0), (0, Sp - S), (0, 0)))
    xc = x.reshape(B, n, Cc, D).transpose(1, 0, 2, 3)
    gc = gates.reshape(B, n, Cc, E).transpose(1, 0, 2, 3)

    def body(_, xs):
        xj, gj = xs                                # (B,Cc,D), (B,Cc,E)
        h1 = jnp.einsum("bcd,edf->becf", xj, p["w1"])
        if "w3" in p:
            h = _act_fn(cfg)(h1, jnp.einsum("bcd,edf->becf", xj, p["w3"]))
        else:
            h = jax.nn.gelu(h1.astype(jnp.float32)).astype(h1.dtype)
        ye = jnp.einsum("becf,efd->becd", h, p["w2"])
        yj = jnp.einsum("becd,bce->bcd", ye, gj.astype(ye.dtype))
        return None, yj

    _, yc = jax.lax.scan(body, None, (xc, gc))
    y = yc.transpose(1, 0, 2, 3).reshape(B, Sp, D)[:, :S]
    return y, aux


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------


def moe_forward(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    mode = cfg.resolved_dispatch()
    out, aux = (moe_forward_coo if mode == "coo" else moe_forward_bitmap)(p, cfg, x)
    if cfg.moe_out_shard:
        # pin the combine output to batch sharding so the partial-sum reduce
        # over the expert (model) axis happens HERE, once, at bf16 width
        out = shard_act(out, "batch", "seq", None)
    if cfg.n_shared_experts:
        h1 = jnp.einsum("bsd,df->bsf", x, p["sw1"])
        if "sw3" in p:
            h = _act_fn(cfg)(h1, jnp.einsum("bsd,df->bsf", x, p["sw3"]))
        else:
            h = jax.nn.gelu(h1.astype(jnp.float32)).astype(h1.dtype)
        out = out + jnp.einsum("bsf,fd->bsd", h, p["sw2"])
    return out, aux
