"""Mamba2 (SSD) block — zamba2's trunk.

Baseline `ssm_impl="scan"` is a per-step recurrence (faithful, simple);
`ssm_impl="chunked"` is the matmul-heavy chunk-parallel SSD form used by the
perf pass (MXU-friendly). Both validated against each other in tests.

State: h (B, nH, hd, N); conv state (B, conv_w-1, d_conv_channels).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Maker

SSD_CHUNK = 128


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_state
    return d_in, nh, conv_ch


def init_mamba2(mk: Maker, cfg: ModelConfig):
    d = cfg.d_model
    d_in, nh, conv_ch = ssm_dims(cfg)
    n = cfg.ssm_state
    return {
        "in_proj": mk.w((d, 2 * d_in + 2 * n + nh), ("embed", "mlp"), fan_in=d),
        "conv_w": mk.w((cfg.ssm_conv, conv_ch), (None, "mlp"), fan_in=cfg.ssm_conv),
        "conv_b": mk.z((conv_ch,), ("mlp",)),
        "a_log": mk.const(jnp.zeros(nh) + 0.5, (None,)),
        "d_skip": mk.ones((nh,), (None,)),
        "dt_bias": mk.z((nh,), (None,)),
        "norm": mk.ones((d_in,), ("mlp",)),
        "out_proj": mk.w((d_in, d), ("mlp", "embed"), fan_in=d_in),
    }


def _split_proj(p, cfg, zxbcdt):
    d_in, nh, _ = ssm_dims(cfg)
    n = cfg.ssm_state
    z = zxbcdt[..., :d_in]
    xs = zxbcdt[..., d_in:2 * d_in]
    b = zxbcdt[..., 2 * d_in:2 * d_in + n]
    c = zxbcdt[..., 2 * d_in + n:2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n:]
    return z, xs, b, c, dt


def _causal_conv(xbc, w, bias, conv_state=None):
    """Depthwise causal conv. xbc (B,S,C); w (K,C). Returns (y, new_state)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    y = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(K)) + bias
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(y.astype(jnp.float32)).astype(xbc.dtype), new_state


def _gated_norm(y, z, gamma, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + eps)
    return (yf * gamma.astype(jnp.float32)).astype(y.dtype)


def mamba2_forward(p, cfg: ModelConfig, x, *, impl: str = "scan"):
    """Train/prefill. x (B,S,D) -> (y, final_state_dict)."""
    B, S, D = x.shape
    d_in, nh, conv_ch = ssm_dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xs, b, c, dt_raw = _split_proj(p, cfg, zxbcdt)
    xbc, conv_state = _causal_conv(
        jnp.concatenate([xs, b, c], axis=-1), p["conv_w"], p["conv_b"])
    xs, b, c = xbc[..., :d_in], xbc[..., d_in:d_in + n], xbc[..., d_in + n:]
    xh = xs.reshape(B, S, nh, hd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    da = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32)) * dt)      # (B,S,nh)

    if impl == "chunked":
        y, h_last = _ssd_chunked(xh, b, c, dt, da)
    else:
        def step(h, inp):
            xt, bt, ct, dtt, dat = inp
            h = h * dat[:, :, None, None] + (dtt[:, :, None] * xt)[..., None] \
                * bt[:, None, None, :]
            yt = jnp.einsum("bhdn,bn->bhd", h, ct)
            return h, yt
        h0 = jnp.zeros((B, nh, hd, n), jnp.float32)
        xsw = (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
               b.transpose(1, 0, 2).astype(jnp.float32),
               c.transpose(1, 0, 2).astype(jnp.float32),
               dt.transpose(1, 0, 2), da.transpose(1, 0, 2))
        h_last, ys = jax.lax.scan(step, h0, xsw)
        y = ys.transpose(1, 0, 2, 3)                                  # (B,S,nh,hd)

    y = y + p["d_skip"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    state = {"h": h_last.astype(jnp.float32), "conv": conv_state}
    return out, state


def _ssd_chunked(xh, b, c, dt, da):
    """Chunk-parallel SSD. xh (B,S,nh,hd); b,c (B,S,n); dt,da (B,S,nh) fp32.

    Within a chunk: y_intra via a decay-weighted quadratic form; across
    chunks: carry h with per-chunk decay. All contractions are matmuls.
    """
    B, S, nh, hd = xh.shape
    n = b.shape[-1]
    C = min(SSD_CHUNK, S)
    nc = (S + C - 1) // C
    Sp = nc * C
    pad = Sp - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)

    def rs(t):  # (B,Sp,...) -> (nc,B,C,...)
        return t.reshape(B, nc, C, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    xc, bc_, cc, dtc, dac = rs(xh.astype(jnp.float32)), rs(b.astype(jnp.float32)), \
        rs(c.astype(jnp.float32)), rs(dt), rs(da)

    def chunk(h, inp):
        xj, bj, cj, dtj, daj = inp                 # (B,C,...)
        logd = jnp.log(jnp.maximum(daj, 1e-38))
        cum = jnp.cumsum(logd, axis=1)             # (B,C,nh)
        # intra-chunk: y[t] = sum_{s<=t} exp(cum_t - cum_s) dt_s (c_t.b_s) x_s
        w = cum[:, :, None, :] - cum[:, None, :, :]            # (B,C,C,nh)
        mask = jnp.tril(jnp.ones((C, C), bool))
        g = jnp.where(mask[None, :, :, None], jnp.exp(w), 0.0)  # decay matrix
        cb = jnp.einsum("btn,bsn->bts", cj, bj)                 # (B,C,C)
        m = cb[:, :, :, None] * g * dtj[:, None, :, :]          # (B,C,C,nh)
        y_intra = jnp.einsum("btsh,bshd->bthd", m, xj)
        # inter-chunk: contribution of carried state
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum("bhdn,btn->bthd", h, cj).transpose(0, 1, 2, 3)
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)            # (B,C,nh)
        hb = jnp.einsum("bth,bthd,btn->bhdn", dtj * decay_to_end, xj, bj)
        h = h * jnp.exp(cum[:, -1])[:, :, None, None] + hb
        return h, y_intra + y_inter

    h0 = jnp.zeros((B, nh, hd, n), jnp.float32)
    h_last, yc = jax.lax.scan(chunk, h0, (xc, bc_, cc, dtc, dac))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, Sp, nh, hd)[:, :S]
    return y, h_last


def mamba2_decode(p, cfg: ModelConfig, x1, state) -> Tuple[jax.Array, dict]:
    """One token. x1 (B,1,D); state {"h","conv"}."""
    B = x1.shape[0]
    d_in, nh, conv_ch = ssm_dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x1, p["in_proj"])
    z, xs, b, c, dt_raw = _split_proj(p, cfg, zxbcdt)
    xbc, conv_state = _causal_conv(
        jnp.concatenate([xs, b, c], axis=-1), p["conv_w"], p["conv_b"],
        conv_state=state["conv"])
    xs, b, c = xbc[..., :d_in], xbc[..., d_in:d_in + n], xbc[..., d_in + n:]
    xt = xs.reshape(B, nh, hd).astype(jnp.float32)
    bt = b[:, 0].astype(jnp.float32)
    ct = c[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    da = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32)) * dt)
    h = state["h"] * da[:, :, None, None] + (dt[:, :, None] * xt)[..., None] * bt[:, None, None, :]
    y = jnp.einsum("bhdn,bn->bhd", h, ct)
    y = y + p["d_skip"].astype(jnp.float32)[:, None] * xt
    y = y.reshape(B, 1, d_in).astype(x1.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"h": h, "conv": conv_state}


def mamba2_state_shape(cfg: ModelConfig, batch: int):
    d_in, nh, conv_ch = ssm_dims(cfg)
    return {
        "h": jax.ShapeDtypeStruct((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                                  jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_ch),
                                     jnp.bfloat16),
    }
