"""Attention: GQA/MHA/MQA (+ optional QKV bias), MLA (DeepSeek-V3), caches.

Layouts
  q:  (B, S, Hkv, G, hd)   grouped — G = Hq // Hkv; logical axes let the
                           resolver shard whichever of Hkv / G divides the
                           model axis (DeepSeek: Hkv=128; granite-34b MQA:
                           G=48; grok: neither -> GSPMD propagates).
  kv: (B, S, Hkv, hd)
Caches
  gqa: {"k","v"}: (B, C, Hkv, hd); C = window if windowed else max seq.
  mla: {"c": (B, C, kv_lora), "kr": (B, C, rope_dim)} — the latent cache;
       decode uses the weight-absorbed formulation (DeepSeek's own trick).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Maker, apply_rope, rms_norm
from repro.models.sharding import current_rules, shard_act

QK_CHUNK = 512          # kv-chunk for the online-softmax (flash-style) path
NEG_INF = -1e30
PROD_MODEL_AXIS = 16    # production model-axis width (cache-spec decisions)


def heads_shardable(cfg: ModelConfig, m: int = PROD_MODEL_AXIS) -> bool:
    """Can (kv_heads | q-head-groups) shard over an m-way model axis?"""
    if cfg.attention == "mla":
        return cfg.n_heads % m == 0
    g = cfg.n_heads // max(cfg.n_kv_heads, 1)
    return (cfg.n_kv_heads % m == 0) or (g % m == 0)


def _attn_seq_axis(cfg: ModelConfig) -> str:
    """Sequence-parallel attention when heads cannot shard (qwen1.5's 40
    heads, llama/grok/internvl's 8 kv-heads on a 16-way model axis)."""
    if cfg.seq_shard_attn:
        return "seq_model"
    rules = current_rules()
    if rules is None:
        return "seq_model" if not heads_shardable(cfg) else "seq"
    m = rules.axis_size(rules.act_rules.get("kv_heads"))
    return "seq_model" if (m > 1 and not heads_shardable(cfg, m)) else "seq"


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init_gqa(mk: Maker, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": mk.w((d, hkv, hq // hkv, hd), ("embed", "kv_heads", "heads", "head_dim"), fan_in=d),
        "wk": mk.w((d, hkv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wv": mk.w((d, hkv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wo": mk.w((hkv, hq // hkv, hd, d), ("kv_heads", "heads", "head_dim", "embed"),
                   fan_in=hq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = mk.z((hkv, hq // hkv, hd), ("kv_heads", "heads", "head_dim"))
        p["bk"] = mk.z((hkv, hd), ("kv_heads", "head_dim"))
        p["bv"] = mk.z((hkv, hd), ("kv_heads", "head_dim"))
    return p


def init_mla(mk: Maker, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wdq": mk.w((d, qr), ("embed", "q_lora"), fan_in=d),
        "q_norm": mk.ones((qr,), ("q_lora",)),
        "wuq": mk.w((qr, h, nope + rope), ("q_lora", "heads", "head_dim"), fan_in=qr),
        "wdkv": mk.w((d, kr + rope), ("embed", "kv_lora"), fan_in=d),
        "kv_norm": mk.ones((kr,), ("kv_lora",)),
        "wuk": mk.w((kr, h, nope), ("kv_lora", "heads", "head_dim"), fan_in=kr),
        "wuv": mk.w((kr, h, vh), ("kv_lora", "heads", "head_dim"), fan_in=kr),
        "wo": mk.w((h, vh, d), ("heads", "head_dim", "embed"), fan_in=h * vh),
    }


def init_attention(mk: Maker, cfg: ModelConfig):
    return init_mla(mk, cfg) if cfg.attention == "mla" else init_gqa(mk, cfg)


# --------------------------------------------------------------------------
# core softmax-attention on grouped layouts
# --------------------------------------------------------------------------


def _masked_attn_naive(q, k, v, mask, scale):
    """q (B,S,K,G,h); k,v (B,T,K,h); mask (B,S,T) or (S,T) bool keep."""
    s = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        s = jnp.where(m[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    return o


def _masked_attn_chunked(q, k, v, q_pos, kv_pos, scale, causal, window):
    """Online-softmax over KV chunks (flash-style, pure XLA).

    q (B,S,K,G,h); k,v (B,T,K,h); q_pos (S,), kv_pos (T,). Memory per step is
    O(S * chunk) instead of O(S * T).
    """
    B, S, K, G, h = q.shape
    T = k.shape[1]
    C = min(QK_CHUNK, T)
    n_chunks = (T + C - 1) // C
    Tp = n_chunks * C
    if Tp != T:
        pad = [(0, 0), (0, Tp - T)] + [(0, 0)] * (k.ndim - 2)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        kv_pos = jnp.pad(kv_pos, (0, Tp - T), constant_values=-1)
    kc = k.reshape(B, n_chunks, C, K, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, C, K, v.shape[-1]).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, C)

    qf = q.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs
        s = jnp.einsum("bskgh,bckh->bkgsc", qf, kj.astype(jnp.float32)) * scale
        keep = pj[None, :] >= 0                                   # (1, C) pad
        if causal:
            keep = keep & (q_pos[:, None] >= pj[None, :])         # (S, C)
        if window:
            keep = keep & (q_pos[:, None] - pj[None, :] < window)
        s = jnp.where(keep[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgsc,bckh->bkgsh", p, vj.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)             # (B,S,K,G,h)


def _attn_dispatch(q, k, v, q_pos, kv_pos, scale, causal, window, impl):
    T = k.shape[1]
    if impl == "auto":
        impl = "naive" if T <= 4096 else "chunked"
    if impl == "chunked":
        return _masked_attn_chunked(q, k, v, q_pos, kv_pos, scale, causal, window)
    keep = jnp.ones((q.shape[1], T), bool)
    if causal:
        keep = keep & (q_pos[:, None] >= kv_pos[None, :])
    if window:
        keep = keep & (q_pos[:, None] - kv_pos[None, :] < window)
    keep = keep & (kv_pos >= 0)[None, :]
    return _masked_attn_naive(q, k, v, keep, scale)


# --------------------------------------------------------------------------
# GQA forward
# --------------------------------------------------------------------------


def _gqa_qkv(p, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = shard_act(q, "batch", _attn_seq_axis(cfg), "kv_heads", "heads",
                  "head_dim")
    if positions is not None:          # rope (not for enc-dec abs-pos stubs)
        B, S, K, G, h = q.shape
        q = apply_rope(q.reshape(B, S, K * G, h), positions, cfg.rope_theta
                       ).reshape(B, S, K, G, h)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(p, cfg: ModelConfig, x, positions, *, causal=True, window=0,
                impl=None, return_cache=False):
    """Train/prefill path. x (B,S,D); positions (S,). Returns (out, cache|None)."""
    impl = impl or cfg.attention_impl
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    kv_pos = positions if positions is not None else jnp.arange(k.shape[1])
    q_pos = kv_pos
    o = _attn_dispatch(q, k, v, q_pos, kv_pos, scale, causal, window, impl)
    out = jnp.einsum("bskgh,kghd->bsd", o, p["wo"])
    out = shard_act(out, "batch", "seq", None)
    cache = {"k": k, "v": v} if return_cache else None
    return out, cache


def gqa_decode(p, cfg: ModelConfig, x1, pos, cache, *, window=0):
    """One-token decode. x1 (B,1,D); pos scalar int32; cache k/v (B,C,K,h)."""
    B = x1.shape[0]
    q = jnp.einsum("bsd,dkgh->bskgh", x1, p["wq"])
    k1 = jnp.einsum("bsd,dkh->bskh", x1, p["wk"])
    v1 = jnp.einsum("bsd,dkh->bskh", x1, p["wv"])
    if cfg.qkv_bias:
        q, k1, v1 = q + p["bq"], k1 + p["bk"], v1 + p["bv"]
    posv = jnp.full((1,), pos, jnp.int32)
    K, G, h = q.shape[2], q.shape[3], q.shape[4]
    q = apply_rope(q.reshape(B, 1, K * G, h), posv, cfg.rope_theta).reshape(B, 1, K, G, h)
    k1 = apply_rope(k1, posv, cfg.rope_theta)

    C = cache["k"].shape[1]
    slot = pos % C if window else jnp.minimum(pos, C - 1)
    k = jax.lax.dynamic_update_slice(cache["k"], k1, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v1, (0, slot, 0, 0))
    cache_ax = _attn_seq_axis(cfg)       # flash-decode: shard cache seq when
    k = shard_act(k, "batch", cache_ax, "kv_heads", "head_dim")  # heads can't
    v = shard_act(v, "batch", cache_ax, "kv_heads", "head_dim")

    idx = jnp.arange(C)
    if window:
        kv_pos = pos - ((pos - idx) % C)          # ring-buffer true positions
        kv_pos = jnp.where(kv_pos >= 0, kv_pos, -1)
    else:
        kv_pos = jnp.where(idx <= pos, idx, -1)

    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    s = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    s = jnp.where((kv_pos >= 0)[None, None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", pattn.astype(v.dtype), v)
    out = jnp.einsum("bskgh,kghd->bsd", o, p["wo"])
    return out, {"k": k, "v": v}


def gqa_cache_shape(cfg: ModelConfig, batch: int, seq: int, window=0):
    C = min(seq, window) if window else seq
    hd = cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, C, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((batch, C, cfg.n_kv_heads, hd), jnp.bfloat16),
    }


# --------------------------------------------------------------------------
# Cross-attention (enc-dec): kv from encoder memory, no rope, no mask
# --------------------------------------------------------------------------


def cross_kv(p, memory):
    """Precompute cross-attention K/V once per request (cached for decode)."""
    k = jnp.einsum("bmd,dkh->bmkh", memory, p["wk"])
    v = jnp.einsum("bmd,dkh->bmkh", memory, p["wv"])
    return k, v


def cross_forward(p, cfg: ModelConfig, x, memory=None, kv=None):
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
    if kv is None:
        kv = cross_kv(p, memory)
    k, v = kv
    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    o = _masked_attn_naive(q, k, v, None, scale)
    return jnp.einsum("bskgh,kghd->bsd", o, p["wo"])


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# --------------------------------------------------------------------------


def _mla_q(p, cfg, x, positions):
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", cq, p["wuq"])        # e = nope + rope
    qn = q[..., : cfg.qk_nope_head_dim]
    qr = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, cfg.rope_theta)
    return qn, qr


def _mla_latent(p, cfg, x, positions):
    ckr = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    c = rms_norm(ckr[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr = ckr[..., cfg.kv_lora_rank:]                     # (B,S,rope) shared
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, kr


def mla_forward(p, cfg: ModelConfig, x, positions, *, impl=None,
                return_cache=False):
    """Train/prefill: expand k,v from the latent; grouped layout K=H, G=1."""
    impl = impl or cfg.attention_impl
    qn, qr = _mla_q(p, cfg, x, positions)
    c, kr = _mla_latent(p, cfg, x, positions)
    kn = jnp.einsum("bsr,rhe->bshe", c, p["wuk"])
    v = jnp.einsum("bsr,rhe->bshe", c, p["wuv"])
    q = jnp.concatenate([qn, qr], axis=-1)[:, :, :, None, :]      # (B,S,H,1,e)
    k = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :],
                                              kn.shape[:3] + (cfg.qk_rope_head_dim,))],
                        axis=-1)
    q = shard_act(q, "batch", "seq", "kv_heads", None, None)
    k = shard_act(k, "batch", "seq", "kv_heads", None)
    scale = 1.0 / ((cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** 0.5)
    pos = positions
    o = _attn_dispatch(q, k, v, pos, pos, scale, True, 0, impl)   # (B,S,H,1,vh)
    out = jnp.einsum("bshv,hvd->bsd", o[:, :, :, 0, :], p["wo"])
    out = shard_act(out, "batch", "seq", None)
    cache = {"c": c, "kr": kr} if return_cache else None
    return out, cache


def mla_decode(p, cfg: ModelConfig, x1, pos, cache):
    """Weight-absorbed decode: score against the latent cache directly."""
    B = x1.shape[0]
    posv = jnp.full((1,), pos, jnp.int32)
    qn, qr = _mla_q(p, cfg, x1, posv)                    # (B,1,H,·)
    c1, kr1 = _mla_latent(p, cfg, x1, posv)
    C = cache["c"].shape[1]
    c = jax.lax.dynamic_update_slice(cache["c"], c1, (0, pos, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"], kr1, (0, pos, 0))

    # absorb W_uk into q: q_eff (B,1,H,r) = qn @ W_uk^T
    q_eff = jnp.einsum("bshe,rhe->bshr", qn, p["wuk"])
    s = jnp.einsum("bshr,btr->bhst", q_eff, c) + \
        jnp.einsum("bshe,bte->bhst", qr, kr)
    scale = 1.0 / ((cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** 0.5)
    s = s.astype(jnp.float32) * scale
    idx = jnp.arange(C)
    s = jnp.where((idx <= pos)[None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", pattn.astype(c.dtype), c)   # (B,1,H,r)
    o = jnp.einsum("bshr,rhv->bshv", o_lat, p["wuv"])
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, {"c": c, "kr": kr}


def mla_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    return {
        "c": jax.ShapeDtypeStruct((batch, seq, cfg.kv_lora_rank), jnp.bfloat16),
        "kr": jax.ShapeDtypeStruct((batch, seq, cfg.qk_rope_head_dim), jnp.bfloat16),
    }


# --------------------------------------------------------------------------
# unified entry points
# --------------------------------------------------------------------------


def attention_forward(p, cfg: ModelConfig, x, positions, *, causal=True,
                      window=0, return_cache=False):
    if cfg.attention == "mla":
        return mla_forward(p, cfg, x, positions, return_cache=return_cache)
    return gqa_forward(p, cfg, x, positions, causal=causal, window=window,
                       return_cache=return_cache)


def attention_decode(p, cfg: ModelConfig, x1, pos, cache, *, window=0):
    if cfg.attention == "mla":
        return mla_decode(p, cfg, x1, pos, cache)
    return gqa_decode(p, cfg, x1, pos, cache, window=window)


def attention_cache_shape(cfg: ModelConfig, batch: int, seq: int, window=0):
    if cfg.attention == "mla":
        return mla_cache_shape(cfg, batch, seq)
    return gqa_cache_shape(cfg, batch, seq, window=window)
