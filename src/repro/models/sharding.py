"""Logical-axis -> mesh-axis resolution.

Params and activations carry *logical* axis names ("embed", "mlp", "heads",
"vocab", "experts", "batch", "seq", ...). `AxisRules` maps each logical name
to a mesh axis (or tuple of axes). `resolve_spec` greedily assigns mesh axes
left-to-right over a tensor's dims, dropping an assignment when

  (a) the mesh axis is already used by an earlier dim of the same tensor, or
  (b) the dim size does not divide the mesh-axis size.

Rule (b) is what makes one rule-set serve all 10 archs: qwen1.5's 40 heads or
granite-34b's single KV head simply fall back to replication on that dim.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisEntry = Union[str, Tuple[str, ...], None]

# Default rules for the production meshes. `batch` spans the pure-data axes
# (pod + data on the multi-pod mesh); `embed` is the FSDP/ZeRO-3 param axis.
DEFAULT_PARAM_RULES: Dict[str, AxisEntry] = {
    "embed": "data",        # FSDP: shard d_model of weights over data
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "experts": "model",
    # q_lora is a CONTRACTION dim of the up-projections; sharding it forces
    # an all-reduce of the full (B,S,H,e) q tensor every layer (§Perf iter 3)
    "q_lora": None,
    "kv_lora": None,
    "head_dim": None,
    "state": None,
    "stack": None,          # layer-stack axis of scanned params
}

DEFAULT_ACT_RULES: Dict[str, AxisEntry] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "experts": "model",
    "cap": None,
    "head_dim": None,
    "state": None,
    "seq_model": "model",   # sequence-parallel attention (qwen / long ctx)
}


@dataclasses.dataclass
class AxisRules:
    mesh: Mesh
    param_rules: Dict[str, AxisEntry]
    act_rules: Dict[str, AxisEntry]

    def axis_size(self, entry: AxisEntry) -> int:
        if entry is None:
            return 1
        names = (entry,) if isinstance(entry, str) else entry
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n


def make_rules(mesh: Mesh,
               param_overrides: Optional[Dict[str, AxisEntry]] = None,
               act_overrides: Optional[Dict[str, AxisEntry]] = None) -> AxisRules:
    pr = dict(DEFAULT_PARAM_RULES)
    ar = dict(DEFAULT_ACT_RULES)
    mesh_axes = set(mesh.axis_names)
    if "pod" not in mesh_axes:
        ar["batch"] = "data"
    else:
        # on multi-pod meshes, shard FSDP params over (pod, data)
        pr["embed"] = ("pod", "data")
    if param_overrides:
        pr.update(param_overrides)
    if act_overrides:
        ar.update(act_overrides)
    return AxisRules(mesh=mesh, param_rules=pr, act_rules=ar)


def resolve_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 rules: Dict[str, AxisEntry], ar: AxisRules, fill=None) -> P:
    """Greedy left-to-right assignment with divisibility + reuse checks.

    `fill` is what unresolved dims get: None (replicated — params, which must
    be fully specified for in_shardings) or P.UNCONSTRAINED (activations —
    let GSPMD propagate from the weights, e.g. grok's 8 kv-heads on a 16-way
    model axis).
    """
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        entry = rules.get(name) if name else None
        if entry is None:
            parts.append(fill)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        # drop axes already used by this tensor
        names = tuple(a for a in names if a not in used and a in ar.mesh.shape)
        size = 1
        for a in names:
            size *= ar.mesh.shape[a]
        if not names or size <= 1 or dim % size != 0:
            parts.append(fill)
            continue
        used.update(names)
        parts.append(names[0] if len(names) == 1 else names)
    return P(*parts)


def param_sharding(params, logical, rules: AxisRules):
    """NamedSharding tree for a param tree + its logical tree (string leaves,
    see common.log_str; scalars with empty logical are replicated)."""
    from repro.models.common import log_parse

    def one(arr, log):
        axes = log_parse(log) if isinstance(log, str) else tuple(log)
        if len(axes) != len(arr.shape):
            axes = (None,) * len(arr.shape)
        spec = resolve_spec(arr.shape, axes, rules.param_rules, rules)
        return NamedSharding(rules.mesh, spec)
    return jax.tree.map(one, params, logical)


# --------------------------------------------------------------------------
# Activation constraints — a thread-local rules context so model code can be
# written once and run with or without a mesh (CPU smoke tests set no rules).
# --------------------------------------------------------------------------

_CTX = threading.local()


class use_rules:
    def __init__(self, rules: Optional[AxisRules]):
        self.rules = rules

    def __enter__(self):
        self.prev = getattr(_CTX, "rules", None)
        _CTX.rules = self.rules
        return self.rules

    def __exit__(self, *exc):
        _CTX.rules = self.prev
        return False


def current_rules() -> Optional[AxisRules]:
    return getattr(_CTX, "rules", None)


def shard_act(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding; no-op outside a rules context."""
    rules = current_rules()
    if rules is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"logical {logical} vs shape {x.shape}")
    spec = resolve_spec(x.shape, logical, rules.act_rules, rules,
                        fill=P.UNCONSTRAINED)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
