"""Shared building blocks for the LM substrate.

Parameters are built as `PL(arr, logical)` pairs — a single source of truth
for both the value tree and the logical-axis tree (used by
`repro.models.sharding` to derive PartitionSpecs). `split_pl` separates them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Param-with-logical-axes leaves
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PL:
    """A parameter leaf: value (or ShapeDtypeStruct) + logical axis names."""
    arr: Any
    logical: Tuple[Optional[str], ...]


def is_pl(x) -> bool:
    return isinstance(x, PL)


def log_str(logical: Tuple[Optional[str], ...]) -> str:
    """Encode logical axes as a '|'-joined string (strings are pytree LEAVES,
    tuples are not — this keeps the logical tree congruent to the param tree)."""
    return "|".join(a or "" for a in logical)


def log_parse(s: str) -> Tuple[Optional[str], ...]:
    return tuple(a if a else None for a in s.split("|")) if s else ()


def split_pl(tree):
    """(params, logical) trees from a tree of PL leaves."""
    params = jax.tree.map(lambda l: l.arr, tree, is_leaf=is_pl)
    logical = jax.tree.map(lambda l: log_str(l.logical), tree, is_leaf=is_pl)
    return params, logical


class Maker:
    """Deterministic param factory: splits keys, applies fan-in init."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self.key = key
        self.dtype = dtype

    def _next(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def w(self, shape: Sequence[int], logical: Sequence[Optional[str]],
          fan_in: Optional[int] = None, scale: float = 1.0) -> PL:
        assert len(shape) == len(logical), (shape, logical)
        fi = fan_in if fan_in is not None else shape[0]
        std = scale / math.sqrt(max(fi, 1))
        arr = (jax.random.normal(self._next(), tuple(shape), jnp.float32) * std
               ).astype(self.dtype)
        return PL(arr, tuple(logical))

    def z(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> PL:
        assert len(shape) == len(logical)
        return PL(jnp.zeros(tuple(shape), self.dtype), tuple(logical))

    def ones(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> PL:
        assert len(shape) == len(logical)
        return PL(jnp.ones(tuple(shape), self.dtype), tuple(logical))

    def const(self, value, logical: Sequence[Optional[str]]) -> PL:
        arr = jnp.asarray(value, self.dtype)
        return PL(arr, tuple(logical))


# --------------------------------------------------------------------------
# Numerics
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.silu(x_gate.astype(jnp.float32)).astype(x_gate.dtype) * x_up


def geglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.gelu(x_gate.astype(jnp.float32)).astype(x_gate.dtype) * x_up


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) rotated pairwise; positions: (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits (..., V) fp-any, labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def positional_encoding(x: jax.Array, n_bands: int) -> jax.Array:
    """NeRF-style PE: concat(x, sin/cos(2^i x)) — also used by the color MLP."""
    outs = [x]
    for i in range(n_bands):
        outs.append(jnp.sin((2.0 ** i) * x))
        outs.append(jnp.cos((2.0 ** i) * x))
    return jnp.concatenate(outs, axis=-1)
