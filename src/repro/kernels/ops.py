"""jit'd public wrappers for the Pallas kernels with platform dispatch.

On TPU the compiled kernels run natively; on CPU we validate in interpret
mode (`force="pallas"`) or fall back to the jnp oracle (`force="ref"`,
default on CPU — interpret mode is for correctness, not speed).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import fused_sample, ref
from repro.kernels.bitmap_decode import bitmap_gather as _bitmap_gather_pallas
from repro.kernels.bitmap_decode import bitmap_matmul as _bitmap_pallas
from repro.kernels.coo_gather import coo_gather as _coo_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.volume_render import volume_render as _vr_pallas


def _mode(force: Optional[str]) -> str:
    if force:
        return force
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("cols", "force"))
def bitmap_matmul(words, rowptr, values, x, *, cols: int,
                  force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return ref.bitmap_decode_matmul_ref(words, rowptr, values, x, cols)
    return _bitmap_pallas(words, rowptr, values, x, cols=cols,
                          interpret=(jax.default_backend() != "tpu"))


@functools.partial(jax.jit, static_argnames=("cols", "force"))
def bitmap_gather(words, rowptr, values, queries, *, cols: int,
                  force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return ref.bitmap_gather_ref(words, rowptr, values, queries, cols)
    return _bitmap_gather_pallas(words, rowptr, values, queries, cols=cols,
                                 interpret=(jax.default_backend() != "tpu"))


@functools.partial(jax.jit, static_argnames=("force",))
def coo_gather(coords, values, queries, *, force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return ref.coo_gather_ref(coords, values, queries)
    return _coo_pallas(coords, values, queries,
                       interpret=(jax.default_backend() != "tpu"))


def fused_mode(force: Optional[str] = None) -> str:
    """Dispatch mode for the fused decode-sample-accumulate path: "fused"
    (Pallas kernel; interpret off-TPU), "fused_ref" (jnp oracle, the CPU
    serving default), or whatever explicit mode `force` names ("per-op"
    makes core/tensorf fall back to the per-op gather composition). The
    per-op force vocabulary maps onto its fused equivalents so callers can
    use one force string for the whole hybrid eval."""
    if force in ("pallas", "fused"):
        return "fused"
    if force in ("ref", "fused_ref"):
        return "fused_ref"
    if force:
        return force
    return "fused" if jax.default_backend() == "tpu" else "fused_ref"


fused_supported = fused_sample.fused_supported


@functools.partial(jax.jit, static_argnames=(
    "spec", "grid_res", "scene_bound", "window", "app_dim", "force"))
def fused_sigma_app(spec, streams, basis, pts, cube_base, cube_id, *,
                    grid_res: int, scene_bound: float, window: int,
                    app_dim: int, force: Optional[str] = None):
    """(sigma_raw, feat) straight from the encoded factor streams — the
    fused decode-sample-accumulate kernel (kernels/fused_sample.py). `spec`
    is the static factor-structure tuple from tensorf.fused_field_inputs;
    it participates in the jit key, so hot-swapped fields with the same
    encoded structure reuse the compiled step."""
    m = fused_mode(force)
    if m == "fused_ref":
        return fused_sample.fused_sigma_app_ref(
            spec, streams, basis, pts, cube_base, cube_id,
            grid_res=grid_res, scene_bound=scene_bound, window=window,
            app_dim=app_dim)
    return fused_sample.fused_sigma_app(
        spec, streams, basis, pts, cube_base, cube_id,
        grid_res=grid_res, scene_bound=scene_bound, window=window,
        app_dim=app_dim, interpret=(jax.default_backend() != "tpu"))


@functools.partial(jax.jit, static_argnames=("delta", "term_eps", "force"))
def volume_render(sigma, rgb, *, delta: float, term_eps: float = 1e-4,
                  force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return ref.volume_render_ref(sigma, rgb, delta, term_eps)
    return _vr_pallas(sigma, rgb, delta=delta, term_eps=term_eps,
                      interpret=(jax.default_backend() != "tpu"))


@functools.partial(jax.jit, static_argnames=("causal", "force"))
def flash_attention(q, k, v, *, causal: bool = True,
                    force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_pallas(q, k, v, causal=causal,
                         interpret=(jax.default_backend() != "tpu"))
