"""Pallas TPU kernel: bitmap-encoded sparse matmul y = W @ x (paper H2,
"high-density sparse search unit", TPU-native form — DESIGN.md §3).

HBM holds only the *compressed* stream (uint32 bitmap words + row pointers +
packed non-zeros). Each grid step DMAs one row-block into VMEM, reconstructs
the dense row-block with a vectorised prefix-popcount (the ASIC's fixed
3-cycle search becomes a fixed per-tile decode), and feeds the MXU. The
memory-roofline win is the compression ratio; compute stays dense.

The packed-value expansion is a dynamic VMEM gather — supported in interpret
mode (our validation target) and on Mosaic TPU v4+; the oracle is
ref.bitmap_decode_matmul_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8
DEFAULT_BLOCK_Q = 512


def _kernel(words_ref, rowptr_ref, values_ref, x_ref, y_ref, *, cols: int):
    words = words_ref[...]                          # (BR, cols//32) uint32
    br = words.shape[0]
    rep = jnp.repeat(words, 32, axis=1)[:, :cols]   # static expand
    shift = (jnp.arange(cols, dtype=jnp.uint32) % 32)[None, :]
    bits = ((rep >> shift) & jnp.uint32(1)).astype(jnp.int32)   # (BR, cols)
    prefix = jnp.cumsum(bits, axis=1) - bits        # nnz before (r, c)
    addr = rowptr_ref[...][:, None] + prefix
    nv = values_ref.shape[0]
    vals = jnp.take(values_ref[...], jnp.clip(addr, 0, nv - 1).reshape(-1)
                    ).reshape(br, cols)
    w = jnp.where(bits > 0, vals, 0).astype(x_ref.dtype)
    y_ref[...] = jnp.dot(w, x_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(y_ref.dtype)


def _gather_kernel(words_ref, rowptr_ref, values_ref, q_ref, out_ref, *,
                   cols: int):
    """Random-access block: per query lane, bit test + prefix-popcount over
    the query row's bitmap words (the ASIC's fixed 3-cycle search)."""
    words = words_ref[...]                           # (rows, W) in VMEM
    q = q_ref[...]
    r = q // cols
    c = q % cols
    wi = (c // 32).astype(jnp.int32)
    bi = (c % 32).astype(jnp.uint32)
    qwords = jnp.take(words, r, axis=0)              # (BQ, W)
    widx = jnp.arange(words.shape[1], dtype=jnp.int32)[None, :]
    below = jnp.left_shift(jnp.uint32(1), bi) - jnp.uint32(1)
    mask = jnp.where(widx < wi[:, None], jnp.uint32(0xFFFFFFFF),
                     jnp.where(widx == wi[:, None], below[:, None],
                               jnp.uint32(0)))
    prefix = jnp.sum(jax.lax.population_count(qwords & mask), axis=1)
    word_at = jnp.take(words.reshape(-1), r * words.shape[1] + wi)
    bit = (word_at >> bi) & jnp.uint32(1)
    addr = jnp.take(rowptr_ref[...], r) + prefix.astype(jnp.int32)
    nv = values_ref.shape[0]
    vals = jnp.take(values_ref[...], jnp.clip(addr, 0, nv - 1))
    out_ref[...] = jnp.where(bit > 0, vals, 0).astype(out_ref.dtype)


def bitmap_gather(words: jax.Array, rowptr: jax.Array, values: jax.Array,
                  queries: jax.Array, *, cols: int,
                  block_q: int = DEFAULT_BLOCK_Q,
                  interpret: bool = True) -> jax.Array:
    """values of the encoded matrix at linear indices `queries` (0 at zeros).

    The whole compressed stream (bitmap words + rowptr + packed values) sits
    in VMEM; each grid step serves one query block. Interpret mode is the
    CPU validation target; the oracle is ref.bitmap_gather_ref.
    """
    nq = queries.shape[0]
    bq = min(block_q, nq)
    assert nq % bq == 0, (nq, bq)
    return pl.pallas_call(
        functools.partial(_gather_kernel, cols=cols),
        grid=(nq // bq,),
        in_specs=[
            pl.BlockSpec(words.shape, lambda i: (0, 0)),
            pl.BlockSpec((rowptr.shape[0],), lambda i: (0,)),
            pl.BlockSpec((values.shape[0],), lambda i: (0,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), values.dtype),
        interpret=interpret,
    )(words, rowptr, values, queries)


def bitmap_matmul(words: jax.Array, rowptr: jax.Array, values: jax.Array,
                  x: jax.Array, *, cols: int,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True) -> jax.Array:
    """y = decode(words, rowptr, values) @ x. x (cols, n)."""
    rows = words.shape[0]
    w32 = words.shape[1]
    n = x.shape[1]
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, cols=cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, w32), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((values.shape[0],), lambda i: (0,)),
            pl.BlockSpec((cols, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(words, rowptr, values, x)
