"""Pallas TPU kernel: Eq. 1 front-to-back integration with early-ray-
termination (paper Step 3 + Sec. 3.2 on TPU).

Grid = (ray_blocks, sample_chunks); sample chunks arrive front-to-back (the
view-dependent ordering guarantees this), so the kernel keeps only the
running (log T, partial color) per ray — the paper's "only the partial sum
of the final rendered color needs to be stored". When every ray in the
block is already opaque the whole chunk's math is skipped (`pl.when`), the
TPU-native form of the ASIC's per-point skip (lanes can't diverge; blocks
can).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_RAYS = 128
DEFAULT_CHUNK = 64


def _kernel(sigma_ref, rgb_ref, color_ref, logt_ref, nproc_ref, *,
            delta: float, term_eps: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        color_ref[...] = jnp.zeros_like(color_ref)
        logt_ref[...] = jnp.zeros_like(logt_ref)
        nproc_ref[...] = jnp.zeros_like(nproc_ref)

    log_eps = math.log(term_eps)
    logt = logt_ref[...]                          # (BR, 1) fp32, <= 0
    any_alive = jnp.any(logt > log_eps)

    @pl.when(any_alive)
    def _work():
        sigma = sigma_ref[...].astype(jnp.float32)   # (BR, CS)
        rgb = rgb_ref[...].astype(jnp.float32)       # (BR, CS, 3)
        tau_raw = sigma * delta
        cum_raw = jnp.cumsum(tau_raw, axis=-1)
        t_before = jnp.exp(logt + -(cum_raw - tau_raw))   # (BR, CS)
        alive = t_before > term_eps
        tau = jnp.where(alive, tau_raw, 0.0)
        cum = jnp.cumsum(tau, axis=-1)
        t_b = jnp.exp(logt + -(cum - tau))
        w = t_b * (1.0 - jnp.exp(-tau))
        color_ref[...] += jnp.einsum("rn,rnc->rc", w, rgb)
        logt_ref[...] += -cum[:, -1:]
        nproc_ref[...] += jnp.sum(alive.astype(jnp.float32)).reshape(1, 1)


def volume_render(sigma: jax.Array, rgb: jax.Array, *, delta: float,
                  term_eps: float = 1e-4,
                  block_rays: int = DEFAULT_BLOCK_RAYS,
                  chunk: int = DEFAULT_CHUNK, interpret: bool = True):
    """sigma (R,N), rgb (R,N,3) front-to-back. Returns (color, t_final, nproc)."""
    r, n = sigma.shape
    br = min(block_rays, r)
    cs = min(chunk, n)
    assert r % br == 0 and n % cs == 0, (r, br, n, cs)
    grid = (r // br, n // cs)
    color, logt, nproc = pl.pallas_call(
        functools.partial(_kernel, delta=delta, term_eps=term_eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, cs), lambda i, j: (i, j)),
            pl.BlockSpec((br, cs, 3), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 3), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r // br, 1), jnp.float32),
        ],
        interpret=interpret,
    )(sigma, rgb)
    return color, jnp.exp(logt[:, 0]), jnp.sum(nproc)
