"""Fused decode-sample-accumulate Pallas kernel for the hybrid field
(Potamoi's unified-streaming insight applied to the paper's H1 codec).

One kernel replaces the per-op gather pipeline of the hybrid eval path
(`bitmap_gather` / `coo_gather` called once per factor per stencil batch):
it takes the *encoded* bitmap/COO streams of all twelve TensoRF VM factor
slices, and per block of ray points

  1. **decode** — reconstructs, in VMEM, the small per-cube factor windows
     the block's points can touch: bitmap entries via the O(1) rank-table
     lookup (one rank read + one masked-word popcount, the ASIC's
     fixed-latency search), COO entries via branchless binary search over
     the sorted coordinate stream;
  2. **sample** — interpolates the factored VM grids at the points
     (bilinear on plane windows, linear on line windows), reading only the
     decoded windows;
  3. **accumulate** — folds the Eq. 2 products into the density sum and the
     basis-projected appearance features in place.

No dense factor is ever written back to HBM: the working set per grid step
is the encoded streams plus `C * R * W * W` floats of decoded windows
(C = cubes in flight, W = window span — a few KB), which is the whole
point of streaming the compressed representation.

Layout contract (shared with `core/tensorf.fused_field_inputs` and
`kernels/ops.fused_sigma_app`):

  * `spec` is a flat tuple of 12 factor specs in canonical order —
    sigma_planes[0..2], sigma_lines[0..2], app_planes[0..2],
    app_lines[0..2] — each `(fmt, rows, ncols)` with fmt in
    {"dense", "bitmap", "coo"}. It is static (hashable) and participates in
    jit keys, so a hot-swapped field with the same encoded structure reuses
    the compiled kernel.
  * `streams` is the matching flat tuple of arrays: dense -> (matrix,),
    bitmap -> (words, rank, values) (rank from `core/sparse.bitmap_rank`),
    coo -> (coords, values).
  * Points are grouped by occupancy cube: `cube_base` (C, 3) holds each
    cube's window origin in grid coords, `cube_id` (N,) maps every point to
    its cube. Callers guarantee every *unmasked* point's interpolation
    stencil falls inside its cube's window (`core/tensorf.window_base` /
    `fused_window`); out-of-window points read clipped window entries and
    must be masked out downstream (the render paths multiply them by zero).

Interpret mode is the validated CI target (tests/test_kernels.py fused
parity suite); real Mosaic lowering needs the dynamic-VMEM-gather support
of TPU v4+, same as the per-op kernels. The pure-jnp twin
`fused_sigma_app_ref` is the CPU serving path (dispatched by
kernels/ops.py) and the parity oracle.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_PTS = 1024

# mode m pairs plane axes with line axis (mirrors core/tensorf.py — the
# kernels layer sits below core, so the constant is restated, not imported)
PLANE_AXES = ((1, 2), (0, 2), (0, 1))
LINE_AXES = (0, 1, 2)

STREAMS_PER_FMT = {"dense": 1, "bitmap": 3, "coo": 2}


def fused_supported(spec) -> bool:
    """Whether the fused kernel can serve this field structure. False sends
    the whole eval down the per-op oracle composition in core/tensorf.py
    (the dispatch contract's per-op fallback)."""
    return (len(spec) == 12
            and all(fs[0] in STREAMS_PER_FMT for fs in spec))


def stream_count(spec) -> int:
    return sum(STREAMS_PER_FMT[fs[0]] for fs in spec)


def group_streams(spec, streams):
    """Pair each factor spec with its slice of the flat stream tuple."""
    out, i = [], 0
    for fs in spec:
        k = STREAMS_PER_FMT[fs[0]]
        out.append((fs, tuple(streams[i:i + k])))
        i += k
    if i != len(streams):
        raise ValueError(f"got {len(streams)} stream arrays, spec needs {i}")
    return out


def to_grid(pts, *, grid_res: int, scene_bound: float):
    """World [-bound, bound]^3 -> continuous grid coords [0, G-1] (the same
    mapping as core/tensorf.to_grid, restated for layering)."""
    return (pts / scene_bound * 0.5 + 0.5) * (grid_res - 1)


def _decode_cols(fs, arrs, cols, *, searchsorted: bool):
    """All R rows of one encoded (R, ncols) factor at column indices `cols`
    (K,) -> (R, K), decoded straight from the stream (VMEM when called from
    the kernel body). This is the per-element form of the H1 codec: bitmap
    = rank lookup + single-word popcount, COO = binary search, dense = read.
    """
    fmt, rows, ncols = fs
    if fmt == "dense":
        return jnp.take(arrs[0], cols, axis=1)
    if fmt == "bitmap":
        words, rank, values = arrs
        wi = (cols // 32).astype(jnp.int32)
        bi = (cols % 32).astype(jnp.uint32)
        w = jnp.take(words, wi, axis=1)                      # (R, K)
        rk = jnp.take(rank, wi, axis=1)                      # (R, K)
        below = (jnp.left_shift(jnp.uint32(1), bi)
                 - jnp.uint32(1))[None, :]
        addr = rk + jax.lax.population_count(w & below).astype(jnp.int32)
        bit = (w >> bi[None, :]) & jnp.uint32(1)
        nv = values.shape[0]
        vals = jnp.take(values, jnp.clip(addr, 0, nv - 1).reshape(-1)
                        ).reshape(addr.shape)
        return jnp.where(bit > 0, vals, 0).astype(values.dtype)
    coords, values = arrs                                    # fmt == "coo"
    q = (jnp.arange(rows, dtype=jnp.int32)[:, None] * ncols
         + cols[None, :].astype(jnp.int32))                  # (R, K)
    n = coords.shape[0]
    if searchsorted:                                         # jnp oracle
        lo = jnp.searchsorted(coords, q.reshape(-1)).reshape(
            q.shape).astype(jnp.int32)
    else:                                       # in-kernel: static unroll
        steps = max(int(math.ceil(math.log2(n))), 1) + 1     # lo == hi
        lo = jnp.zeros(q.shape, jnp.int32)
        hi = jnp.full(q.shape, n, jnp.int32)
        for _ in range(steps):
            mid = (lo + hi) // 2
            cm = jnp.take(coords, jnp.clip(mid, 0, n - 1).reshape(-1)
                          ).reshape(mid.shape)
            go_right = cm < q
            lo = jnp.where(go_right, mid + 1, lo)
            hi = jnp.where(go_right, hi, mid)
    safe = jnp.clip(lo, 0, n - 1)
    found = (lo < n) & (jnp.take(coords, safe.reshape(-1)
                                 ).reshape(safe.shape) == q)
    vals = jnp.take(values, safe.reshape(-1)).reshape(safe.shape)
    return jnp.where(found, vals, 0).astype(values.dtype)


def _eval(groups, basis, ptsg, base, cid, *, grid_res: int, window: int,
          app_dim: int, searchsorted: bool):
    """The shared decode-sample-accumulate math (jnp oracle and kernel body
    run this same function; only the COO search strategy differs).

    groups: the 12 (spec, arrays) entries in canonical order; ptsg (N, 3)
    continuous grid coords; base (C, 3) int32 window origins; cid (N,)
    int32 cube ids. Returns (sigma_raw (N,), feat (N, app_dim)) — raw
    pre-softplus density sum and basis-projected appearance features.
    """
    G, W = grid_res, window
    C = base.shape[0]
    n = ptsg.shape[0]
    ii = jnp.arange(W, dtype=jnp.int32)

    # jax.named_scope markers (trace-time only, zero runtime cost) tag the
    # decode / sample / accumulate phases in the HLO so XLA profiler
    # captures line up with the serving spans (docs/observability.md)
    # per-point global stencil — identical arithmetic to the dense path:
    # clip to the grid, floor to the low corner, fractional weights; then
    # shift into window-local coords (clipped: out-of-window points are
    # masked to zero contribution by every caller)
    p = jnp.clip(ptsg, 0.0, G - 1.0)
    p0 = jnp.clip(jnp.floor(p).astype(jnp.int32), 0, G - 2)
    fr = p - p0
    loc = jnp.clip(p0 - jnp.take(base, cid, axis=0), 0, W - 2)   # (N, 3)

    out = jnp.zeros((n, 1 + app_dim), jnp.float32)       # [sigma | feat]
    for m in range(3):
        a, b = PLANE_AXES[m]
        ax = LINE_AXES[m]
        spf, spa = groups[m]            # sigma plane / line, mode m
        slf, sla = groups[3 + m]
        apf, apa = groups[6 + m]        # app plane / line, mode m
        alf, ala = groups[9 + m]
        Rs, Rc = spf[1], apf[1]

        # 1. decode — per-cube factor windows, straight from the encoded
        # streams (bitmap rank lookup / COO binary search). The sigma and
        # app windows of one mode share the same stencil, so they are
        # decoded into ONE (Rs+Rc, ...) block and sampled together —
        # halving the gather count versus evaluating the heads separately
        # (the structural win over the dense two-head baseline).
        with jax.named_scope(f"fused.decode.m{m}"):
            pcols = ((base[:, a, None, None] + ii[None, :, None]) * G
                     + base[:, b, None, None]
                     + ii[None, None, :]).reshape(-1)
            pw = jnp.concatenate([
                _decode_cols(spf, spa, pcols, searchsorted=searchsorted),
                _decode_cols(apf, apa, pcols, searchsorted=searchsorted),
            ]).T                                         # (C*W*W, Rs+Rc)
            lcols = (base[:, ax, None] + ii[None, :]).reshape(-1)
            lw = jnp.concatenate([
                _decode_cols(slf, sla, lcols, searchsorted=searchsorted),
                _decode_cols(alf, ala, lcols, searchsorted=searchsorted),
            ]).T                                         # (C*W, Rs+Rc)

        # 2. sample — bilinear on the plane window, linear on the line.
        # Windows are transposed to (cells, R) BEFORE the gathers so each
        # of the N stencil reads pulls one contiguous R-length row —
        # row-gathers on the small window are the cheap orientation;
        # column-gathers (stride R) measured ~5x slower on CPU.
        with jax.named_scope(f"fused.sample.m{m}"):
            lu, lv, lx = loc[:, a], loc[:, b], loc[:, ax]
            fu = fr[:, a, None]
            fv = fr[:, b, None]
            fx = fr[:, ax, None]
            i00 = (cid * W + lu) * W + lv
            p00 = jnp.take(pw, i00, axis=0)              # (N, Rs+Rc)
            p01 = jnp.take(pw, i00 + 1, axis=0)
            p10 = jnp.take(pw, i00 + W, axis=0)
            p11 = jnp.take(pw, i00 + W + 1, axis=0)
            pm = (p00 * (1 - fu) * (1 - fv) + p01 * (1 - fu) * fv
                  + p10 * fu * (1 - fv) + p11 * fu * fv)
            il = cid * W + lx
            lm = (jnp.take(lw, il, axis=0) * (1 - fx)
                  + jnp.take(lw, il + 1, axis=0) * fx)
            comp = pm * lm                               # (N, Rs+Rc)

        # 3. accumulate — ONE matmul folds both heads: the basis slice is
        # extended with a leading ones-column over the sigma rows, so
        # out[:, 0] accumulates the density sum and out[:, 1:] the
        # basis-projected features. Slicing comp into two consumers
        # instead (sum + matmul) makes XLA CPU re-evaluate the whole
        # gather fusion per consumer — measured 6x slower.
        with jax.named_scope(f"fused.accumulate.m{m}"):
            bm = basis[m * Rc:(m + 1) * Rc]              # (Rc, app_dim)
            bext = jnp.concatenate([
                jnp.concatenate(
                    [jnp.ones((Rs, 1), jnp.float32),
                     jnp.zeros((Rs, app_dim), jnp.float32)], axis=1),
                jnp.concatenate(
                    [jnp.zeros((Rc, 1), jnp.float32), bm], axis=1),
            ])                                           # (Rs+Rc, 1+app_dim)
            out = out + jnp.dot(comp, bext,
                                preferred_element_type=jnp.float32)
    return out[:, 0], out[:, 1:]


def fused_sigma_app_ref(spec, streams, basis, pts, cube_base, cube_id, *,
                        grid_res: int, scene_bound: float, window: int,
                        app_dim: int):
    """Pure-jnp twin of the fused kernel: same windows-then-sample math,
    vectorised with plain jnp (COO decode via `searchsorted`). This is both
    the parity oracle for the Pallas kernel and the CPU serving fast path —
    kernels/ops.py dispatches here when the backend is not a TPU."""
    groups = group_streams(spec, streams)
    ptsg = to_grid(pts, grid_res=grid_res, scene_bound=scene_bound)
    return _eval(groups, basis, ptsg, jnp.asarray(cube_base, jnp.int32),
                 jnp.asarray(cube_id, jnp.int32), grid_res=grid_res,
                 window=window, app_dim=app_dim, searchsorted=True)


def _kernel(*refs, spec, n_streams: int, grid_res: int, scene_bound: float,
            window: int, app_dim: int):
    pts_ref, cid_ref, base_ref, basis_ref = refs[:4]
    stream_refs = refs[4:4 + n_streams]
    out_sig_ref, out_feat_ref = refs[4 + n_streams:]
    arrays = tuple(r[...] for r in stream_refs)          # streams in VMEM
    groups = group_streams(spec, arrays)
    ptsg = to_grid(pts_ref[...], grid_res=grid_res, scene_bound=scene_bound)
    sig, feat = _eval(groups, basis_ref[...], ptsg, base_ref[...],
                      cid_ref[...], grid_res=grid_res, window=window,
                      app_dim=app_dim, searchsorted=False)
    out_sig_ref[...] = sig
    out_feat_ref[...] = feat.astype(out_feat_ref.dtype)


def _full(shape):
    """BlockSpec for an array that sits whole in VMEM on every grid step."""
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def fused_sigma_app(spec, streams, basis, pts, cube_base, cube_id, *,
                    grid_res: int, scene_bound: float, window: int,
                    app_dim: int, block_pts: int = DEFAULT_BLOCK_PTS,
                    interpret: bool = True):
    """(sigma_raw (N,), feat (N, app_dim)) for points `pts` (N, 3) grouped
    by cube, evaluated straight from the encoded factor streams.

    Grid is over point blocks; every step holds the full encoded streams in
    VMEM and re-decodes the (small) cube windows — decode cost is
    C*W*W*sum(R) lookups per step, negligible against sampling. (A scratch
    buffer persisting windows across steps would remove even that; left for
    a later PR.) Wrapper pads N to a block multiple and slices the pad off.
    """
    n = pts.shape[0]
    bp = min(block_pts, max(n, 1))
    pad = (-n) % bp
    cube_id = jnp.asarray(cube_id, jnp.int32)
    cube_base = jnp.asarray(cube_base, jnp.int32)
    if pad:
        pts = jnp.concatenate([pts, jnp.zeros((pad, 3), pts.dtype)])
        cube_id = jnp.concatenate([cube_id, jnp.zeros((pad,), jnp.int32)])
    npad = n + pad
    in_specs = ([pl.BlockSpec((bp, 3), lambda i: (i, 0)),
                 pl.BlockSpec((bp,), lambda i: (i,)),
                 _full(cube_base.shape),
                 _full(basis.shape)]
                + [_full(s.shape) for s in streams])
    sig, feat = pl.pallas_call(
        functools.partial(_kernel, spec=spec, n_streams=len(streams),
                          grid_res=grid_res, scene_bound=scene_bound,
                          window=window, app_dim=app_dim),
        grid=(npad // bp,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((bp,), lambda i: (i,)),
                   pl.BlockSpec((bp, app_dim), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((npad,), jnp.float32),
                   jax.ShapeDtypeStruct((npad, app_dim), jnp.float32)],
        interpret=interpret,
    )(pts, cube_id, cube_base, basis, *streams)
    return sig[:n], feat[:n]
