"""Pallas TPU kernel: fused causal flash attention (forward).

Not part of the paper, but the LM substrate's perf-critical hot spot for the
prefill_32k cells; block sizes follow the MXU/VMEM constraints (128-aligned
q/kv blocks, fp32 online-softmax state in VMEM). The pure-XLA chunked path
(models/attention._masked_attn_chunked) is the fallback and oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int):
    i = pl.program_id(1)                      # q block
    j = pl.program_id(2)                      # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    should_run = True
    if causal:
        should_run = (j * block_k) <= (i * block_q + block_q - 1)

    @pl.when(should_run)
    def _work():
        q = q_ref[0].astype(jnp.float32)                 # (bq, d)
        k = k_ref[0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                          (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                          (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q,k,v (B,H,S,hd) -> (B,H,S,hd). Forward only (serving path)."""
    b, h, s, d = q.shape
    sk = k.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, sk)
    assert s % bq == 0 and sk % bk == 0
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    grid = (b * h, s // bq, sk // bk)
    scale = 1.0 / (d ** 0.5)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=bq, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
