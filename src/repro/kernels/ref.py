"""Pure-jnp oracles for every Pallas kernel (shape/dtype-swept in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitmap_decode_matmul_ref(words: jax.Array, rowptr: jax.Array,
                             values: jax.Array, x: jax.Array,
                             cols: int) -> jax.Array:
    """Decode a bitmap-encoded sparse matrix W (rows x cols) and compute W @ x.

    words  (rows, cols//32) uint32; rowptr (rows,) int32;
    values (nnz_pad,)       packed row-major non-zeros;
    x      (cols, n)        dense right-hand side.
    """
    rows = words.shape[0]
    bpos = jnp.arange(cols, dtype=jnp.uint32)
    bits = (words[:, bpos // 32] >> (bpos % 32)) & 1          # (rows, cols)
    bits = bits.astype(jnp.int32)
    prefix = jnp.cumsum(bits, axis=1) - bits
    addr = rowptr[:, None] + prefix
    vals = values[jnp.clip(addr, 0, values.shape[0] - 1)]
    w = jnp.where(bits > 0, vals, 0).astype(x.dtype)          # dense (rows, cols)
    return w @ x


def bitmap_gather_ref(words: jax.Array, rowptr: jax.Array, values: jax.Array,
                      queries: jax.Array, cols: int) -> jax.Array:
    """Random access into a bitmap-encoded (rows, cols) matrix.

    queries (Q,) linear row-major indices. Per query: one bit test plus a
    prefix-popcount over the row's bitmap words — the ASIC's fixed-latency
    search, vectorised over the query block. The math lives in
    core/sparse.bitmap_lookup_linear (the codec's single source of truth).
    """
    from repro.core.sparse import bitmap_lookup_linear
    return bitmap_lookup_linear(words, rowptr, values, queries, cols)


def coo_gather_ref(coords: jax.Array, values: jax.Array,
                   queries: jax.Array) -> jax.Array:
    """Look up linear indices `queries` in a sorted COO stream (0 if absent)."""
    n = coords.shape[0]
    lo = jnp.searchsorted(coords, queries)
    safe = jnp.clip(lo, 0, n - 1)
    found = (lo < n) & (coords[safe] == queries)
    return jnp.where(found, values[safe], 0)


def volume_render_ref(sigma: jax.Array, rgb: jax.Array, delta: float,
                      term_eps: float):
    """Eq. 1 front-to-back with early termination. sigma (R,N); rgb (R,N,3).

    Returns (color (R,3), t_final (R,), processed (scalar)) where `processed`
    counts samples with transmittance-before > term_eps (the points the ASIC
    actually processes).
    """
    tau = sigma.astype(jnp.float32) * delta
    cum = jnp.cumsum(tau, axis=-1)
    t_before = jnp.exp(-(cum - tau))
    alive = t_before > term_eps
    tau = jnp.where(alive, tau, 0.0)
    cum = jnp.cumsum(tau, axis=-1)
    t_before = jnp.exp(-(cum - tau))
    alpha = 1.0 - jnp.exp(-tau)
    w = t_before * alpha
    color = jnp.einsum("rn,rnc->rc", w, rgb.astype(jnp.float32))
    t_final = jnp.exp(-cum[:, -1])
    return color, t_final, jnp.sum(alive.astype(jnp.float32))


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Plain softmax attention. q,k,v (B,H,S,hd) -> (B,H,S,hd)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / (q.shape[-1] ** 0.5)
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
