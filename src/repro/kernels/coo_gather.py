"""Pallas TPU kernel: COO lookup via branchless binary search (paper H2,
"dual-purpose bi-direction adder & search tree", TPU-native form).

The ASIC's binary search *tree* becomes a data-parallel binary *search*:
each of the Q lanes in a query block walks log2(nnz) halving steps over the
sorted coordinate stream held in VMEM (>=80% sparsity means the compressed
stream is small). Absent coordinates return 0 — exactly the ASIC's
"search result is zero" path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 512


def _kernel(coords_ref, values_ref, q_ref, out_ref, *, steps: int):
    coords = coords_ref[...]
    n = coords.shape[0]
    q = q_ref[...]
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, n, jnp.int32)
    for _ in range(steps):                          # static unroll: log2(n)
        mid = (lo + hi) // 2
        cm = jnp.take(coords, jnp.clip(mid, 0, n - 1))
        go_right = cm < q
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    safe = jnp.clip(lo, 0, n - 1)
    found = (lo < n) & (jnp.take(coords, safe) == q)
    vals = jnp.take(values_ref[...], safe)
    out_ref[...] = jnp.where(found, vals, 0).astype(out_ref.dtype)


def coo_gather(coords: jax.Array, values: jax.Array, queries: jax.Array, *,
               block_q: int = DEFAULT_BLOCK_Q,
               interpret: bool = True) -> jax.Array:
    """values at `queries` (sorted linear coords; 0 where absent)."""
    nq = queries.shape[0]
    bq = min(block_q, nq)
    assert nq % bq == 0, (nq, bq)
    steps = max(int(math.ceil(math.log2(coords.shape[0]))), 1) + 1  # lo==hi
    return pl.pallas_call(
        functools.partial(_kernel, steps=steps),
        grid=(nq // bq,),
        in_specs=[
            pl.BlockSpec((coords.shape[0],), lambda i: (0,)),
            pl.BlockSpec((values.shape[0],), lambda i: (0,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), values.dtype),
        interpret=interpret,
    )(coords, values, queries)
