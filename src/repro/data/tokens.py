"""Deterministic synthetic token pipeline for LM training.

Offline container -> tokens come from a splittable counter-based generator
(threefry via jax.random, keyed by (shard, step)), so every data-parallel
host produces a disjoint, reproducible stream without coordination — the
same property a production sharded-file loader gives you. Restart-safety:
the stream is a pure function of step, so checkpoint restore resumes the
exact batch sequence (exactly-once semantics without a data journal).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class TokenStream:
    cfg: ModelConfig
    shape: ShapeConfig
    n_shards: int = 1
    shard: int = 0
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jax.Array]:
        """The global batch for `step` (host slice when n_shards > 1)."""
        b = self.shape.global_batch // self.n_shards
        s = self.shape.seq_len
        cfg = self.cfg
        key = jax.random.PRNGKey(self.seed)
        key = jax.random.fold_in(key, step)
        key = jax.random.fold_in(key, self.shard)
        n_front = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
        n_text = s - n_front
        toks = jax.random.randint(key, (b, n_text + 1), 0, cfg.vocab,
                                  dtype=jnp.int32)
        out: Dict[str, jax.Array] = {
            "tokens": toks[:, :-1],
        }
        labels = toks[:, 1:]
        mask = jnp.ones((b, n_text), jnp.float32)
        if n_front:
            out["frontend"] = jax.random.normal(
                jax.random.fold_in(key, 1), (b, n_front, cfg.d_model),
                jnp.bfloat16)
            labels = jnp.concatenate(
                [jnp.zeros((b, n_front), jnp.int32), labels], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((b, n_front), jnp.float32), mask], axis=1)
        if cfg.enc_dec:
            out["enc_frames"] = jax.random.normal(
                jax.random.fold_in(key, 2), (b, s, cfg.d_model), jnp.bfloat16)
        out["labels"] = labels
        out["loss_mask"] = mask
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one global batch (dry-run inputs)."""
    b, s = shape.global_batch, shape.seq_len
    n_front = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((b, s - n_front), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    if n_front:
        specs["frontend"] = jax.ShapeDtypeStruct((b, n_front, cfg.d_model),
                                                 jnp.bfloat16)
    if cfg.enc_dec:
        specs["enc_frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
    if shape.kind != "train":
        specs.pop("labels")
        specs.pop("loss_mask")
    return specs


def input_logical(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, str]:
    """Logical axes for the batch inputs (resolved to in_shardings)."""
    log = {"tokens": "batch|seq", "labels": "batch|seq",
           "loss_mask": "batch|seq"}
    if cfg.frontend == "vision":
        log["frontend"] = "batch|seq|"
    if cfg.enc_dec:
        log["enc_frames"] = "batch|seq|"
    if shape.kind != "train":
        log.pop("labels")
        log.pop("loss_mask")
    return log
