"""Procedural Synthetic-NeRF-like scenes + posed views + ray batches.

The container is offline, so the 8 Blender scenes are replaced by analytic
SDF scenes (named after the originals) with a sphere-traced ground-truth
renderer. Scenes are constructed to span a wide occupancy/factor sparsity
range (ficus/mic/materials sparse -> lego/ship dense), which is what the
paper's Fig. 5 / hybrid-encoding experiments need.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.core.rendering import Camera, camera_rays, look_at_camera

SPHERE, BOX, CYL = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class Scene:
    name: str
    prim_type: np.ndarray    # (P,) int
    center: np.ndarray       # (P,3)
    size: np.ndarray         # (P,3) radii / half-extents / (r, h, -)
    color: np.ndarray        # (P,3)


def _mk(name, prims) -> Scene:
    t = np.array([p[0] for p in prims], np.int32)
    c = np.array([p[1] for p in prims], np.float32)
    s = np.array([p[2] for p in prims], np.float32)
    col = np.array([p[3] for p in prims], np.float32)
    return Scene(name, t, c, s, col)


def make_scene(name: str) -> Scene:
    """8 scenes named after Synthetic-NeRF, ordered sparse -> dense."""
    rng = np.random.RandomState(abs(hash(name)) % (2 ** 31))
    if name == "mic":          # very sparse: thin stand + small head
        return _mk(name, [
            (SPHERE, [0, 0, 0.7], [0.18, 0, 0], [0.8, 0.8, 0.85]),
            (CYL, [0, 0, -0.1], [0.04, 0.75, 0], [0.3, 0.3, 0.32]),
            (BOX, [0, 0, -0.9], [0.3, 0.3, 0.05], [0.2, 0.2, 0.22]),
        ])
    if name == "materials":    # sparse row of spheres
        prims = []
        for i in range(6):
            x = -1.1 + i * 0.44
            prims.append((SPHERE, [x, 0, -0.6], [0.2, 0, 0],
                          [0.2 + 0.13 * i, 0.9 - 0.12 * i, 0.4]))
        return _mk(name, prims)
    if name == "ficus":        # thin trunk + leaf blobs
        prims = [(CYL, [0, 0, -0.4], [0.05, 0.55, 0], [0.45, 0.3, 0.15])]
        for i in range(9):
            a = rng.rand() * 2 * np.pi
            r = 0.25 + 0.45 * rng.rand()
            z = 0.15 + 0.75 * rng.rand()
            prims.append((SPHERE, [r * np.cos(a), r * np.sin(a), z],
                          [0.13, 0, 0], [0.1, 0.5 + 0.3 * rng.rand(), 0.12]))
        return _mk(name, prims)
    if name == "drums":
        return _mk(name, [
            (CYL, [-0.5, 0.3, -0.45], [0.38, 0.22, 0], [0.85, 0.2, 0.2]),
            (CYL, [0.5, 0.3, -0.45], [0.38, 0.22, 0], [0.2, 0.3, 0.85]),
            (CYL, [0, -0.5, -0.35], [0.45, 0.3, 0], [0.9, 0.75, 0.2]),
            (SPHERE, [-0.75, -0.5, 0.3], [0.22, 0, 0], [0.9, 0.85, 0.3]),
            (SPHERE, [0.75, -0.5, 0.3], [0.22, 0, 0], [0.9, 0.85, 0.3]),
        ])
    if name == "chair":
        return _mk(name, [
            (BOX, [0, 0, -0.25], [0.45, 0.45, 0.07], [0.6, 0.35, 0.15]),
            (BOX, [0, 0.42, 0.35], [0.45, 0.06, 0.55], [0.65, 0.4, 0.2]),
            (BOX, [-0.38, -0.38, -0.7], [0.06, 0.06, 0.4], [0.35, 0.2, 0.1]),
            (BOX, [0.38, -0.38, -0.7], [0.06, 0.06, 0.4], [0.35, 0.2, 0.1]),
            (BOX, [-0.38, 0.38, -0.7], [0.06, 0.06, 0.4], [0.35, 0.2, 0.1]),
            (BOX, [0.38, 0.38, -0.7], [0.06, 0.06, 0.4], [0.35, 0.2, 0.1]),
        ])
    if name == "hotdog":
        return _mk(name, [
            (BOX, [0, 0, -0.55], [0.9, 0.55, 0.08], [0.92, 0.92, 0.9]),
            (CYL, [0, -0.12, -0.32], [0.16, 0.65, 1], [0.85, 0.6, 0.3]),
            (CYL, [0, 0.12, -0.32], [0.16, 0.65, 1], [0.85, 0.6, 0.3]),
            (CYL, [0, 0, -0.22], [0.12, 0.6, 1], [0.7, 0.25, 0.1]),
        ])
    if name == "lego":         # dense: grid of bricks
        prims = []
        for i in range(4):
            for j in range(3):
                z = -0.6 + 0.28 * (i % 3)
                prims.append((BOX, [-0.6 + 0.4 * i, -0.4 + 0.4 * j, z],
                              [0.18, 0.18, 0.12],
                              [0.8, 0.65 - 0.1 * j, 0.1 + 0.2 * (i % 2)]))
        prims.append((BOX, [0, 0, -0.85], [0.9, 0.7, 0.06], [0.4, 0.4, 0.42]))
        return _mk(name, prims)
    if name == "ship":         # dense, large extent
        return _mk(name, [
            (BOX, [0, 0, -0.72], [1.2, 1.2, 0.05], [0.25, 0.45, 0.6]),
            (BOX, [0, 0, -0.5], [0.85, 0.3, 0.16], [0.5, 0.33, 0.18]),
            (BOX, [0.5, 0, -0.2], [0.08, 0.08, 0.35], [0.45, 0.3, 0.2]),
            (BOX, [-0.3, 0, -0.1], [0.06, 0.06, 0.45], [0.45, 0.3, 0.2]),
            (BOX, [-0.3, 0, 0.15], [0.02, 0.5, 0.25], [0.95, 0.95, 0.9]),
            (BOX, [0.5, 0, 0.0], [0.02, 0.38, 0.18], [0.95, 0.95, 0.9]),
        ])
    raise KeyError(name)


SCENES = ("chair", "drums", "ficus", "hotdog", "lego", "materials", "mic",
          "ship")


# --------------------------------------------------------------------------
# analytic SDF + ground-truth renderer
# --------------------------------------------------------------------------


def scene_sdf(scene: Scene, p: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """p (N,3) -> (dist (N,), nearest-prim color (N,3))."""
    t = jnp.asarray(scene.prim_type)
    c = jnp.asarray(scene.center)
    s = jnp.asarray(scene.size)
    rel = p[:, None, :] - c[None]                   # (N,P,3)

    d_sphere = jnp.linalg.norm(rel, axis=-1) - s[None, :, 0]
    q = jnp.abs(rel) - s[None]
    d_box = (jnp.linalg.norm(jnp.maximum(q, 0.0), axis=-1)
             + jnp.minimum(jnp.max(q, axis=-1), 0.0))
    dxy = jnp.linalg.norm(rel[..., :2], axis=-1) - s[None, :, 0]
    dz = jnp.abs(rel[..., 2]) - s[None, :, 1]
    qc = jnp.stack([dxy, dz], axis=-1)
    d_cyl = (jnp.linalg.norm(jnp.maximum(qc, 0.0), axis=-1)
             + jnp.minimum(jnp.max(qc, axis=-1), 0.0))

    d = jnp.where(t[None] == SPHERE, d_sphere,
                  jnp.where(t[None] == BOX, d_box, d_cyl))   # (N,P)
    best = jnp.argmin(d, axis=-1)
    col = jnp.asarray(scene.color)[best]
    return jnp.min(d, axis=-1), col


def render_gt(scene: Scene, cam: Camera, *, n_steps: int = 64,
              light=(0.4, 0.3, 0.85)) -> jax.Array:
    """Sphere-traced ground truth image (H*W, 3), white background."""
    o, d = camera_rays(cam)
    t = jnp.full((o.shape[0],), 1.0)

    def step(t, _):
        p = o + d * t[:, None]
        dist, _ = scene_sdf(scene, p)
        return t + jnp.clip(dist, -0.05, 0.3), None

    t, _ = jax.lax.scan(step, t, None, length=n_steps)
    p = o + d * t[:, None]
    dist, col = scene_sdf(scene, p)
    hit = (dist < 5e-3) & (t < 7.0)

    eps = 1e-3
    def grad_axis(i):
        e = jnp.zeros((3,)).at[i].set(eps)
        return (scene_sdf(scene, p + e)[0] - scene_sdf(scene, p - e)[0])
    n = jnp.stack([grad_axis(i) for i in range(3)], axis=-1)
    n = n / jnp.maximum(jnp.linalg.norm(n, axis=-1, keepdims=True), 1e-8)
    l = jnp.asarray(light) / np.linalg.norm(light)
    lam = jnp.clip(jnp.einsum("nd,d->n", n, l), 0.0, 1.0)
    shade = (0.35 + 0.65 * lam)[:, None] * col
    return jnp.where(hit[:, None], shade, 1.0)


def make_cameras(n_views: int, h: int, w: int, radius: float = 4.0,
                 elevation: float = 0.5) -> List[Camera]:
    cams = []
    for i in range(n_views):
        a = 2 * np.pi * i / n_views
        o = np.array([radius * np.cos(a) * np.cos(elevation),
                      radius * np.sin(a) * np.cos(elevation),
                      radius * np.sin(elevation)], np.float32)
        cams.append(look_at_camera(o, [0, 0, 0], 1.2 * w, h, w))
    return cams


@dataclasses.dataclass
class RayDataset:
    rays_o: np.ndarray      # (M,3)
    rays_d: np.ndarray      # (M,3)
    rgb: np.ndarray         # (M,3)

    def batches(self, batch: int, seed: int = 0):
        rng = np.random.RandomState(seed)
        m = self.rays_o.shape[0]
        while True:
            idx = rng.randint(0, m, size=batch)
            yield (jnp.asarray(self.rays_o[idx]), jnp.asarray(self.rays_d[idx]),
                   jnp.asarray(self.rgb[idx]))


def build_dataset(scene: Scene, n_views: int, h: int, w: int) -> RayDataset:
    cams = make_cameras(n_views, h, w)
    render = jax.jit(lambda c2w, orig: render_gt(
        scene, Camera(c2w, orig, cams[0].focal, h, w)))
    ro, rd, rgb = [], [], []
    for cam in cams:
        img = np.asarray(render(cam.c2w, cam.origin))
        o, d = camera_rays(cam)
        ro.append(np.asarray(o))
        rd.append(np.asarray(d))
        rgb.append(img)
    return RayDataset(np.concatenate(ro), np.concatenate(rd),
                      np.concatenate(rgb))
