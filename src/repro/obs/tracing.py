"""Per-request span tracing for the serving stack.

Cicero (PAPERS.md) grounds every rendering-pipeline change in a
stage-level latency breakdown; this module gives each served view that
breakdown. A `ViewTrace` is one request's span tree through the engine
lifecycle:

    view (submit -> deliver)
    ├── submit      enqueue under the engine lock
    ├── queue       submit -> the flush that claimed the request
    ├── group       (scene, ordering-octant) bucketing of the whole batch
    ├── ordering    per-view ordering-cache lookups for the group
    ├── compaction  micro-batch planning + ray sharding for the group
    ├── render      the jitted decode/sample/accumulate steps
    │                 (attrs: dispatch path, chunks, dropped pairs)
    └── deliver     PSNR + result construction -> future resolution

Group-level stages (group/ordering/compaction/render) are measured once
per flush group and attached to every member request's trace — each
request's tree answers "where did MY time go", and the shared intervals
are exactly the time that request spent in those stages.

A `Tracer` mints traces, folds every finished trace's stage durations
into `request_stage_s{stage=...}` histograms in the shared
`MetricsRegistry` (where benchmarks and `scripts/obs_report.py` read the
stage breakdown), counts render dispatch paths
(`render_dispatch_total{path=...}`), and keeps the last `max_traces`
completed trees for inspection. `enabled=False` short-circuits everything
— `start()` returns None and all recording sites no-op — which is what
the serving benchmark's self-overhead gate toggles.

Span timestamps are `time.perf_counter()` values; trees are exported with
times relative to the root so they are directly comparable across
requests. These host-side spans line up with device-side XLA profiler
captures through the `jax.named_scope` annotations in `core/pipeline.py`
and `kernels/fused_sample.py` (see docs/observability.md for capturing a
profile via `serve --profile-dir`).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional

from repro.obs.registry import MetricsRegistry

# canonical stage order of one request's lifecycle (doc + report order).
# Every request passes through all of STAGES; the temporal tier's stages
# (engine.submit_delta: warp/mask on the submitting thread, composite on
# the flush thread) only appear on delta frames, so reports iterate
# REPORT_STAGES — the full lifecycle order — and skip empty stages.
STAGES = ("submit", "queue", "group", "ordering", "compaction", "render",
          "deliver")
REPORT_STAGES = ("warp", "mask", "submit", "queue", "group", "ordering",
                 "compaction", "render", "composite", "deliver")


@dataclasses.dataclass
class Span:
    """One timed stage: [t0, t1] absolute perf_counter seconds + attrs."""
    name: str
    t0: float
    t1: float
    attrs: Dict = dataclasses.field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        return max(self.t1 - self.t0, 0.0)


class ViewTrace:
    """The span tree of one request: a root (submit -> deliver) plus one
    child span per lifecycle stage. Built concurrently from the submitting
    thread and the flushing thread; appends are lock-protected."""

    def __init__(self, view_id: int, scene: str, t_submit: float):
        self.view_id = view_id
        self.scene = scene
        self.t_submit = t_submit
        self.t_done: Optional[float] = None
        self._lock = threading.Lock()
        self._spans: List[Span] = []

    def add(self, name: str, t0: float, t1: float, **attrs) -> Span:
        sp = Span(name, t0, t1, attrs)
        with self._lock:
            self._spans.append(sp)
        return sp

    def span(self, name: str, **attrs):
        """Context manager measuring one stage on the current thread."""
        return _SpanCtx(self, name, attrs)

    def spans(self) -> List[Span]:
        with self._lock:
            return sorted(self._spans, key=lambda s: s.t0)

    def stage_durations(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for sp in self.spans():
            out[sp.name] = out.get(sp.name, 0.0) + sp.dur_s
        return out

    def tree(self) -> Dict:
        """JSON-able span tree, times relative to submit."""
        t_end = self.t_done if self.t_done is not None else self.t_submit
        return {
            "view_id": self.view_id,
            "scene": self.scene,
            "dur_s": max(t_end - self.t_submit, 0.0),
            "stages": [
                {"name": sp.name,
                 "t0_s": max(sp.t0 - self.t_submit, 0.0),
                 "dur_s": sp.dur_s, **sp.attrs}
                for sp in self.spans()],
        }


class _SpanCtx:
    def __init__(self, trace: ViewTrace, name: str, attrs: Dict):
        self._trace, self._name, self._attrs = trace, name, attrs

    def __enter__(self) -> Dict:
        self._t0 = time.perf_counter()
        return self._attrs          # caller may add attrs inside the block

    def __exit__(self, *exc):
        self._trace.add(self._name, self._t0, time.perf_counter(),
                        **self._attrs)
        return False


class Tracer:
    """Mints ViewTraces and folds finished ones into the registry."""

    def __init__(self, registry: MetricsRegistry, *, max_traces: int = 256,
                 enabled: bool = True):
        self.registry = registry
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._completed: collections.deque = collections.deque(
            maxlen=int(max_traces))

    def start(self, view_id: int, scene: str,
              t_submit: Optional[float] = None) -> Optional[ViewTrace]:
        if not self.enabled:
            return None
        return ViewTrace(view_id, scene,
                         time.perf_counter() if t_submit is None
                         else t_submit)

    def finish(self, trace: Optional[ViewTrace],
               t_done: Optional[float] = None):
        """Close the root span, aggregate stage durations into the shared
        registry, retain the tree."""
        if trace is None:
            return
        trace.t_done = time.perf_counter() if t_done is None else t_done
        for stage, dur in trace.stage_durations().items():
            self.registry.histogram("request_stage_s", stage=stage).record(
                dur)
        for sp in trace.spans():
            path = sp.attrs.get("dispatch_path")
            if path is not None:
                self.registry.counter("render_dispatch_total",
                                      path=path).inc()
        with self._lock:
            self._completed.append(trace)

    def completed(self) -> List[ViewTrace]:
        """Most-recent-last completed traces (bounded window)."""
        with self._lock:
            return list(self._completed)

    def last(self) -> Optional[ViewTrace]:
        with self._lock:
            return self._completed[-1] if self._completed else None
