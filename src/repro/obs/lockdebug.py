"""Debug-mode runtime lock-order assertion — the dynamic complement to
repro-lint's static ``lock-order`` rule (docs/static_analysis.md).

Every lock in the serving stack is created through :func:`make_lock`.
With ``REPRO_LOCK_DEBUG`` unset (the default) it returns a plain
``threading.Lock``/``RLock`` — zero overhead, nothing imported beyond
stdlib. With ``REPRO_LOCK_DEBUG=1`` it returns a tracking wrapper that
records the process-global acquisition-order graph (label held ->
label acquired) and raises :class:`LockOrderError` *before* blocking
when an acquisition would invert an order already observed — turning a
once-in-a-blue-moon deadlock into a deterministic test failure.

Labels are stable strings ("engine", "store", "router", ...); multiple
instances sharing a label share ordering constraints, which is what you
want for per-scene / per-metric lock families.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Tuple, Union

__all__ = ["make_lock", "LockOrderError", "enabled", "reset", "edges"]


class LockOrderError(RuntimeError):
    """Acquisition order inverted against the recorded global order."""


_graph_lock = threading.Lock()
# (held_label, acquired_label) -> thread name that first recorded it
_edges: Dict[Tuple[str, str], str] = {}
_tls = threading.local()


def enabled() -> bool:
    return os.environ.get("REPRO_LOCK_DEBUG") == "1"


def _held_stack() -> List[str]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def reset() -> None:
    """Forget the recorded order graph (tests start from a clean slate)."""
    with _graph_lock:
        _edges.clear()
    _tls.stack = []


def edges() -> Dict[Tuple[str, str], str]:
    with _graph_lock:
        return dict(_edges)


class _TrackedLock:
    """Lock/RLock wrapper recording acquisition order by label."""

    def __init__(self, label: str, inner, reentrant: bool):
        self._label = label
        self._inner = inner
        self._reentrant = reentrant

    # -- ordering bookkeeping ---------------------------------------------

    def _check_and_note(self) -> None:
        st = _held_stack()
        if self._label in st:
            if not self._reentrant:
                raise LockOrderError(
                    f"reentrant acquire of non-reentrant lock "
                    f"'{self._label}' (held: {st})")
            return  # reentrant re-acquire adds no ordering edges
        me = threading.current_thread().name
        with _graph_lock:
            for held in st:
                if (self._label, held) in _edges:
                    first = _edges[(self._label, held)]
                    raise LockOrderError(
                        f"lock-order inversion: acquiring '{self._label}' "
                        f"while holding '{held}', but thread '{first}' "
                        f"previously acquired '{held}' while holding "
                        f"'{self._label}' (held: {st})")
            for held in st:
                _edges.setdefault((held, self._label), me)

    # -- Lock API ----------------------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._check_and_note()
        got = self._inner.acquire(blocking, timeout)
        if got:
            _held_stack().append(self._label)
        return got

    def release(self) -> None:
        st = _held_stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] == self._label:
                del st[i]
                break
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> "_TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    # -- threading.Condition duck-typed hooks ------------------------------
    # Condition(lock) lifts these if present; they must keep the held
    # stack honest across wait()'s release/reacquire cycle.

    def _release_save(self):
        st = _held_stack()
        n = 0
        for i in range(len(st) - 1, -1, -1):
            if st[i] == self._label:
                del st[i]
                n += 1
        return (self._inner._release_save(), n)

    def _acquire_restore(self, saved) -> None:
        inner_state, n = saved
        self._inner._acquire_restore(inner_state)
        _held_stack().extend([self._label] * n)

    def _is_owned(self) -> bool:
        return self._inner._is_owned()


def make_lock(label: str, kind: str = "lock"
              ) -> Union[threading.Lock, threading.RLock, _TrackedLock]:
    """A lock for the serving stack. ``kind`` is "lock" or "rlock".

    Plain stdlib lock unless ``REPRO_LOCK_DEBUG=1``, in which case the
    returned wrapper asserts global acquisition order under ``label``."""
    if kind not in ("lock", "rlock"):
        raise ValueError(f"unknown lock kind {kind!r}")
    reentrant = kind == "rlock"
    inner = threading.RLock() if reentrant else threading.Lock()
    if not enabled():
        return inner
    return _TrackedLock(label, inner, reentrant)
