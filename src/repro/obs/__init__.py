from repro.obs import lockdebug  # noqa: F401
from repro.obs.lockdebug import LockOrderError, make_lock  # noqa: F401
from repro.obs.registry import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, get_registry)
from repro.obs.tracing import (Span, Tracer, ViewTrace, STAGES,  # noqa: F401
                               REPORT_STAGES)
from repro.obs.exposition import (  # noqa: F401
    MetricsServer, StatsReporter, snapshot_json, to_prometheus)
