"""Exposition for the metrics registry: JSON snapshots, Prometheus text
format, an HTTP endpoint, and a periodic stats line.

  * `snapshot_json(registry, extra=...)` — the canonical JSON snapshot
    (schema validated in CI by `scripts/check_metrics_schema.py`, rendered
    by `scripts/obs_report.py`).
  * `to_prometheus(registry)` — Prometheus text format: counters and
    gauges verbatim; histograms as summaries (`_count`/`_sum`/`_max` plus
    `quantile="0.5|0.95|0.99"` sample lines over the resident window).
  * `MetricsServer(registry, port=...)` — a threaded stdlib HTTP server:
    `GET /metrics` (Prometheus text), `GET /metrics.json` (JSON snapshot).
    `port=0` binds an ephemeral port (tests); `.port` tells which. Wired
    by `serve --metrics-port`.
  * `StatsReporter(line_fn, interval_s)` — background thread printing one
    summary line per interval (`serve --stats-interval`). Daemon + stop
    event, so a crashed serve loop never hangs on it; `close()` joins.

The server binds 127.0.0.1 by default — this is an operator diagnostic
endpoint, not a public API.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, Optional

from repro.obs.registry import MetricsRegistry, flat_name


def snapshot_json(registry: MetricsRegistry,
                  extra: Optional[Dict] = None) -> Dict:
    """The canonical JSON snapshot envelope."""
    out = {
        "schema": "repro.obs/v1",
        "ts_unix_s": time.time(),
        "metrics": registry.snapshot(),
    }
    if extra:
        out["stats"] = extra
    return out


def _prom_labels(labels, extra=()) -> str:
    items = list(labels) + list(extra)
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


def to_prometheus(registry: MetricsRegistry) -> str:
    """Prometheus text exposition (counters, gauges, histogram summaries)."""
    lines = []
    typed = set()

    def head(name: str, kind: str):
        if name not in typed:
            lines.append(f"# TYPE {name} {kind}")
            typed.add(name)

    for m in registry.metrics():
        if m.kind == "counter":
            head(m.name, "counter")
            lines.append(f"{m.name}{_prom_labels(m.labels)} {m.value}")
        elif m.kind == "gauge":
            head(m.name, "gauge")
            lines.append(f"{m.name}{_prom_labels(m.labels)} {m.value}")
        else:                                        # histogram -> summary
            head(m.name, "summary")
            snap = m.snapshot()
            for q in (50, 95, 99):
                lines.append(
                    f"{m.name}"
                    f"{_prom_labels(m.labels, [('quantile', q / 100)])} "
                    f"{snap[f'p{q}']}")
            lines.append(
                f"{m.name}_count{_prom_labels(m.labels)} {snap['count']}")
            lines.append(
                f"{m.name}_sum{_prom_labels(m.labels)} {snap['sum']}")
            lines.append(
                f"{m.name}_max{_prom_labels(m.labels)} {snap['max']}")
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Threaded HTTP exposition of one registry (+ optional extra stats).

    `extra` is a zero-arg callable evaluated per request and merged into
    the JSON snapshot under "stats" — the engine passes its `stats()` so
    scrapes see derived state (FPS, resident scenes) alongside the raw
    metrics.
    """

    def __init__(self, registry: MetricsRegistry, *, port: int = 0,
                 host: str = "127.0.0.1",
                 extra: Optional[Callable[[], Dict]] = None):
        self.registry = registry
        self.extra = extra
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                try:
                    if self.path.startswith("/metrics.json"):
                        extra_stats = server.extra() if server.extra else None
                        body = json.dumps(snapshot_json(
                            server.registry, extra_stats), indent=2)
                        ctype = "application/json"
                    elif self.path.startswith("/metrics"):
                        body = to_prometheus(server.registry)
                        ctype = "text/plain; version=0.0.4"
                    else:
                        self.send_error(404)
                        return
                except Exception as e:       # surface, don't kill the server
                    self.send_error(500, str(e))
                    return
                data = body.encode()
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def log_message(self, *a):        # keep serve stdout clean
                pass

        self._httpd = ThreadingHTTPServer((host, int(port)), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-metrics-server",
            daemon=True)
        self._thread.start()

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    def close(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join()

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class StatsReporter:
    """Print `line_fn()` every `interval_s` seconds on a daemon thread."""

    def __init__(self, line_fn: Callable[[], str], interval_s: float):
        self._line_fn = line_fn
        self.interval_s = float(interval_s)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="obs-stats-reporter", daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.wait(self.interval_s):
            try:
                print(self._line_fn(), flush=True)
            except Exception as e:            # never kill the host process
                print(f"[obs] stats reporter error: {e}", flush=True)

    def close(self):
        self._stop.set()
        self._thread.join()


__all__ = ["MetricsServer", "StatsReporter", "snapshot_json",
           "to_prometheus", "flat_name"]
