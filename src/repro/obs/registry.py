"""Unified metrics registry for the serving stack.

RT-NeRF's contribution began with profiling (PAPER.md Sec. 1: uniform
sampling and dense embedding access identified as the on-device
bottlenecks); this module is the repo's equivalent instrument. One
`MetricsRegistry` per serving process replaces the ad-hoc `_latencies`
deques and per-scene telemetry dicts that used to live inside
`serving/engine.py`, `serving/store.py`, and `serving/finetune.py`:
every producer records into named, optionally labelled metrics, and every
consumer — `stats()`, the JSON/Prometheus exposition
(`obs/exposition.py`), the benchmarks' stage columns, and
`scripts/obs_report.py` — reads one coherent snapshot.

Three metric kinds, all thread-safe:

  * `Counter`   — monotone float accumulator (`inc`); used for totals
                  (views served, flushes, dropped pairs, render seconds).
  * `Gauge`     — last-write-wins value (`set`); used for states
                  (pair budget, resident bytes, occupancy).
  * `Histogram` — bounded ring buffer (`collections.deque(maxlen=...)`)
                  of observations with **all-time** `count`/`sum`/`max`
                  kept separately, so a long-running service never grows
                  per-request state while percentiles (p50/p95/p99) cover
                  the recent window. This is the same windowed-percentile
                  contract the engine's `_latencies` deque and
                  `SceneRecord.swap_latencies` had — now in one place.

Labels: `registry.counter("scene_views", scene="lego")` keys the metric by
(name, sorted label items) — the Prometheus data model, so the exposition
formats fall out directly. Metric handles are cached: repeated lookups
return the same object, and hot paths should hold the handle rather than
re-resolve by name.

`get_registry()` returns the process-default registry (for one-off
scripts); serving components default to one registry **per SceneStore**
(shared with the engine and its fine-tune loops) so two engines in one
test process never bleed counters into each other's `stats()`.
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.obs.lockdebug import make_lock

LabelKey = Tuple[Tuple[str, str], ...]

# repro-lint lock-discipline declarations (docs/static_analysis.md).
# Metric locks are leaves of the serving lock order: nothing is acquired
# while one is held.
GUARDED_BY = {
    "Counter": {"lock": "_lock", "attrs": ("_value",)},
    "Gauge": {"lock": "_lock", "attrs": ("_value",)},
    "Histogram": {"lock": "_lock",
                  "attrs": ("_window", "_count", "_sum", "_max")},
    "MetricsRegistry": {"lock": "_lock", "attrs": ("_metrics",)},
}


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone float accumulator."""

    kind = "counter"

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self._lock = make_lock("obs.metric")
        self._value = 0.0

    def inc(self, n: float = 1.0):
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> Dict:
        return {"value": self.value}


class Gauge:
    """Last-write-wins value."""

    kind = "gauge"

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self._lock = make_lock("obs.metric")
        self._value = 0.0

    def set(self, v: float):
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0):
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> Dict:
        return {"value": self.value}


class Histogram:
    """Bounded ring buffer of observations + all-time count/sum/max.

    Percentiles are computed over the resident window (the most recent
    `maxlen` observations); `count`/`sum`/`max` cover everything ever
    recorded — so rates and worst-cases survive the window rolling over
    while memory stays O(maxlen) for the life of the service.
    """

    kind = "histogram"

    def __init__(self, name: str, labels: LabelKey = (), maxlen: int = 4096):
        self.name = name
        self.labels = labels
        self.maxlen = int(maxlen)
        self._lock = make_lock("obs.metric")
        self._window: collections.deque = collections.deque(
            maxlen=self.maxlen)
        self._count = 0
        self._sum = 0.0
        self._max = 0.0

    def record(self, v: float):
        v = float(v)
        with self._lock:
            self._window.append(v)
            self._count += 1
            self._sum += v
            if v > self._max:
                self._max = v

    def extend(self, vs: Iterable[float]):
        for v in vs:
            self.record(v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def max(self) -> float:
        """All-time maximum (not windowed)."""
        with self._lock:
            return self._max

    @property
    def last(self) -> float:
        with self._lock:
            return self._window[-1] if self._window else 0.0

    def window(self) -> np.ndarray:
        with self._lock:
            return np.asarray(self._window, np.float64)

    def percentile(self, q: float) -> float:
        w = self.window()
        return float(np.percentile(w, q)) if w.size else 0.0

    def mean(self) -> float:
        w = self.window()
        return float(w.mean()) if w.size else 0.0

    def snapshot(self) -> Dict:
        with self._lock:
            w = np.asarray(self._window, np.float64)
            out = {"count": self._count, "sum": self._sum, "max": self._max,
                   "window_len": int(w.size), "maxlen": self.maxlen,
                   "last": float(w[-1]) if w.size else 0.0}
        for q in (50, 95, 99):
            out[f"p{q}"] = float(np.percentile(w, q)) if w.size else 0.0
        out["mean"] = float(w.mean()) if w.size else 0.0
        return out


class MetricsRegistry:
    """Named, labelled metrics with cached handles and a JSON snapshot."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._lock = make_lock("obs.registry")
        self._metrics: Dict[Tuple[str, LabelKey], object] = {}

    def _get(self, cls, name: str, labels: Dict[str, str], **kw):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric '{name}' already registered as {m.kind}, "
                    f"not {cls.kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, maxlen: int = 4096,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, maxlen=maxlen)

    def metrics(self):
        with self._lock:
            return list(self._metrics.values())

    def snapshot(self) -> Dict:
        """JSON-able view: {kind: {flat_name: {...}}} where flat_name is
        `name{k=v,...}` for labelled metrics (Prometheus-style)."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in self.metrics():
            flat = flat_name(m.name, m.labels)
            out[m.kind + "s"][flat] = m.snapshot()
        return out


def flat_name(name: str, labels: LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


_default_registry: Optional[MetricsRegistry] = None
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-default registry (scripts / one-off consumers). Serving
    components create or share per-store registries instead — see module
    docstring."""
    global _default_registry
    with _default_lock:
        if _default_registry is None:
            _default_registry = MetricsRegistry()
        return _default_registry
