"""NeRF training loop: photometric MSE + L1 sparsity + TV, periodic
occupancy rebuild, compressed-native optimisation.

Training renders use the differentiable uniform pipeline (as in TensoRF);
the RT-NeRF pipeline is the inference path it is benchmarked against.

Compressed-native training (ROADMAP "compressed training"): after a dense
warmup, the field is pruned and hybrid-encoded (core/field.py), and every
optimizer step from then on applies gradients to the *encoded* field's nnz
values (`FieldBackend.trainable()` — packed non-zeros + MLP/basis). The
bitmap/COO support is fixed between re-encode boundaries (every
`occ_every` steps the field is re-pruned and re-encoded, so the support
tracks the emerging sparsity). Training renders are occupancy-free (as in
TensoRF); the occupancy grid is built once from the final field, at the
one shared cutoff `cfg.occ_sigma_thresh`. The factors stay encoded between
steps — what the trainer holds is what the checkpoint stores and the
serving engine publishes (`swap_field`), with no encode-at-serve-time step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import rendering
from repro.core import tensorf
from repro.data import rays as rays_lib
from repro.optim import adamw


@dataclasses.dataclass
class TrainResult:
    field: field_lib.FieldBackend
    cubes: occ_lib.CubeSet
    history: list


def nerf_loss(field, cfg: NeRFConfig, rays_o, rays_d, target, cubes=None):
    f = field_lib.as_backend(field, cfg)
    rgb, _ = rendering.render_uniform(
        f, cfg, cubes, rays_o, rays_d,
        use_occupancy=cubes is not None)
    mse = jnp.mean(jnp.square(rgb - target))
    loss = mse + cfg.sigma_sparsity_l1 * f.l1() + cfg.tv_weight * f.tv()
    return loss, mse


def train_nerf(cfg: NeRFConfig, scene_name: str, *, steps: int = 400,
               n_views: int = 12, image_hw: int = 64,
               occ_every: int = 200, prune_tol: float = 1e-3,
               seed: int = 0, log_every: int = 100, verbose: bool = True,
               compressed: bool = True) -> TrainResult:
    """Train a TensoRF field; return the final (encoded) FieldBackend +
    occupancy cubes.

    compressed=True (default): at every `occ_every` boundary the field is
    pruned (`prune_tol`), hybrid-encoded, and the optimizer continues on the
    encoded representation's nnz values — the field is never densified
    again. compressed=False keeps the legacy dense loop end to end (the
    baseline the compressed-parity test measures against). The occupancy
    grid is built once, from the final field, at `cfg.occ_sigma_thresh`
    (training renders don't consume occupancy).
    """
    scene = rays_lib.make_scene(scene_name)
    ds = rays_lib.build_dataset(scene, n_views, image_hw, image_hw)
    field = field_lib.DenseField(
        tensorf.init_field(cfg, jax.random.PRNGKey(seed)), cfg)
    opt = adamw(lr=cfg.lr_grid, b2=0.99)

    def make_step(template):
        """One jitted step over the template's trainable leaves. The encoded
        structure (bitmap words / rowptr / COO coords) rides in the closure;
        only the float payloads flow through grad/update."""
        @jax.jit
        def step_fn(tvals, opt_state, ro, rd, tgt):
            def loss_fn(v):
                return nerf_loss(template.with_trainable(v), cfg, ro, rd,
                                 tgt)
            (loss, mse), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(tvals)
            tvals2, opt_state2 = opt.update(grads, opt_state, tvals)
            return tvals2, opt_state2, loss, mse
        return step_fn

    tvals = field.trainable()
    opt_state = opt.init(tvals)
    step_fn = make_step(field)

    history = []
    it = ds.batches(cfg.train_rays, seed=seed)
    for i in range(steps):
        if compressed and i > 0 and i % occ_every == 0:
            # re-encode boundary: re-prune + re-encode; the support (and
            # with it the trainable leaf shapes) changes, so the optimizer
            # state and the jitted step are rebuilt
            field = field.with_trainable(tvals).prune(tol=prune_tol).encode()
            tvals = field.trainable()
            opt_state = opt.init(tvals)
            step_fn = make_step(field)
            if verbose:
                print(f"  [{scene_name}] step {i:5d} re-encoded field "
                      f"({field.compression_ratio():.2f}x factor bytes)",
                      flush=True)
        ro, rd, tgt = next(it)
        tvals, opt_state, loss, mse = step_fn(tvals, opt_state, ro, rd, tgt)
        if i % log_every == 0 or i == steps - 1:
            p = float(-10 * jnp.log10(jnp.maximum(mse, 1e-10)))
            history.append({"step": i, "loss": float(loss), "psnr": p})
            if verbose:
                print(f"  [{scene_name}] step {i:5d} loss {float(loss):.5f} "
                      f"train-psnr {p:.2f}", flush=True)

    field = field.with_trainable(tvals).prune(tol=prune_tol)
    if compressed:
        field = field.encode()
    occ = occ_lib.build_occupancy(field, cfg)        # cfg.occ_sigma_thresh
    cubes = occ_lib.extract_cubes(occ, cfg)
    return TrainResult(field=field, cubes=cubes, history=history)


def eval_view(field, cfg: NeRFConfig, cubes, cam, gt, *,
              pipeline: str = "rtnerf", order_mode: str = "octant",
              chunk: int = 1, intersect: str = "box"):
    """Render one view with either pipeline; return (psnr, stats, img).

    `field` is anything `field.as_backend` accepts; an encoded field is
    sampled from its hybrid bitmap/COO streams on BOTH pipelines (the
    uniform baseline no longer needs a decompressed copy).
    """
    from repro.core import pipeline as rt_pipe

    f = field_lib.as_backend(field, cfg)
    if pipeline == "rtnerf":
        img, stats = rt_pipe.render_rtnerf(f, cfg, cubes, cam,
                                           order_mode=order_mode, chunk=chunk,
                                           intersect=intersect)
    else:
        o, d = rendering.camera_rays(cam)
        img, stats = rendering.render_uniform(f, cfg, cubes, o, d)
    p = float(rendering.psnr(jnp.clip(img, 0, 1), gt))
    return p, {k: float(v) for k, v in stats.items()}, img
