"""NeRF training loop: photometric MSE + L1 sparsity + TV, periodic
occupancy rebuild, optional pruning pass that realises factor sparsity.

Training renders use the differentiable uniform pipeline (as in TensoRF);
the RT-NeRF pipeline is the inference path it is benchmarked against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.rtnerf import NeRFConfig
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, sparse, tensorf
from repro.data import rays as rays_lib
from repro.optim import adamw


@dataclasses.dataclass
class TrainResult:
    params: Dict
    cubes: occ_lib.CubeSet
    history: list


def nerf_loss(params, cfg: NeRFConfig, rays_o, rays_d, target, cubes=None):
    rgb, _ = rendering.render_uniform(
        params, cfg, cubes, rays_o, rays_d,
        use_occupancy=cubes is not None)
    mse = jnp.mean(jnp.square(rgb - target))
    loss = mse + cfg.sigma_sparsity_l1 * tensorf.field_l1(params) \
        + cfg.tv_weight * tensorf.field_tv(params)
    return loss, mse


def train_nerf(cfg: NeRFConfig, scene_name: str, *, steps: int = 400,
               n_views: int = 12, image_hw: int = 64,
               occ_every: int = 200, sigma_thresh: float = 2.0,
               prune_tol: float = 1e-3, seed: int = 0,
               log_every: int = 100, verbose: bool = True) -> TrainResult:
    scene = rays_lib.make_scene(scene_name)
    ds = rays_lib.build_dataset(scene, n_views, image_hw, image_hw)
    params = tensorf.init_field(cfg, jax.random.PRNGKey(seed))
    opt = adamw(lr=cfg.lr_grid, b2=0.99)
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, ro, rd, tgt):
        (loss, mse), grads = jax.value_and_grad(
            lambda p: nerf_loss(p, cfg, ro, rd, tgt), has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss, mse

    history = []
    it = ds.batches(cfg.train_rays, seed=seed)
    for i in range(steps):
        ro, rd, tgt = next(it)
        params, opt_state, loss, mse = step_fn(params, opt_state, ro, rd, tgt)
        if verbose and (i % log_every == 0 or i == steps - 1):
            p = float(-10 * jnp.log10(jnp.maximum(mse, 1e-10)))
            history.append({"step": i, "loss": float(loss), "psnr": p})
            print(f"  [{scene_name}] step {i:5d} loss {float(loss):.5f} "
                  f"train-psnr {p:.2f}", flush=True)

    params = tensorf.prune_factors(params, tol=prune_tol)
    occ = occ_lib.build_occupancy(params, cfg, sigma_thresh=sigma_thresh)
    cubes = occ_lib.extract_cubes(occ, cfg)
    return TrainResult(params=params, cubes=cubes, history=history)


def eval_view(params, cfg: NeRFConfig, cubes, cam, gt, *,
              pipeline: str = "rtnerf", order_mode: str = "octant",
              chunk: int = 1, intersect: str = "box",
              field_mode: str = "dense"):
    """Render one view with either pipeline; return (psnr, stats, img).

    field_mode="hybrid" (rtnerf pipeline only) evaluates the field from its
    hybrid bitmap/COO encoding; `params` may be a sparse.CompressedField to
    amortise the encoding across views.
    """
    if pipeline == "rtnerf":
        img, stats = rt_pipe.render_rtnerf(params, cfg, cubes, cam,
                                           order_mode=order_mode, chunk=chunk,
                                           intersect=intersect,
                                           field_mode=field_mode)
    else:
        if field_mode != "dense":
            raise ValueError("field_mode='hybrid' requires pipeline='rtnerf' "
                             "(the uniform baseline has no compressed path)")
        if isinstance(params, sparse.CompressedField):
            params = sparse.decompress_field(params)
        o, d = rendering.camera_rays(cam)
        img, stats = rendering.render_uniform(params, cfg, cubes, o, d)
    p = float(rendering.psnr(jnp.clip(img, 0, 1), gt))
    return p, {k: float(v) for k, v in stats.items()}, img
