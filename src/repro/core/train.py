"""NeRF training loop: photometric MSE + L1 sparsity + TV, periodic
occupancy rebuild, compressed-native optimisation with support revival.

API: `NerfTrainer` is the incremental stepper (`step()` / `reencode()` /
`snapshot()` / `final()`) that the online fine-tuning service
(serving/finetune.py) drives one step at a time between `swap_field`
publications; `train_nerf(cfg, scene, steps=...)` runs it to completion
and returns a `TrainResult`; `eval_view` renders one view through either
pipeline for PSNR reporting.

Training renders use the differentiable uniform pipeline (as in TensoRF);
the RT-NeRF pipeline is the inference path it is benchmarked against.

Compressed-native training (ROADMAP "compressed training"): after a dense
warmup, the field is pruned and hybrid-encoded (core/field.py), and every
optimizer step from then on applies gradients to the *encoded* field's nnz
values (`FieldBackend.trainable()` — packed non-zeros + MLP/basis). The
bitmap/COO support is fixed between re-encode boundaries (every
`occ_every` steps the field is re-pruned and re-encoded, so the support
tracks the emerging sparsity). At each boundary the support is also
*revived* (ROADMAP "support revival"): entries pruned to zero before an
earlier encode get no gradient and could otherwise never regrow, so the
top `revive_frac` zero entries by dense-gradient magnitude are re-seeded
(`DenseField.revive`) before the re-prune — RigL-style regrowth at exactly
the cadence the support is re-chosen anyway. Training renders are
occupancy-free (as in TensoRF); the occupancy grid is built once from the
final field, at the one shared cutoff `cfg.occ_sigma_thresh`. The factors
stay encoded between steps — what the trainer holds is what the checkpoint
stores and the serving engine publishes (`swap_field`), with no
encode-at-serve-time step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import rendering
from repro.core import tensorf
from repro.data import rays as rays_lib
from repro.optim import adamw


@dataclasses.dataclass
class TrainResult:
    field: field_lib.FieldBackend
    cubes: occ_lib.CubeSet
    history: list


def nerf_loss(field, cfg: NeRFConfig, rays_o, rays_d, target, cubes=None):
    f = field_lib.as_backend(field, cfg)
    rgb, _ = rendering.render_uniform(
        f, cfg, cubes, rays_o, rays_d,
        use_occupancy=cubes is not None)
    mse = jnp.mean(jnp.square(rgb - target))
    loss = mse + cfg.sigma_sparsity_l1 * f.l1() + cfg.tv_weight * f.tv()
    return loss, mse


class NerfTrainer:
    """Incremental compressed-native trainer: one optimizer step at a time.

    `train_nerf` drives this to completion; `serving.finetune.FineTuneLoop`
    drives it on a background thread, interleaving `step()` with
    `snapshot()` -> `RenderEngine.swap_field` publications. The trainer can
    start from a fresh init (`field=None`) or resume from any FieldBackend
    — e.g. the field a serving engine is currently rendering from, for
    online fine-tuning of a live scene.

    State: `field` is the structure template (encoded or dense), `_tvals`
    the float payloads the optimizer owns. At every `occ_every` boundary
    `reencode()` revives + re-prunes + re-encodes, rebuilding the optimizer
    state and the jitted step for the new trainable leaf shapes.
    """

    def __init__(self, cfg: NeRFConfig, scene_name: str, *,
                 field: Optional[field_lib.FieldBackend] = None,
                 n_views: int = 12, image_hw: int = 64,
                 occ_every: int = 200, prune_tol: float = 1e-3,
                 revive_frac: float = 0.05,
                 revive_eps: Optional[float] = None,
                 seed: int = 0, compressed: bool = True,
                 verbose: bool = False):
        self.cfg = cfg
        self.scene_name = scene_name
        self.compressed = bool(compressed)
        self.occ_every = int(occ_every)
        self.prune_tol = float(prune_tol)
        self.revive_frac = float(revive_frac)
        # revived entries must clear the next tol-prune or revival is a no-op
        self.revive_eps = (2.0 * self.prune_tol if revive_eps is None
                           else float(revive_eps))
        self.verbose = bool(verbose)
        scene = rays_lib.make_scene(scene_name)
        ds = rays_lib.build_dataset(scene, n_views, image_hw, image_hw)
        self._it = ds.batches(cfg.train_rays, seed=seed)
        # revival grads come from their own stream so enabling revival
        # doesn't shift which rays the optimizer steps see
        self._revive_it = ds.batches(cfg.train_rays, seed=seed + 1)
        if field is None:
            field = field_lib.DenseField(
                tensorf.init_field(cfg, jax.random.PRNGKey(seed)), cfg)
        self.opt = adamw(lr=cfg.lr_grid, b2=0.99)
        self._dense_grad = jax.jit(lambda params, ro, rd, tgt: jax.grad(
            lambda p: nerf_loss(field_lib.DenseField(p, cfg), cfg,
                                ro, rd, tgt)[0])(params))
        self.step_count = 0
        self._rebind(field_lib.as_backend(field, cfg))

    def _rebind(self, field: field_lib.FieldBackend):
        """Adopt `field` as the new structure template: fresh optimizer
        state + a jitted step over its trainable leaves. The encoded
        structure (bitmap words / rowptr / COO coords) rides in the step's
        closure; only the float payloads flow through grad/update."""
        cfg, opt = self.cfg, self.opt

        @jax.jit
        def step_fn(tvals, opt_state, ro, rd, tgt):
            def loss_fn(v):
                return nerf_loss(field.with_trainable(v), cfg, ro, rd, tgt)
            (loss, mse), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(tvals)
            tvals2, opt_state2 = opt.update(grads, opt_state, tvals)
            return tvals2, opt_state2, loss, mse

        self.field = field
        self._tvals = field.trainable()
        self._opt_state = opt.init(self._tvals)
        self._step_fn = step_fn

    def reencode(self):
        """Re-encode boundary: revive the support from dense gradients,
        re-prune, hybrid-encode, and rebuild the optimizer + jitted step
        (the trainable leaf shapes change with the support)."""
        field = self.field.with_trainable(self._tvals)
        dense = field.decode()
        if self.revive_frac > 0.0:
            ro, rd, tgt = next(self._revive_it)
            grads = self._dense_grad(dense.params, ro, rd, tgt)
            dense = dense.revive(grads, frac=self.revive_frac,
                                 eps=self.revive_eps)
        self._rebind(dense.prune(tol=self.prune_tol).encode())
        if self.verbose:
            print(f"  [{self.scene_name}] step {self.step_count:5d} "
                  f"re-encoded field "
                  f"({self.field.compression_ratio():.2f}x factor bytes)",
                  flush=True)

    def step(self) -> Dict[str, float]:
        """One optimizer step (re-encoding first at `occ_every`
        boundaries); returns {step, loss, psnr} for this batch."""
        i = self.step_count
        if self.compressed and i > 0 and i % self.occ_every == 0:
            self.reencode()
        ro, rd, tgt = next(self._it)
        self._tvals, self._opt_state, loss, mse = self._step_fn(
            self._tvals, self._opt_state, ro, rd, tgt)
        self.step_count = i + 1
        p = float(-10 * np.log10(max(float(mse), 1e-10)))
        return {"step": i, "loss": float(loss), "psnr": p}

    def snapshot(self) -> field_lib.FieldBackend:
        """The current field with the optimizer's payloads applied — what a
        publication (`swap_field`) or checkpoint should see. Cheap: no
        decode, no re-encode."""
        return self.field.with_trainable(self._tvals)

    def final(self) -> TrainResult:
        """Finish: prune, encode (compressed mode), build the occupancy
        cube set at `cfg.occ_sigma_thresh`."""
        field = self.snapshot().prune(tol=self.prune_tol)
        if self.compressed:
            field = field.encode()
        occ = occ_lib.build_occupancy(field, self.cfg)
        cubes = occ_lib.extract_cubes(occ, self.cfg)
        return TrainResult(field=field, cubes=cubes, history=[])


def train_nerf(cfg: NeRFConfig, scene_name: str, *, steps: int = 400,
               n_views: int = 12, image_hw: int = 64,
               occ_every: int = 200, prune_tol: float = 1e-3,
               revive_frac: float = 0.05,
               seed: int = 0, log_every: int = 100, verbose: bool = True,
               compressed: bool = True) -> TrainResult:
    """Train a TensoRF field; return the final (encoded) FieldBackend +
    occupancy cubes.

    compressed=True (default): at every `occ_every` boundary the field is
    pruned (`prune_tol`), hybrid-encoded — with the support revived first
    (`revive_frac`, see NerfTrainer/DenseField.revive) — and the optimizer
    continues on the encoded representation's nnz values; the field is
    never densified again. compressed=False keeps the legacy dense loop end
    to end (the baseline the compressed-parity test measures against). The
    occupancy grid is built once, from the final field, at
    `cfg.occ_sigma_thresh` (training renders don't consume occupancy).
    """
    trainer = NerfTrainer(cfg, scene_name, n_views=n_views,
                          image_hw=image_hw, occ_every=occ_every,
                          prune_tol=prune_tol, revive_frac=revive_frac,
                          seed=seed, compressed=compressed, verbose=verbose)
    history = []
    for i in range(steps):
        rec = trainer.step()
        if i % log_every == 0 or i == steps - 1:
            history.append(rec)
            if verbose:
                print(f"  [{scene_name}] step {i:5d} "
                      f"loss {rec['loss']:.5f} "
                      f"train-psnr {rec['psnr']:.2f}", flush=True)
    res = trainer.final()
    return TrainResult(field=res.field, cubes=res.cubes, history=history)


def eval_view(field, cfg: NeRFConfig, cubes, cam, gt, *,
              pipeline: str = "rtnerf", order_mode: str = "octant",
              chunk: int = 1, intersect: str = "box"):
    """Render one view with either pipeline; return (psnr, stats, img).

    `field` is anything `field.as_backend` accepts; an encoded field is
    sampled from its hybrid bitmap/COO streams on BOTH pipelines (the
    uniform baseline no longer needs a decompressed copy).
    """
    from repro.core import pipeline as rt_pipe

    f = field_lib.as_backend(field, cfg)
    if pipeline == "rtnerf":
        img, stats = rt_pipe.render_rtnerf(f, cfg, cubes, cam,
                                           order_mode=order_mode, chunk=chunk,
                                           intersect=intersect)
    else:
        o, d = rendering.camera_rays(cam)
        img, stats = rendering.render_uniform(f, cfg, cubes, o, d)
    p = float(rendering.psnr(jnp.clip(img, 0, 1), gt))
    return p, {k: float(v) for k, v in stats.items()}, img
