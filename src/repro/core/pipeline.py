"""RT-NeRF's efficient rendering pipeline (paper Sec. 3.1) and the
coarse-grained view-dependent rendering ordering (Sec. 3.2).

API: `render_rtnerf(field, cfg, cubes, cam)` renders one view image-space;
`make_ray_renderer(cfg, chunk=...)` builds the jit-able fixed-shape ray
step the serving engine compiles once; `order_cubes` / `octant_rank` /
`ordering_key` implement the Sec. 3.2 ordering and its exact reuse key;
`OrderingCache` memoises per-view schedules across a request stream
(ROADMAP "streaming / multi-view compressed serving"). `field` is anything
`field.as_backend` accepts — encoded fields are sampled in place.

Instead of uniformly sampling N points along each of H*W rays and querying
the occupancy grid H*W*N times, we loop over the *non-zero cubes* of the
occupancy grid (CubeSet, computed at occupancy-update time):

  Step 2-1-a  approximate each cube by its bounding ball,
  Step 2-1-b  project the ball to the image plane as an oval (we use the
              conservative bounding circle of the oval — JAX needs a static
              pixel tile; see DESIGN.md §3),
  Step 2-1-c  the pixels inside the oval, realised as a static TILE x TILE
              pixel window around the projected center with an in-circle mask,
  Step 2-1-d  analytic line-sphere intersection per (pixel-ray, ball) giving
              the sample segment [t_in, t_out].

Cubes are processed front-to-back in the view-dependent order (octants of
the scene, nearest first — Sec. 3.2), so per-pixel transmittance is known
when a cube is reached and invisible points (T <= eps) are skipped. Only the
running (T, partial color) per pixel is kept — no per-point feature buffer.

`chunk` > 1 composites that many cubes per scan step; cubes are spatially
disjoint so this is exact unless two same-chunk cubes overlap the same pixel
(rare under front-to-back ordering; chunk=1 is exact and is the default).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core.occupancy import CubeSet
from repro.core.rendering import Camera, composite, pixel_rays, step_world


# --------------------------------------------------------------------------
# Sec. 3.2 — view-dependent ordering
# --------------------------------------------------------------------------


def octant_rank(origin):
    """Sec. 3.2 octant priorities: rank of each of the 8 scene octants by
    distance of its center to the (normalised) view origin. Host-side
    numpy, and the ONLY implementation — both `order_cubes` (to build the
    schedule) and `ordering_key` (to cache it) consume this, so a cache key
    can never disagree with the schedule it stands for."""
    o = np.asarray(origin, np.float32).reshape(-1)
    o_n = (o / np.maximum(np.abs(o).max(), np.float32(1e-6))).astype(
        np.float32)
    signs = np.array([[sx, sy, sz] for sx in (-1, 1) for sy in (-1, 1)
                      for sz in (-1, 1)], np.float32) * np.float32(0.5)
    d = np.linalg.norm(signs - o_n[None], axis=-1).astype(np.float32)
    return tuple(int(r) for r in np.argsort(np.argsort(d, kind="stable"),
                                            kind="stable"))


def ordering_key(origin, mode: str = "octant", quantum: float = 0.25):
    """Hashable cache key that determines `order_cubes`' output exactly.

    mode="octant": the permutation depends only on the octant ranking
    (within an octant, cubes keep the fixed scan order), so the
    `octant_rank` tuple is an exact reuse key: finitely many schedules,
    shared by every view that ranks octants alike. Keying on the origin's
    octant alone would NOT be sound — two cameras in one octant with
    different dominant axes rank the octants differently, and compositing
    disjoint segments out of order leaks occluded geometry.

    mode="trajectory": the streaming key — the origin quantised to a
    `quantum`-sized grid, so consecutive cameras on a smooth head-tracked
    path share a key (and near-misses are caught by the OrderingCache's
    nearest-neighbour fallback). The schedule itself is the octant
    ordering (exact for the origin that computed it); reusing it from a
    neighbouring pose is the trajectory-level approximation — bounded by
    the quantum, and only ever wrong in the rare case a sub-quantum move
    flips the octant ranking mid-cell.

    mode="distance": the per-cube sort depends on the full origin; key by
    its rounded coordinates (reuse only for effectively identical views).
    """
    if mode == "trajectory":
        o = np.asarray(origin, np.float64).reshape(-1)
        return tuple(int(q) for q in np.round(o / float(quantum)))
    if mode != "octant":
        return tuple(np.round(np.asarray(origin, np.float64), 6).tolist())
    return octant_rank(origin)


class OrderingCache:
    """Cache of per-view `order_cubes` schedules (Sec. 3.2 reuse).

    One entry per `ordering_key`: the first request with a given octant
    ranking computes the front-to-back permutation (and the permuted cube
    arrays, so consumers don't re-gather them); every later view that ranks
    the octants identically reuses it bit-exactly — the paper's
    coarse-grained view-dependent ordering as a cache. `invalidate()` must
    be called when the cube set changes (occupancy rebuild).

    `max_entries` bounds the resident set LRU-style: octant mode has
    finitely many keys anyway, but distance mode keys on the full origin
    and would otherwise grow without bound under a free camera stream.

    mode="trajectory" is the streaming extension (ROADMAP "frame-coherent
    AR/VR streaming"): keys are the origin quantised to `pose_quantum`,
    and an exact-key miss falls back to the nearest cached pose within
    `nn_radius` quanta before recomputing `order_cubes` — so a smooth
    head-tracked path reuses one schedule per neighbourhood instead of
    recomputing per frame. The NN tie-break is (distance, key), not
    insertion order, so lookups are deterministic regardless of LRU churn.

    `scene` is an optional label (the serving SceneStore keys one cache per
    resident scene); `with_cubes(cubes)` is the rebuild path — a NEW cache
    over the new cube set that carries the hit/miss counters forward, so an
    in-flight render keeps its old cache consistent while telemetry stays
    cumulative across occupancy rebuilds and field swaps. When a metrics
    `registry` is supplied, hits and misses are additionally exported as
    `ordering_cache_hits`/`ordering_cache_misses` counters (labelled by
    scene), so cache effectiveness is visible in the exposition endpoints
    — not only in `stats()` polls.
    """

    def __init__(self, cubes: CubeSet, mode: str = "octant",
                 max_entries: int = 64, scene: Optional[str] = None, *,
                 pose_quantum: float = 0.25, nn_radius: float = 1.5,
                 registry=None):
        import collections

        self.cubes = cubes
        self.mode = mode
        self.scene = scene
        self.max_entries = int(max_entries)
        self.pose_quantum = float(pose_quantum)
        self.nn_radius = float(nn_radius)
        self.registry = registry
        self._entries = collections.OrderedDict()  # key -> (perm, ctr, vld)
        self.hits = 0
        self.misses = 0
        self.nn_hits = 0            # subset of hits served by NN fallback
        self._c_hits = self._c_misses = None
        if registry is not None:
            labels = {"scene": scene} if scene is not None else {}
            self._c_hits = registry.counter("ordering_cache_hits", **labels)
            self._c_misses = registry.counter("ordering_cache_misses",
                                              **labels)

    def with_cubes(self, cubes: CubeSet) -> "OrderingCache":
        """Fresh (empty) cache over `cubes`, counters carried over — the
        cube-set-changed path (occupancy rebuild / field swap). A new object
        rather than invalidate-in-place so a snapshot taken before the swap
        keeps rendering from a consistent (cubes, ordering) pair."""
        nxt = OrderingCache(cubes, self.mode, self.max_entries, self.scene,
                            pose_quantum=self.pose_quantum,
                            nn_radius=self.nn_radius, registry=self.registry)
        nxt.hits, nxt.misses, nxt.nn_hits = (self.hits, self.misses,
                                             self.nn_hits)
        return nxt

    def key_for(self, origin) -> tuple:
        return ordering_key(origin, self.mode, self.pose_quantum)

    def _nearest(self, k: tuple):
        """Nearest cached key within `nn_radius` quanta of `k`, or None.
        Tie-break on (distance, key) so the winner doesn't depend on LRU
        order — two passes over the same cache contents pick the same
        entry."""
        best = None
        for k2 in self._entries:
            d = math.dist(k, k2)
            if d <= self.nn_radius and (best is None or (d, k2) < best):
                best = (d, k2)
        return None if best is None else best[1]

    def _note(self, hit: bool, nn: bool = False):
        if hit:
            self.hits += 1
            self.nn_hits += int(nn)
            if self._c_hits is not None:
                self._c_hits.inc()
        else:
            self.misses += 1
            if self._c_misses is not None:
                self._c_misses.inc()

    def _lookup(self, origin) -> tuple:
        k = self.key_for(origin)
        e = self._entries.get(k)
        if e is None and self.mode == "trajectory":
            k_nn = self._nearest(k)
            if k_nn is not None:
                self._note(hit=True, nn=True)
                self._entries.move_to_end(k_nn)
                return self._entries[k_nn]
        if e is None:
            self._note(hit=False)
            perm = order_cubes(self.cubes,
                               jnp.asarray(origin, jnp.float32), self.mode)
            e = (perm, self.cubes.centers[perm], self.cubes.valid[perm])
            self._entries[k] = e
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)      # evict LRU
        else:
            self._note(hit=True)
            self._entries.move_to_end(k)
        return e

    def get(self, origin) -> jax.Array:
        """This view's front-to-back cube permutation."""
        return self._lookup(origin)[0]

    def get_ordered(self, origin):
        """The permuted (centers, valid) arrays for this view."""
        _, centers, valid = self._lookup(origin)
        return centers, valid

    def invalidate(self, cubes: CubeSet = None):
        self._entries.clear()
        if cubes is not None:
            self.cubes = cubes

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "nn_hits": self.nn_hits, "entries": len(self._entries)}


def order_cubes(cubes: CubeSet, origin: jax.Array, mode: str = "octant"):
    """Front-to-back permutation of the cube list for this view.

    mode="octant": the paper's coarse scheme — 8 sub-spaces ranked by
    distance of their centers to the view origin (`octant_rank`, host-side:
    the origin is concrete at schedule-build time); cubes keep their fixed
    scan order within an octant (regular DRAM access pattern).
    mode="trajectory": the octant schedule, cached under quantised-pose
    keys by OrderingCache (the streaming tier's reuse mode).
    mode="distance": per-cube distance sort (finer; beyond-paper).
    """
    c = cubes.centers
    if mode in ("octant", "trajectory"):
        oct_id = ((c[:, 0] > 0).astype(jnp.int32) * 4
                  + (c[:, 1] > 0).astype(jnp.int32) * 2
                  + (c[:, 2] > 0).astype(jnp.int32))
        rank = jnp.asarray(octant_rank(origin), jnp.float32)
        key = rank[oct_id] * (c.shape[0] + 1.0) \
            + jnp.arange(c.shape[0], dtype=jnp.float32)
    else:
        key = jnp.linalg.norm(c - origin[None], axis=-1)
    key = jnp.where(cubes.valid, key, jnp.inf)            # invalid last
    perm = jnp.argsort(key)
    return perm


# --------------------------------------------------------------------------
# Sec. 3.1 — geometry of pre-existing points from non-zero cubes
# --------------------------------------------------------------------------


def auto_tile(cfg: NeRFConfig, cam: Camera) -> int:
    """Static tile size covering the projected ball at the near plane."""
    r_pix = cam.focal * cfg.cube_ball_radius() / max(cfg.near - cfg.scene_bound * 0.0
                                                     - cfg.cube_ball_radius(), 0.5)
    t = int(math.ceil(2.0 * r_pix / 8.0) * 8 + 8)
    return max(8, min(t, 128))


def samples_per_segment(cfg: NeRFConfig) -> int:
    """Static bound on samples inside one ball: ceil(2r / step)."""
    return int(math.ceil(2.0 * cfg.cube_ball_radius() / step_world(cfg))) + 1


def _cube_samples(cfg: NeRFConfig, cam: Camera, center, tile: int,
                  intersect: str = "box"):
    """Steps 2-1-b/c/d for ONE cube. Returns per-tile-pixel sample geometry.

    intersect="ball" is the paper's Step 2-1-d (line-sphere); "box" clips the
    sample segment to the cube itself (line-slab, also analytic), which
    removes the double-counting of overlapping bounding balls — a measured
    beyond-paper accuracy fix (EXPERIMENTS.md §NeRF-ablations).
    """
    # project center
    rel = (center - cam.origin) @ cam.c2w                 # camera coords
    depth = -rel[2]
    r = cfg.cube_ball_radius()
    safe_depth = jnp.maximum(depth - r, 0.1)
    cx = rel[0] / safe_depth * cam.focal + cam.w / 2.0
    cy = -rel[1] / safe_depth * cam.focal + cam.h / 2.0
    r_pix = cam.focal * r / safe_depth

    # static TILE x TILE window around the projected center (Step 2-1-c)
    half = tile // 2
    x0 = jnp.clip(jnp.round(cx).astype(jnp.int32) - half, 0, max(cam.w - tile, 0))
    y0 = jnp.clip(jnp.round(cy).astype(jnp.int32) - half, 0, max(cam.h - tile, 0))
    dx = jnp.arange(tile)
    px = (x0 + dx)[None, :] * jnp.ones((tile, 1), jnp.int32)
    py = (y0 + dx)[:, None] * jnp.ones((1, tile), jnp.int32)
    px = px.reshape(-1)
    py = py.reshape(-1)
    in_oval = (px - cx) ** 2 + (py - cy) ** 2 <= (r_pix + 1.0) ** 2
    in_img = (px < cam.w) & (py < cam.h)
    pix_id = py * cam.w + px

    # Step 2-1-d: analytic intersection (line-sphere or line-slab)
    d = pixel_rays(cam, px.astype(jnp.float32), py.astype(jnp.float32))
    if intersect == "ball":
        oc = cam.origin - center
        b = jnp.einsum("pd,d->p", d, oc)
        disc = b * b - (jnp.dot(oc, oc) - r * r)
        hit_geo = disc > 0.0
        sq = jnp.sqrt(jnp.maximum(disc, 0.0))
        t0 = -b - sq
        t1 = -b + sq
    else:                                             # exact cube slabs
        half = cfg.cube_world() / 2.0
        safe_d = jnp.where(jnp.abs(d) < 1e-9, 1e-9, d)
        ta = (center[None] - half - cam.origin[None]) / safe_d
        tb = (center[None] + half - cam.origin[None]) / safe_d
        t0 = jnp.max(jnp.minimum(ta, tb), axis=-1)
        t1 = jnp.min(jnp.maximum(ta, tb), axis=-1)
        hit_geo = t1 > t0
    hit = hit_geo & in_oval & in_img & (depth > cfg.near * 0.5)
    t0 = jnp.maximum(t0, cfg.near)

    ns = samples_per_segment(cfg)
    delta = step_world(cfg)
    ts = t0[:, None] + (jnp.arange(ns)[None, :] + 0.5) * delta
    s_mask = hit[:, None] & (ts < t1[:, None])            # (P, ns)
    pts = cam.origin[None, None] + d[:, None] * ts[..., None]
    return pix_id, d, pts, ts, s_mask


def compact_select(flat_hit: jax.Array, budget: int) -> jax.Array:
    """Deterministic active-pair selection: the indices of hitting pairs
    first (in ascending pair order), cut to the static `budget`.

    Sorting on the composite key `miss * n + index` makes every key unique,
    so the result cannot depend on any backend's sort stability or
    tie-breaking — the same hit mask selects the same pair set on CPU, TPU,
    and under the numpy oracle (`np.argsort(~hits, kind="stable")`), which
    is what makes dropped-pair choice (and with it the rendered image)
    reproducible across jit invocations and backends."""
    n = flat_hit.shape[0]
    key = ((~flat_hit).astype(jnp.int32) * n
           + jnp.arange(n, dtype=jnp.int32))
    return jnp.argsort(key)[:budget]


def make_ray_renderer(cfg: NeRFConfig, *, chunk: int = 8,
                      pair_budget: int = None, white_bg: bool = True):
    """Ray-centric RT-NeRF renderer (serving path).

    Returns `render(field, centers, valid, rays_o, rays_d) -> (rgb, aux)`
    where `field` is any FieldBackend (a registered pytree, so under
    `jax.jit` a swapped-in field with the same encoded structure reuses the
    compiled step — the serving engine's `swap_field` path), centers/valid
    are the *ordered* cube arrays (apply an order_cubes permutation first —
    e.g. from an OrderingCache) and rays are an arbitrary batch, so one
    jitted instance serves micro-batched rays from many queued views at a
    fixed chunk shape.

    Geometry is the pipeline's exact line-slab intersection (Step 2-1-d,
    intersect="box") per (cube, ray) instead of per (cube, tile-pixel): no
    tile clipping or oval mask, so accuracy is >= the image-space path.
    Early termination and the chunk>1 overlap approximation match
    `render_rtnerf` exactly.

    Sec. 3.1's "process only pre-existing points" is realised by active-pair
    compaction: per scan step the (chunk, N) ray-cube pairs are tested
    geometrically (cheap) and only the hitting pairs — gathered into a
    static `pair_budget` — go through the field/MLP evaluation (expensive).
    Typical scenes hit a few % of pairs, so this is the serving path's main
    algorithmic win over the per-view loop. Pairs beyond the budget are
    dropped and counted in `aux["dropped_pairs"]` (0 in every measured
    scene at the default budget of chunk*N // 4); `aux["active_pairs_max"]`
    is the max hitting-pair count over the scan steps — the occupancy
    signal the serving engine's adaptive pair-budget loop reads to size the
    budget to the scene instead of the static default.

    The field is an argument, not a closure: trace once, serve many, swap
    freely. `aux` carries per-ray transmittance plus processed/dropped
    counters.
    """
    delta = step_world(cfg)
    ns = samples_per_segment(cfg)
    half = cfg.cube_world() / 2.0

    def render(field, centers, valid, rays_o, rays_d):
        f = field_lib.as_backend(field, cfg)
        n_rays = rays_o.shape[0]
        nc = centers.shape[0]
        # pad (never truncate) the cube list to a chunk multiple: a
        # non-divisible cube_chunk must not silently drop trailing cubes
        pad = (-nc) % chunk
        if pad:
            centers = jnp.concatenate(
                [centers, jnp.zeros((pad, 3), centers.dtype)])
            valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
        n_chunks = (nc + pad) // chunk
        n_pairs = chunk * n_rays
        budget = min(pair_budget or max(n_pairs // 4, 128), n_pairs)

        # named_scope markers (zero runtime cost) tag the HLO so XLA
        # profiler captures (serve --profile-dir) line up with the host-side
        # span stages in repro/obs/tracing.py (see docs/observability.md)
        def body(carry, xs):
            log_t, color, depth, processed, dropped, pairs_max = carry
            ctr, vld = xs                                 # (chunk,3),(chunk,)

            # Step 2-1-d: line-slab intersection of every ray with each cube
            with jax.named_scope("rtnerf.intersect"):
                safe_d = jnp.where(jnp.abs(rays_d) < 1e-9, 1e-9, rays_d)
                ta = (ctr[:, None] - half - rays_o[None]) / safe_d[None]
                tb = (ctr[:, None] + half - rays_o[None]) / safe_d[None]
                t0 = jnp.max(jnp.minimum(ta, tb), axis=-1)  # (chunk,N)
                t1 = jnp.min(jnp.maximum(ta, tb), axis=-1)
                alive = jnp.exp(log_t) > cfg.term_eps       # (N,)
                # t1 > near: cubes behind the camera / inside the near plane
                # yield no samples and must not consume pair-budget slots
                hit = (t1 > t0) & (t1 > cfg.near) & vld[:, None] & alive[None]
                t0 = jnp.maximum(t0, cfg.near)

            # active-pair compaction: hitting pairs first (stable), cut to
            # the static budget, evaluate the field only there
            with jax.named_scope("rtnerf.compact"):
                flat_hit = hit.reshape(-1)                # (chunk*N,)
                idx = compact_select(flat_hit, budget)    # hits lead
                sel = flat_hit[idx]                       # (budget,)
                ray_i = idx % n_rays
                t0s = t0.reshape(-1)[idx]
                t1s = t1.reshape(-1)[idx]
                ro_s = rays_o[ray_i]
                rd_s = rays_d[ray_i]

                ts = t0s[:, None] + (jnp.arange(ns)[None] + 0.5) * delta
                s_mask = sel[:, None] & (ts < t1s[:, None])  # (budget,ns)
                pts = ro_s[:, None] + rd_s[:, None] * ts[..., None]
                flat = pts.reshape(-1, 3)
                # points grouped by chunk-local cube (idx // n_rays) so
                # encoded fields stream per-cube factor windows through the
                # fused kernel; non-selected pairs land out-of-window and
                # are masked below
                cube_i = (idx // n_rays).astype(jnp.int32)
                cid = jnp.broadcast_to(cube_i[:, None],
                                       s_mask.shape).reshape(-1)
            with jax.named_scope("rtnerf.field_eval"):
                sigma, feats = f.sigma_app(flat, ctr, cid)
                sigma = jnp.where(s_mask, sigma.reshape(s_mask.shape), 0.0)
                dirs = jnp.broadcast_to(rd_s[:, None],
                                        pts.shape).reshape(-1, 3)
                rgb = f.color(feats, dirs).reshape(*s_mask.shape, 3)

            # per-pair local compositing along the segment
            with jax.named_scope("rtnerf.composite"):
                tau = sigma * delta
                cum = jnp.cumsum(tau, axis=-1)
                t_local = jnp.exp(-(cum - tau))
                alpha = 1.0 - jnp.exp(-tau)
                w = t_local * alpha
                seg_rgb = jnp.sum(w[..., None] * rgb, axis=-2)  # (budget,3)
                seg_d = jnp.sum(w * ts, axis=-1)                # (budget,)
                seg_tau = jnp.where(sel, cum[..., -1], 0.0)     # (budget,)

            # scatter into the per-ray accumulators (pre-chunk T, exactly
            # the image path's chunk>1 approximation)
            with jax.named_scope("rtnerf.scatter"):
                t_here = jnp.exp(log_t)[ray_i]
                contrib = jnp.where(sel[:, None],
                                    t_here[:, None] * seg_rgb, 0.0)
                color = color.at[ray_i].add(contrib)
                depth = depth.at[ray_i].add(
                    jnp.where(sel, t_here * seg_d, 0.0))
                log_t = log_t.at[ray_i].add(-seg_tau)
                processed = processed + jnp.sum(s_mask.astype(jnp.float32))
                n_hit = jnp.sum(flat_hit.astype(jnp.int32))
                dropped = dropped + jnp.maximum(n_hit - budget, 0)
                pairs_max = jnp.maximum(pairs_max, n_hit)
            return (log_t, color, depth, processed, dropped, pairs_max), None

        xs = (centers.reshape(n_chunks, chunk, 3),
              valid.reshape(n_chunks, chunk))
        init = (jnp.zeros((n_rays,), jnp.float32),
                jnp.zeros((n_rays, 3), jnp.float32),
                jnp.zeros((n_rays,), jnp.float32), jnp.float32(0),
                jnp.int32(0), jnp.int32(0))
        (log_t, color, depth, processed, dropped, pairs_max), _ = \
            jax.lax.scan(body, init, xs)
        t_final = jnp.exp(log_t)
        if white_bg:
            color = color + t_final[:, None]
        # depth is the opacity-weighted expected termination distance
        # (sum_k w_k t_k); opacity = 1 - T_final. The serving temporal tier
        # (serving/temporal.py) unprojects depth/opacity to forward-warp
        # this frame's radiance to the next camera.
        return color, {"t_final": t_final, "depth": depth,
                       "opacity": 1.0 - t_final,
                       "processed_samples": processed,
                       "dropped_pairs": dropped,
                       "active_pairs_max": pairs_max}

    return render


def render_rtnerf(field, cfg: NeRFConfig, cubes: CubeSet, cam: Camera, *,
                  order_mode: str = "octant", chunk: int = 1,
                  intersect: str = "box",
                  white_bg: bool = True) -> Tuple[jax.Array, Dict]:
    """Full-image render via the RT-NeRF pipeline. Returns (rgb (H*W,3), stats).

    `field` is anything `field.as_backend` accepts: a DenseField / params
    dict evaluates the raw TensoRF factor arrays (baseline); a
    CompressedField evaluates the hybrid bitmap/COO-encoded factors (paper
    Sec. 4.2.2) — every grid read decodes the compressed stream in place,
    so the field's memory footprint in the hot loop is the encoded bytes.
    """
    f = field_lib.as_backend(field, cfg)
    factor_bytes = f.factor_bytes()
    factor_bytes_dense = f.dense_factor_bytes()
    tile = auto_tile(cfg, cam)
    perm = order_cubes(cubes, cam.origin, order_mode)
    centers = cubes.centers[perm]
    valid = cubes.valid[perm]
    n_pix = cam.h * cam.w
    delta = step_world(cfg)

    nc = centers.shape[0]
    n_chunks = nc // chunk

    def body(carry, xs):
        log_t, color, processed = carry
        ctr, vld = xs                                     # (chunk,3),(chunk,)

        with jax.named_scope("rtnerf.intersect"):
            def per_cube(c):
                return _cube_samples(cfg, cam, c, tile, intersect)
            pix_id, d, pts, ts, s_mask = jax.vmap(per_cube)(ctr)
            s_mask = s_mask & vld[:, None, None]
            P = pix_id.shape[1]

            # Sec. 3.2 early termination: skip points on rays already opaque
            t_here = jnp.exp(log_t.reshape(-1)[pix_id])   # (chunk,P)
            alive = t_here > cfg.term_eps
            s_mask = s_mask & alive[..., None]

            flat = pts.reshape(-1, 3)
            # points grouped by source cube for the fused streaming path
            cid = jnp.broadcast_to(
                jnp.arange(ctr.shape[0], dtype=jnp.int32)[:, None, None],
                s_mask.shape).reshape(-1)
        with jax.named_scope("rtnerf.field_eval"):
            sigma, feats = f.sigma_app(flat, ctr, cid)
            sigma = jnp.where(s_mask, sigma.reshape(s_mask.shape), 0.0)
            dirs = jnp.broadcast_to(d[:, :, None], pts.shape).reshape(-1, 3)
            rgb = f.color(feats, dirs).reshape(*s_mask.shape, 3)

        # per-(cube,pixel) local compositing along the segment
        tau = sigma * delta                               # (chunk,P,ns)
        cum = jnp.cumsum(tau, axis=-1)
        t_local = jnp.exp(-(cum - tau))
        alpha = 1.0 - jnp.exp(-tau)
        w = t_local * alpha
        seg_rgb = jnp.sum(w[..., None] * rgb, axis=-2)    # (chunk,P,3)
        seg_tau = cum[..., -1]                            # (chunk,P)

        # scatter into the running per-pixel (T, color) accumulators
        contrib = (t_here[..., None] * seg_rgb).reshape(-1, 3)
        ids = pix_id.reshape(-1)
        color = color.at[ids].add(contrib)
        log_t = log_t.at[ids].add(-seg_tau.reshape(-1))
        processed = processed + jnp.sum(s_mask.astype(jnp.float32))
        return (log_t, color, processed), None

    log_t0 = jnp.zeros((n_pix,), jnp.float32)
    color0 = jnp.zeros((n_pix, 3), jnp.float32)
    xs = (centers[: n_chunks * chunk].reshape(n_chunks, chunk, 3),
          valid[: n_chunks * chunk].reshape(n_chunks, chunk))
    (log_t, color, processed), _ = jax.lax.scan(body, (log_t0, color0,
                                                       jnp.float32(0)), xs)
    t_final = jnp.exp(log_t)
    if white_bg:
        color = color + t_final[:, None]

    ns = samples_per_segment(cfg)
    stats = {
        # the pipeline touches the occupancy structure once per non-zero cube
        "occ_accesses": jnp.asarray(float(cubes.count), jnp.float32),
        "candidate_samples": jnp.asarray(
            float(cubes.count) * tile * tile * ns, jnp.float32),
        "processed_samples": processed,
        "n_cubes": jnp.asarray(float(cubes.count), jnp.float32),
        "tile": jnp.asarray(float(tile), jnp.float32),
        # field-memory footprint of the hot loop (paper Sec. 4.2.2): the
        # bytes the factor reads stream from, in the active representation
        "factor_bytes": jnp.asarray(float(factor_bytes), jnp.float32),
        "factor_bytes_dense": jnp.asarray(float(factor_bytes_dense),
                                          jnp.float32),
    }
    return color, stats
