"""Unified field representation: one `FieldBackend` API over the dense and
hybrid-compressed (bitmap/COO, paper Sec. 4.2.2) TensoRF parameter sets.

Every consumer of the radiance field — the uniform baseline renderer, the
RT-NeRF pipeline, the serving engine, the trainer, occupancy rebuilds and
checkpoints — talks to this protocol instead of forking on a `field_mode`
string or `isinstance` checks:

  sigma(pts)          density at world points (Eq. 2)
  app_features(pts)   appearance features (Eq. 2 + basis)
  color(feats, dirs)  view-dependent color MLP
  encode()            -> CompressedField (hybrid bitmap/COO per the 80% rule)
  decode()            -> DenseField (exact inverse)
  prune(...)          magnitude pruning (tol- or target-sparsity-based)
  revive(grads, ...)  dense-side support regrowth at re-encode boundaries
                      (ROADMAP "support revival"; RigL-style top-|grad|)
  sparsity_report()   per-factor format / sparsity / bytes
  trainable()         flat dict of float leaves (gradient targets)
  with_trainable(t)   same structure, new float payloads

`DenseField` wraps the raw params dict; `CompressedField` holds every VM
factor in its chosen hybrid format and samples the encoded streams directly
(core/tensorf.py gather path) — the bitmap/COO dispatch is internal to it.
Both are registered JAX pytrees, so fields flow through jit / grad /
device_put / checkpointing like any other parameter tree; the integer codec
metadata (bitmap words, row pointers, COO coords) rides along as non-float
leaves while `trainable()` exposes exactly the differentiable payload — the
mechanism behind compressed-native training (gradients applied to nnz
values between occupancy rebuilds, ROADMAP "compressed training").

`as_backend` is the ONLY place in the codebase that inspects a field's
concrete type; everything else dispatches through the protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.core import sparse, tensorf

# repro-lint jit-purity roots (docs/static_analysis.md): these methods run
# inside jitted render/train steps via dynamic dispatch on the field
# pytree, which static call resolution cannot see.
LINT_JIT_ENTRYPOINTS = ("FieldBackend.sigma_app", "DenseField.sigma",
                        "DenseField.app_features",
                        "CompressedField.sigma_app", "CompressedField.sigma",
                        "CompressedField.app_features")


class FieldBackend:
    """Protocol base. Subclasses hold a `cfg` and implement the field API;
    the color MLP evaluation is shared (both backends keep the MLP dense —
    it is KBs against the factors' MBs)."""

    cfg: NeRFConfig
    kind: str = "abstract"

    # -- evaluation --------------------------------------------------------

    def sigma(self, pts: jax.Array) -> jax.Array:
        raise NotImplementedError

    def app_features(self, pts: jax.Array) -> jax.Array:
        raise NotImplementedError

    def sigma_app(self, pts: jax.Array, cube_centers=None, cube_id=None):
        """(sigma (N,), app_features (N, app_dim)) in one call. Renderers
        that group points by occupancy cube pass `cube_centers` (C, 3
        world) and `cube_id` (N,) so encoded backends can stream per-cube
        factor windows through the fused kernel; the default is the
        two-head composition (no grouping required)."""
        return self.sigma(pts), self.app_features(pts)

    def dispatch_path(self) -> str:
        """Which kernel path `sigma_app` takes on this backend (benchmarks
        record this per run): "dense", "fused", "fused_ref" or "per-op"."""
        return "dense"

    @property
    def mlp_params(self) -> Dict[str, jax.Array]:
        raise NotImplementedError

    def color(self, feats: jax.Array, dirs: jax.Array) -> jax.Array:
        return tensorf.eval_color(self.mlp_params, self.cfg, feats, dirs)

    # -- representation lifecycle -----------------------------------------

    def encode(self, threshold: Optional[float] = None) -> "CompressedField":
        raise NotImplementedError

    def decode(self) -> "DenseField":
        raise NotImplementedError

    def prune(self, sparsity: Optional[float] = None,
              tol: Optional[float] = None) -> "FieldBackend":
        raise NotImplementedError

    # -- training ----------------------------------------------------------

    def trainable(self) -> Dict[str, jax.Array]:
        raise NotImplementedError

    def with_trainable(self, t: Dict[str, jax.Array]) -> "FieldBackend":
        raise NotImplementedError

    def l1(self) -> jax.Array:
        raise NotImplementedError

    def tv(self) -> jax.Array:
        raise NotImplementedError

    # -- accounting --------------------------------------------------------

    def factor_bytes(self) -> int:
        raise NotImplementedError

    def dense_factor_bytes(self) -> int:
        raise NotImplementedError

    def compression_ratio(self) -> float:
        return self.dense_factor_bytes() / max(self.factor_bytes(), 1)

    def sparsity_report(self) -> Dict[str, Dict]:
        raise NotImplementedError


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class DenseField(FieldBackend):
    """The raw TensoRF parameter dict behind the FieldBackend protocol."""

    params: Dict[str, jax.Array]
    cfg: NeRFConfig
    kind = "dense"

    def tree_flatten(self):
        keys = tuple(sorted(self.params))
        return tuple(self.params[k] for k in keys), (keys, self.cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, cfg = aux
        return cls(dict(zip(keys, children)), cfg)

    # -- evaluation --------------------------------------------------------

    def sigma(self, pts):
        return tensorf.eval_sigma(self.params, self.cfg, pts)

    def app_features(self, pts):
        return tensorf.eval_app_features(self.params, self.cfg, pts)

    @property
    def mlp_params(self):
        return self.params

    # -- representation lifecycle -----------------------------------------

    def encode(self, threshold: Optional[float] = None) -> "CompressedField":
        """Hybrid-encode every VM factor (sparse.encode_factor per mode);
        the switch point comes from `threshold` if given, else
        cfg.sparse_threshold."""
        if threshold is None:
            threshold = self.cfg.sparse_threshold
        factors: Dict[str, Tuple[sparse.EncodedFactor, ...]] = {}
        extras = {k: v for k, v in self.params.items()
                  if k not in sparse.FACTOR_KEYS}
        for k in sparse.FACTOR_KEYS:
            w = np.asarray(self.params[k])
            efs = []
            for m in range(3):
                wm = w[m].reshape(w.shape[1], -1)
                ef = sparse.encode_factor(wm, threshold)
                efs.append(dataclasses.replace(ef, nd_shape=w[m].shape))
            factors[k] = tuple(efs)
        return CompressedField(factors=factors, extras=extras, cfg=self.cfg,
                               threshold=threshold)

    def decode(self) -> "DenseField":
        return self

    def prune(self, sparsity: Optional[float] = None,
              tol: Optional[float] = None) -> "DenseField":
        if sparsity is not None:
            return DenseField(
                tensorf.prune_to_sparsity(self.params, sparsity), self.cfg)
        return DenseField(
            tensorf.prune_factors(self.params, tol=1e-3 if tol is None
                                  else tol), self.cfg)

    # -- training ----------------------------------------------------------

    def trainable(self):
        return dict(self.params)

    def with_trainable(self, t):
        return DenseField(dict(t), self.cfg)

    def revive(self, grads: Dict[str, jax.Array], *, frac: float,
               eps: float) -> "DenseField":
        """Support revival (ROADMAP "support revival in compressed
        training"): re-admit pruned factor entries at a re-encode boundary.

        Entries pruned to exact zero receive no gradient between encode
        boundaries (`trainable()` exposes only the packed non-zeros), so a
        frozen support can never regrow. RigL-style regrowth fixes that: per
        VM factor, the top `frac` (of total entries) currently-zero entries
        by |dense loss gradient| are seeded with a one-step move against the
        gradient, magnitude `eps`. Choose `eps` above the prune tolerance so
        the next prune+encode keeps the revived entries in the support,
        where ordinary optimizer steps can grow them. MLP/basis extras are
        untouched (never pruned)."""
        if frac <= 0.0:
            return self
        out = dict(self.params)
        for k in sparse.FACTOR_KEYS:
            w = np.asarray(self.params[k])
            g = np.asarray(grads[k])
            zero = w == 0
            score = np.where(zero, np.abs(g), -1.0).reshape(-1)
            k_top = min(int(frac * score.size), int(zero.sum()))
            if k_top <= 0:
                continue
            top = np.argpartition(-score, k_top - 1)[:k_top]
            top = top[score[top] > 0.0]        # never revive grad-free zeros
            seed = np.zeros(score.size, w.dtype)
            seed[top] = -eps * np.sign(g.reshape(-1)[top])
            out[k] = jnp.asarray(w.reshape(-1) + seed).reshape(w.shape)
        return DenseField(out, self.cfg)

    def l1(self):
        return tensorf.field_l1(self.params)

    def tv(self):
        return tensorf.field_tv(self.params)

    # -- accounting --------------------------------------------------------

    def factor_bytes(self) -> int:
        return sum(int(np.prod(self.params[k].shape)) * 4
                   for k in sparse.FACTOR_KEYS)

    def dense_factor_bytes(self) -> int:
        return self.factor_bytes()

    def sparsity_report(self):
        out = {}
        for k in sparse.FACTOR_KEYS:
            w = np.asarray(self.params[k])
            for m in range(3):
                wm = w[m].reshape(w.shape[1], -1)
                nnz = int((wm != 0).sum())
                b = sparse.storage_bytes(wm.shape, nnz, "dense")
                out[f"{k}[{m}]"] = {
                    "format": "dense", "sparsity": sparse.sparsity(wm),
                    "bytes": b, "dense_bytes": b,
                }
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class CompressedField(FieldBackend):
    """The full TensoRF parameter set with every VM factor hybrid-encoded.

    `factors[key][m]` is the sparse.EncodedFactor for mode m of factor
    tensor `key`; `extras` carries the untouched dense params (basis +
    color MLP). Evaluation samples factors through core/tensorf's gather
    path without ever materialising the dense grids — the paper's
    compressed-domain eval. Which of bitmap / COO / dense each factor uses
    is internal: callers only see the protocol.
    """

    factors: Dict[str, Tuple[sparse.EncodedFactor, ...]]
    extras: Dict[str, jax.Array]
    cfg: NeRFConfig
    threshold: float = 0.80
    kind = "compressed"

    def tree_flatten(self):
        fkeys = tuple(sorted(self.factors))
        ekeys = tuple(sorted(self.extras))
        children = (tuple(self.factors[k] for k in fkeys),
                    tuple(self.extras[k] for k in ekeys))
        return children, (fkeys, ekeys, self.cfg, self.threshold)

    @classmethod
    def tree_unflatten(cls, aux, children):
        fkeys, ekeys, cfg, threshold = aux
        return cls(dict(zip(fkeys, children[0])),
                   dict(zip(ekeys, children[1])), cfg, threshold)

    # -- evaluation --------------------------------------------------------

    def sigma(self, pts):
        return tensorf.eval_sigma_hybrid(self, self.cfg, pts)

    def app_features(self, pts):
        return tensorf.eval_app_features_hybrid(self, self.cfg, pts)

    def sigma_app(self, pts, cube_centers=None, cube_id=None):
        """Fused streaming eval when the caller supplies cube grouping:
        decode per-cube factor windows from the encoded streams, sample,
        and accumulate both heads in one pass (kernels/fused_sample.py).
        Without grouping, the per-point gather composition."""
        if cube_centers is None or cube_id is None:
            return self.sigma(pts), self.app_features(pts)
        base = tensorf.window_base(self.cfg, cube_centers)
        return tensorf.eval_sigma_app_hybrid(self, self.cfg, pts, base,
                                             cube_id)

    def dispatch_path(self) -> str:
        return tensorf.hybrid_dispatch(self)

    @property
    def mlp_params(self):
        return self.extras

    # -- representation lifecycle -----------------------------------------

    def encode(self, threshold: Optional[float] = None) -> "CompressedField":
        if threshold is None or threshold == self.threshold:
            return self
        return self.decode().encode(threshold)

    def decode(self) -> DenseField:
        """Exact inverse of DenseField.encode (reference / testing path)."""
        params = dict(self.extras)
        for k, efs in self.factors.items():
            params[k] = jnp.stack([ef.decode().reshape(ef.nd_shape)
                                   for ef in efs])
        return DenseField(params, self.cfg)

    def prune(self, sparsity: Optional[float] = None,
              tol: Optional[float] = None) -> "CompressedField":
        """Prune re-chooses the support, so it round-trips through the
        dense form and re-encodes — the occupancy-rebuild-time operation,
        never the per-step one."""
        return self.decode().prune(sparsity, tol).encode(self.threshold)

    # -- training ----------------------------------------------------------

    def trainable(self):
        """Float payloads only: packed non-zeros per factor + the dense
        extras. The codec's integer metadata (words/rowptr/coords) is NOT
        here — gradients land on the nnz values and the support stays fixed
        until the next occupancy rebuild re-encodes."""
        out = {f"extras/{k}": v for k, v in self.extras.items()}
        for k, efs in self.factors.items():
            for m, ef in enumerate(efs):
                out[f"factors/{k}/{m}"] = ef.value_array
        return out

    def with_trainable(self, t):
        extras = {k: t[f"extras/{k}"] for k in self.extras}
        factors = {
            k: tuple(ef.with_value_array(t[f"factors/{k}/{m}"])
                     for m, ef in enumerate(efs))
            for k, efs in self.factors.items()}
        return CompressedField(factors, extras, self.cfg, self.threshold)

    def l1(self):
        """Matches tensorf.field_l1 on the decoded field: packed values hold
        every non-zero, and zeros contribute nothing to a mean of |w|."""
        tot = 0.0
        for k, efs in self.factors.items():
            num = sum(jnp.sum(jnp.abs(ef.value_array)) for ef in efs)
            den = sum(int(np.prod(ef.shape)) for ef in efs)
            tot = tot + num / den
        return tot

    def tv(self):
        """Plane smoothness needs the spatial neighborhood, so TV decodes
        the plane factors (differentiably) — loss-only; the render path
        never materialises the grids."""
        def planes(key):
            return jnp.stack([ef.decode().reshape(ef.nd_shape)
                              for ef in self.factors[key]])
        return tensorf.field_tv({"sigma_planes": planes("sigma_planes"),
                                 "app_planes": planes("app_planes")})

    # -- accounting --------------------------------------------------------

    def factor_bytes(self) -> int:
        return sum(ef.storage() for efs in self.factors.values()
                   for ef in efs)

    def dense_factor_bytes(self) -> int:
        return sum(ef.dense_storage() for efs in self.factors.values()
                   for ef in efs)

    def sparsity_report(self):
        out = {}
        for k, efs in self.factors.items():
            for m, ef in enumerate(efs):
                out[f"{k}[{m}]"] = {
                    "format": ef.fmt, "sparsity": ef.sparsity,
                    "bytes": ef.storage(),
                    "dense_bytes": ef.dense_storage(),
                }
        return out


# --------------------------------------------------------------------------
# The single dispatch site
# --------------------------------------------------------------------------


def as_backend(field, cfg: Optional[NeRFConfig] = None) -> FieldBackend:
    """Coerce whatever a caller holds into a FieldBackend.

    This is the ONE place that looks at a field's concrete type: raw params
    dicts become DenseField (cfg required), backends pass through. Every
    renderer / trainer / server entry point funnels through here, so no
    `field_mode` strings or isinstance checks leak into the data path.
    """
    if isinstance(field, FieldBackend):
        return field
    if isinstance(field, dict):
        if cfg is None:
            raise ValueError("as_backend(dict) needs the NeRFConfig")
        return DenseField(dict(field), cfg)
    raise TypeError(
        f"not a field: {type(field).__name__} (expected a FieldBackend or a "
        f"TensoRF params dict; the field_mode= kwarg was removed — encode "
        f"explicitly with DenseField(params, cfg).encode())")


# --------------------------------------------------------------------------
# Serialization (ckpt/checkpoint.py round-trips encoded fields through this
# pair without decompressing)
# --------------------------------------------------------------------------


def field_state(field: FieldBackend):
    """Flatten a backend into (json-able spec, {name: array}). The arrays
    are the pytree leaves under stable string names; the spec captures the
    codec structure (formats, shapes, nnz) so `field_from_state` rebuilds
    the exact encoded representation — no decode on either side."""
    field = as_backend(field)
    if isinstance(field, DenseField):
        return ({"kind": "dense"},
                {f"params/{k}": v for k, v in field.params.items()})
    spec = {"kind": "compressed", "threshold": field.threshold,
            "factors": {}}
    arrays: Dict[str, jax.Array] = {
        f"extras/{k}": v for k, v in field.extras.items()}
    for k, efs in field.factors.items():
        spec["factors"][k] = []
        for m, ef in enumerate(efs):
            spec["factors"][k].append({
                "fmt": ef.fmt, "nd_shape": list(ef.nd_shape),
                "shape": list(ef.shape), "nnz": ef.nnz,
                "sparsity": ef.sparsity,
            })
            base = f"factors/{k}/{m}"
            if ef.fmt == "dense":
                arrays[f"{base}/dense"] = ef.dense
            elif ef.fmt == "bitmap":
                arrays[f"{base}/words"] = ef.bitmap.words
                arrays[f"{base}/rowptr"] = ef.bitmap.rowptr
                arrays[f"{base}/values"] = ef.bitmap.values
            else:
                arrays[f"{base}/coords"] = ef.coo.coords
                arrays[f"{base}/values"] = ef.coo.values
    return spec, arrays


def field_from_state(spec: Dict, arrays: Dict[str, jax.Array],
                     cfg: NeRFConfig) -> FieldBackend:
    """Inverse of `field_state` (arrays may be numpy or jax)."""
    A = {k: jnp.asarray(v) for k, v in arrays.items()}
    if spec["kind"] == "dense":
        return DenseField({k[len("params/"):]: v for k, v in A.items()
                           if k.startswith("params/")}, cfg)
    extras = {k[len("extras/"):]: v for k, v in A.items()
              if k.startswith("extras/")}
    factors: Dict[str, Tuple[sparse.EncodedFactor, ...]] = {}
    for k, metas in spec["factors"].items():
        efs = []
        for m, meta in enumerate(metas):
            base = f"factors/{k}/{m}"
            shape = tuple(meta["shape"])
            ef = sparse.EncodedFactor(
                fmt=meta["fmt"], nd_shape=tuple(meta["nd_shape"]),
                shape=shape, nnz=int(meta["nnz"]),
                sparsity=float(meta["sparsity"]))
            if ef.fmt == "dense":
                ef.dense = A[f"{base}/dense"]
            elif ef.fmt == "bitmap":
                # rank is derived, never serialized: rebuild it so restored
                # fields hit the same O(1) fused lookup path as fresh encodes
                ef.bitmap = sparse.BitmapEncoded(
                    shape, A[f"{base}/words"], A[f"{base}/rowptr"],
                    A[f"{base}/values"], ef.nnz,
                    rank=sparse.bitmap_rank(A[f"{base}/words"],
                                            A[f"{base}/rowptr"]))
            else:
                ef.coo = sparse.CooEncoded(
                    shape, A[f"{base}/coords"], A[f"{base}/values"], ef.nnz)
            efs.append(ef)
        factors[k] = tuple(efs)
    return CompressedField(factors, extras, cfg,
                           float(spec.get("threshold", 0.80)))


def cfg_mismatches(field: FieldBackend, cfg: NeRFConfig) -> List[str]:
    """Shape-compare a (possibly encoded) field against the shapes `cfg`
    would initialise — the restore-time guard against serving a field
    trained under a different NeRFConfig. Returns human-readable mismatch
    descriptions (empty = compatible)."""
    like = jax.eval_shape(lambda k: tensorf.init_field(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))
    field = as_backend(field, cfg)
    got: Dict[str, tuple] = {}
    if isinstance(field, DenseField):
        got = {k: tuple(v.shape) for k, v in field.params.items()}
    else:
        got = {k: tuple(v.shape) for k, v in field.extras.items()}
        for k, efs in field.factors.items():
            got[k] = (len(efs),) + tuple(efs[0].nd_shape)
    bad = []
    for k in like:
        if k not in got:
            bad.append(f"{k}: missing from field")
        elif tuple(got[k]) != tuple(like[k].shape):
            bad.append(f"{k}: field {tuple(got[k])} != "
                       f"cfg {tuple(like[k].shape)}")
    return bad
