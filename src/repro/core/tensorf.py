"""TensoRF VM-decomposed radiance field (paper Eq. 2) in pure JAX.

The 3D embedding grid is decomposed per Eq. 2 into three (matrix, vector)
mode pairs: (M^{Y,Z}, v^X), (M^{X,Z}, v^Y), (M^{X,Y}, v^Z), separately for
density (R_sigma components) and appearance (R_color components). Appearance
features go through a basis matrix and a small view-dependent MLP.

Points live in the axis-aligned box [-bound, bound]^3; grid sampling is
bilinear on planes, linear on lines (as in TensoRF).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.rtnerf import NeRFConfig
from repro.core import sparse
from repro.kernels import bitmap_decode
from repro.kernels import coo_gather as coo_gather_kernel
from repro.kernels import ops
from repro.models.common import Maker, PL, positional_encoding, split_pl

# mode m pairs plane axes PLANE_AXES[m] with line axis LINE_AXES[m]
PLANE_AXES = ((1, 2), (0, 2), (0, 1))   # (Y,Z), (X,Z), (X,Y)
LINE_AXES = (0, 1, 2)                   # X, Y, Z


def init_field(cfg: NeRFConfig, key) -> Dict:
    params, _ = split_pl(init_field_pl(cfg, key))
    return params


def init_field_pl(cfg: NeRFConfig, key) -> Dict:
    g = cfg.grid_res
    mk = Maker(key, dtype=jnp.float32)
    scale = 0.1
    p = {
        "sigma_planes": mk.w((3, cfg.r_sigma, g, g), (None, None, None, None),
                             fan_in=1, scale=scale),
        "sigma_lines": mk.w((3, cfg.r_sigma, g), (None, None, None),
                            fan_in=1, scale=scale),
        "app_planes": mk.w((3, cfg.r_color, g, g), (None, None, None, None),
                           fan_in=1, scale=scale),
        "app_lines": mk.w((3, cfg.r_color, g), (None, None, None),
                          fan_in=1, scale=scale),
        "basis": mk.w((3 * cfg.r_color, cfg.app_dim), (None, None),
                      fan_in=3 * cfg.r_color),
    }
    in_dim = mlp_in_dim(cfg)
    p["mlp_w1"] = mk.w((in_dim, cfg.mlp_hidden), (None, "mlp"), fan_in=in_dim)
    p["mlp_b1"] = mk.z((cfg.mlp_hidden,), ("mlp",))
    p["mlp_w2"] = mk.w((cfg.mlp_hidden, cfg.mlp_hidden), ("mlp", "mlp"),
                       fan_in=cfg.mlp_hidden)
    p["mlp_b2"] = mk.z((cfg.mlp_hidden,), ("mlp",))
    p["mlp_w3"] = mk.w((cfg.mlp_hidden, 3), ("mlp", None), fan_in=cfg.mlp_hidden)
    p["mlp_b3"] = mk.z((3,), (None,))
    return p


def mlp_in_dim(cfg: NeRFConfig) -> int:
    d_dir = 3 + 2 * 3 * cfg.pe_view
    d_feat = cfg.app_dim + 2 * cfg.app_dim * cfg.pe_feat
    return d_dir + d_feat


def to_grid(cfg: NeRFConfig, pts: jax.Array) -> jax.Array:
    """World [-bound,bound]^3 -> continuous grid coords [0, G-1]."""
    return (pts / cfg.scene_bound * 0.5 + 0.5) * (cfg.grid_res - 1)


def _interp_line(line: jax.Array, x: jax.Array) -> jax.Array:
    """line (R, G); x (N,) continuous -> (R, N) linear interp."""
    g = line.shape[-1]
    x = jnp.clip(x, 0.0, g - 1.0)
    x0 = jnp.clip(jnp.floor(x).astype(jnp.int32), 0, g - 2)
    f = x - x0
    return line[:, x0] * (1 - f) + line[:, x0 + 1] * f


def _interp_plane(plane: jax.Array, u: jax.Array, v: jax.Array) -> jax.Array:
    """plane (R, G, G); u,v (N,) -> (R, N) bilinear interp."""
    g = plane.shape[-1]
    u = jnp.clip(u, 0.0, g - 1.0)
    v = jnp.clip(v, 0.0, g - 1.0)
    u0 = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, g - 2)
    v0 = jnp.clip(jnp.floor(v).astype(jnp.int32), 0, g - 2)
    fu, fv = u - u0, v - v0
    p00 = plane[:, u0, v0]
    p01 = plane[:, u0, v0 + 1]
    p10 = plane[:, u0 + 1, v0]
    p11 = plane[:, u0 + 1, v0 + 1]
    return (p00 * (1 - fu) * (1 - fv) + p01 * (1 - fu) * fv
            + p10 * fu * (1 - fv) + p11 * fu * fv)


def vm_components(planes, lines, pts_g) -> jax.Array:
    """Eq. 2 inner products per component: returns (3, R, N)."""
    outs = []
    for m in range(3):
        a, b = PLANE_AXES[m]
        pm = _interp_plane(planes[m], pts_g[:, a], pts_g[:, b])
        lm = _interp_line(lines[m], pts_g[:, LINE_AXES[m]])
        outs.append(pm * lm)
    return jnp.stack(outs)


def eval_sigma(params, cfg: NeRFConfig, pts: jax.Array) -> jax.Array:
    """Density (Eq. 2): sum over modes and components. pts (N,3) world."""
    pts_g = to_grid(cfg, pts)
    comp = vm_components(params["sigma_planes"], params["sigma_lines"], pts_g)
    raw = jnp.sum(comp, axis=(0, 1))
    return jax.nn.softplus(raw)                    # nonneg density


def eval_app_features(params, cfg: NeRFConfig, pts: jax.Array) -> jax.Array:
    pts_g = to_grid(cfg, pts)
    comp = vm_components(params["app_planes"], params["app_lines"], pts_g)
    feat = comp.reshape(3 * cfg.r_color, -1).T     # (N, 3*Rc)
    return feat @ params["basis"]                  # (N, app_dim)


# --------------------------------------------------------------------------
# Compressed-field (hybrid bitmap/COO) evaluation — paper Sec. 4.2.2.
# Samples the encoded factor streams directly: the decode happens per grid
# lookup (bitmap prefix-popcount / COO binary search), never materialising
# the dense grids. Dispatch: Pallas kernels on TPU, jnp oracles on CPU
# (kernels/ops.py `force` semantics).
# --------------------------------------------------------------------------


def gather_factor(ef: "sparse.EncodedFactor", cols: jax.Array,
                  force=None) -> jax.Array:
    """All R rows of an encoded (R, ncols) factor at column indices `cols`
    (N,) -> (R, N). Callers batch the whole interpolation stencil into one
    call, so each factor read is a single fused gather over the stream.
    """
    if ef.fmt == "dense":
        return ef.dense[:, cols]
    rows, ncols = ef.shape
    q = (jnp.arange(rows, dtype=jnp.int32)[:, None] * ncols
         + cols[None, :].astype(jnp.int32)).reshape(-1)
    nq = q.shape[0]
    if ef.fmt == "bitmap":
        block = bitmap_decode.DEFAULT_BLOCK_Q
    else:
        block = coo_gather_kernel.DEFAULT_BLOCK_Q
    pad = (-nq) % block                  # kernel block alignment
    if pad:
        q = jnp.concatenate([q, jnp.zeros((pad,), jnp.int32)])
    if ef.fmt == "bitmap":
        e = ef.bitmap
        out = ops.bitmap_gather(e.words, e.rowptr, e.values, q, cols=ncols,
                                force=force)
    else:
        e = ef.coo
        out = ops.coo_gather(e.coords, e.values, q, force=force)
    return out[:nq].reshape(rows, -1)


def _interp_line_enc(ef, x: jax.Array, force=None) -> jax.Array:
    """Encoded counterpart of _interp_line: (R, N) linear interp. Both
    stencil endpoints go through one gather."""
    g = ef.ncols
    x = jnp.clip(x, 0.0, g - 1.0)
    x0 = jnp.clip(jnp.floor(x).astype(jnp.int32), 0, g - 2)
    f = x - x0
    v0, v1 = jnp.split(
        gather_factor(ef, jnp.concatenate([x0, x0 + 1]), force), 2, axis=1)
    return v0 * (1 - f) + v1 * f


def _interp_plane_enc(ef, u: jax.Array, v: jax.Array, force=None) -> jax.Array:
    """Encoded counterpart of _interp_plane: (R, N) bilinear interp over a
    (R, G, G) plane stored as a (R, G*G) encoded matrix. All four stencil
    corners go through one gather."""
    g = int(ef.nd_shape[-1])
    u = jnp.clip(u, 0.0, g - 1.0)
    v = jnp.clip(v, 0.0, g - 1.0)
    u0 = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, g - 2)
    v0 = jnp.clip(jnp.floor(v).astype(jnp.int32), 0, g - 2)
    fu, fv = u - u0, v - v0
    c00 = u0 * g + v0
    p00, p01, p10, p11 = jnp.split(
        gather_factor(ef, jnp.concatenate([c00, c00 + 1, c00 + g,
                                           c00 + g + 1]), force),
        4, axis=1)
    return (p00 * (1 - fu) * (1 - fv) + p01 * (1 - fu) * fv
            + p10 * fu * (1 - fv) + p11 * fu * fv)


def vm_components_hybrid(plane_efs, line_efs, pts_g, force=None) -> jax.Array:
    """Eq. 2 inner products sampled from the compressed stream: (3, R, N)."""
    outs = []
    for m in range(3):
        a, b = PLANE_AXES[m]
        pm = _interp_plane_enc(plane_efs[m], pts_g[:, a], pts_g[:, b], force)
        lm = _interp_line_enc(line_efs[m], pts_g[:, LINE_AXES[m]], force)
        outs.append(pm * lm)
    return jnp.stack(outs)


def eval_sigma_hybrid(cf, cfg: NeRFConfig,
                      pts: jax.Array, force=None) -> jax.Array:
    """eval_sigma over an encoded field (anything with `.factors` /
    `.extras`, i.e. core/field.CompressedField) — bit-identical math to the
    dense path, but every factor read goes through the hybrid codec."""
    pts_g = to_grid(cfg, pts)
    comp = vm_components_hybrid(cf.factors["sigma_planes"],
                                cf.factors["sigma_lines"], pts_g, force)
    raw = jnp.sum(comp, axis=(0, 1))
    return jax.nn.softplus(raw)


def eval_app_features_hybrid(cf, cfg: NeRFConfig,
                             pts: jax.Array, force=None) -> jax.Array:
    pts_g = to_grid(cfg, pts)
    comp = vm_components_hybrid(cf.factors["app_planes"],
                                cf.factors["app_lines"], pts_g, force)
    feat = comp.reshape(3 * cfg.r_color, -1).T
    return feat @ cf.extras["basis"]


# --------------------------------------------------------------------------
# Fused streaming eval (kernels/fused_sample.py): points grouped by
# occupancy cube decode small per-cube factor windows once, then sample and
# accumulate both heads in a single pass — the Potamoi-style unified
# streaming that makes hybrid the fast path.
# --------------------------------------------------------------------------


def fused_window(cfg: NeRFConfig) -> int:
    """Window span W (grid units) that covers every interpolation stencil a
    single cube's sample points can touch. Sized to the cube's *bounding
    ball* (not the cube) so both intersect modes of the pipeline are
    covered, +1 for the floor low corner, +1 for the stencil high corner,
    +1 slack for the clipped origin."""
    span = (cfg.cube_ball_radius() / cfg.scene_bound) * (cfg.grid_res - 1)
    return min(int(math.ceil(span)) + 3, cfg.grid_res)


def window_base(cfg: NeRFConfig, centers: jax.Array) -> jax.Array:
    """(C, 3) int32 window origins for cube centers (C, 3 world): every
    unmasked sample of cube c has its whole stencil inside
    [base[c], base[c]+W) per axis. Masked (out-of-segment) points may fall
    outside; they read clipped window entries and are zeroed downstream."""
    W = fused_window(cfg)
    gmin = to_grid(cfg, centers - cfg.cube_ball_radius())
    base = jnp.floor(gmin).astype(jnp.int32) - 1
    return jnp.clip(base, 0, cfg.grid_res - W)


def fused_field_inputs(cf) -> Tuple:
    """(spec, streams) flattening of a CompressedField's encoded factors in
    the canonical order of kernels/fused_sample.py (FACTOR_KEYS x mode).
    `spec` is static and hashable (it participates in jit keys); `streams`
    is the matching flat tuple of arrays. Returns (None, None) when any
    factor cannot stream — unknown format, or a bitmap that predates rank
    tables — which sends dispatch down the per-op oracle path."""
    spec, streams = [], []
    for k in sparse.FACTOR_KEYS:
        for ef in cf.factors[k]:
            rows, ncols = ef.shape
            if ef.fmt == "dense":
                spec.append(("dense", rows, ncols))
                streams.append(ef.dense)
            elif ef.fmt == "bitmap":
                e = ef.bitmap
                if e.rank is None:
                    return None, None
                spec.append(("bitmap", rows, ncols))
                streams.extend([e.words, e.rank, e.values])
            elif ef.fmt == "coo":
                spec.append(("coo", rows, ncols))
                streams.extend([ef.coo.coords, ef.coo.values])
            else:
                return None, None
    return tuple(spec), tuple(streams)


def hybrid_dispatch(cf, force=None) -> str:
    """Which path `eval_sigma_app_hybrid` takes for this field on this
    backend: "fused" (Pallas kernel), "fused_ref" (jnp fused oracle) or
    "per-op" (gather-composition fallback). Benchmarks record this so bench
    trajectories are apples-to-apples."""
    spec, _ = fused_field_inputs(cf)
    mode = ops.fused_mode(force)
    if spec is None or mode == "per-op":
        return "per-op"
    return mode


def eval_sigma_app_hybrid(cf, cfg: NeRFConfig, pts: jax.Array,
                          cube_base: jax.Array, cube_id: jax.Array,
                          force=None) -> Tuple[jax.Array, jax.Array]:
    """Single-pass (sigma, app_features) over an encoded field via the
    fused streaming kernel: per-cube factor windows are decoded from the
    bitmap/COO streams in VMEM, sampled, and accumulated into both heads
    sharing one stencil computation. Falls back to the per-op gather
    composition when the field can't stream or dispatch forces "per-op"
    (the contract docs/kernels.md specifies). Exact same math as
    eval_sigma_hybrid + eval_app_features_hybrid."""
    spec, streams = fused_field_inputs(cf)
    mode = ops.fused_mode(force)
    if spec is None or mode == "per-op":
        per_op_force = None if mode == "per-op" else force
        return (eval_sigma_hybrid(cf, cfg, pts, per_op_force),
                eval_app_features_hybrid(cf, cfg, pts, per_op_force))
    raw, feats = ops.fused_sigma_app(
        spec, streams, cf.extras["basis"], pts, cube_base, cube_id,
        grid_res=cfg.grid_res, scene_bound=cfg.scene_bound,
        window=fused_window(cfg), app_dim=cfg.app_dim, force=force)
    return jax.nn.softplus(raw), feats


def eval_color(params, cfg: NeRFConfig, feats: jax.Array,
               dirs: jax.Array) -> jax.Array:
    """View-dependent color MLP. feats (N, app_dim); dirs (N, 3) unit."""
    x = jnp.concatenate([
        positional_encoding(dirs, cfg.pe_view),
        positional_encoding(feats, cfg.pe_feat),
    ], axis=-1)
    h = jax.nn.relu(x @ params["mlp_w1"] + params["mlp_b1"])
    h = jax.nn.relu(h @ params["mlp_w2"] + params["mlp_b2"])
    rgb = jax.nn.sigmoid(h @ params["mlp_w3"] + params["mlp_b3"])
    return rgb


def field_l1(params) -> jax.Array:
    """L1 sparsity regulariser — induces the factor sparsity H1 exploits."""
    return (jnp.mean(jnp.abs(params["sigma_planes"]))
            + jnp.mean(jnp.abs(params["sigma_lines"]))
            + jnp.mean(jnp.abs(params["app_planes"]))
            + jnp.mean(jnp.abs(params["app_lines"])))


def field_tv(params) -> jax.Array:
    """Total-variation on planes (smoothness)."""
    def tv(p):
        d1 = jnp.mean(jnp.square(p[..., 1:, :] - p[..., :-1, :]))
        d2 = jnp.mean(jnp.square(p[..., :, 1:] - p[..., :, :-1]))
        return d1 + d2
    return tv(params["sigma_planes"]) + tv(params["app_planes"])


def prune_factors(params, tol: float = 1e-3):
    """Hard-threshold tiny factor entries to exact zeros (post-training step
    that realises the sparsity the hybrid encoding consumes)."""
    out = dict(params)
    for k in sparse.FACTOR_KEYS:
        w = params[k]
        out[k] = jnp.where(jnp.abs(w) < tol, 0.0, w)
    return out


def prune_to_sparsity(params, target: float):
    """Magnitude-prune each factor tensor to (at least) `target` fraction of
    exact zeros — the post-training sparsification step that puts the field
    into the regime the hybrid codec is built for (paper Fig. 5 reports
    50-90% natural sparsity; this makes the level explicit and tunable)."""
    out = dict(params)
    for k in sparse.FACTOR_KEYS:
        w = params[k]
        thresh = jnp.quantile(jnp.abs(w).reshape(-1), target)
        out[k] = jnp.where(jnp.abs(w) <= thresh, 0.0, w)
    return out


def factor_sparsity(params) -> Dict[str, float]:
    """Fraction of exact zeros per factor (paper Fig. 5)."""
    out = {}
    for k in sparse.FACTOR_KEYS:
        w = params[k]
        out[k] = float(jnp.mean(w == 0.0))
    return out
