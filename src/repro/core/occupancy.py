"""Binary occupancy grid + non-zero cube extraction (paper Step 2-1 inputs).

The cube list is computed host-side at occupancy-update time (a rare event,
analogous to the paper's offline encoding step) and padded to a static
`max_cubes` so the rendering pipeline stays jit-compatible.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib


class CubeSet(NamedTuple):
    """Static-shape set of non-zero occupancy cubes."""
    centers: jax.Array      # (max_cubes, 3) world-space centers
    valid: jax.Array        # (max_cubes,) bool
    count: int              # python int — true number of cubes
    radius: float           # bounding-ball radius (Step 2-1-a)
    occ: jax.Array          # (G,G,G) bool — for baseline queries


def grid_coords(cfg: NeRFConfig) -> jax.Array:
    g = cfg.occ_res
    xs = (jnp.arange(g) + 0.5) / g * 2.0 - 1.0      # (-1,1) cell centers
    return xs * cfg.scene_bound


def build_occupancy(field, cfg: NeRFConfig,
                    sigma_thresh: Optional[float] = None,
                    chunk: int = 65536) -> jax.Array:
    """Evaluate sigma on the occupancy grid -> (G,G,G) bool.

    `field` is anything `field.as_backend` accepts (params dict or backend —
    encoded fields are sampled in place, no decode). The cutoff defaults to
    `cfg.occ_sigma_thresh`, the ONE rebuild threshold every site shares
    (training rebuilds, post-prune rebuilds, serving `swap_field`)."""
    if sigma_thresh is None:
        sigma_thresh = cfg.occ_sigma_thresh
    f = field_lib.as_backend(field, cfg)
    g = cfg.occ_res
    xs = grid_coords(cfg)
    pts = jnp.stack(jnp.meshgrid(xs, xs, xs, indexing="ij"), axis=-1
                    ).reshape(-1, 3)
    outs = []
    eval_j = jax.jit(lambda fb, q: fb.sigma(q))
    for i in range(0, pts.shape[0], chunk):
        outs.append(eval_j(f, pts[i:i + chunk]))
    sig = jnp.concatenate(outs).reshape(g, g, g)
    return sig > sigma_thresh


def extract_cubes(occ: jax.Array, cfg: NeRFConfig) -> CubeSet:
    """Max-pool occupancy into cubes; list non-zero cube centers (host-side)."""
    g, cs = cfg.occ_res, cfg.cube_size
    gc = g // cs
    occ_np = np.asarray(occ).reshape(gc, cs, gc, cs, gc, cs)
    cube_occ = occ_np.any(axis=(1, 3, 5))           # (gc,gc,gc)
    idx = np.argwhere(cube_occ)                     # (n, 3)
    n = idx.shape[0]
    if n > cfg.max_cubes:
        # keep densest cubes (by voxel count) under the static bound
        counts = occ_np.sum(axis=(1, 3, 5))[tuple(idx.T)]
        keep = np.argsort(-counts)[: cfg.max_cubes]
        idx = idx[keep]
        n = cfg.max_cubes
    pad = np.zeros((cfg.max_cubes, 3), np.int32)
    pad[:n] = idx
    cube_world = 2.0 * cfg.scene_bound * cs / g     # cube edge length
    centers = (pad + 0.5) * cube_world - cfg.scene_bound
    valid = np.zeros(cfg.max_cubes, bool)
    valid[:n] = True
    radius = cube_world * np.sqrt(3.0) / 2.0        # Step 2-1-a: ball
    return CubeSet(jnp.asarray(centers, jnp.float32), jnp.asarray(valid),
                   int(n), float(radius), occ)


def occupancy_query(occ: jax.Array, cfg: NeRFConfig, pts: jax.Array):
    """Baseline Step 2-1: quantize points, look up the binary grid."""
    g = cfg.occ_res
    ijk = jnp.clip(((pts / cfg.scene_bound * 0.5 + 0.5) * g).astype(jnp.int32),
                   0, g - 1)
    inside = jnp.all(jnp.abs(pts) <= cfg.scene_bound, axis=-1)
    return occ[ijk[..., 0], ijk[..., 1], ijk[..., 2]] & inside
