"""Distributed NeRF: the paper's workload on the production meshes.

Sharding plan (DESIGN.md §7):
  * rays/pixels over the batch axes ("pod","data") — rendering is ray-
    parallel; each frame request fans out over the data axes,
  * VM component channels R over "model" — Eq. 2 is a sum over R, so each
    model shard evaluates its component slice and GSPMD inserts one tiny
    all-reduce of the (N,) partials,
  * the MLP + occupancy grid replicated (KBs).

Training uses the differentiable uniform pipeline (as TensoRF does); the
cube-centric RT-NeRF pipeline is the serving path — cube-chunk-parallel
across the data axes with the same commutative-transmittance argument as
`chunk>1` (core/pipeline.py docstring).

`lower_nerf_cell` mirrors launch/steps.lower_cell so launch/dryrun.py can
prove the rtnerf x {train_rays, render_800} x {pod, multipod} cells compile.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.rtnerf import NERF_SHAPES, NeRFConfig, NeRFShape
from repro.core import rendering, tensorf
from repro.models.sharding import AxisRules, make_rules
from repro.optim import adamw


def nerf_param_sharding(cfg: NeRFConfig, params, rules: AxisRules):
    """R-channel (component) sharding for planes/lines; rest replicated."""
    mesh = rules.mesh

    def spec_for(name, arr):
        if "planes" in name or "lines" in name:
            r = arr.shape[1]
            m = mesh.shape.get("model", 1)
            if m > 1 and r % m == 0:
                return NamedSharding(mesh, P(None, "model"))
        return NamedSharding(mesh, P())

    return {k: spec_for(k, v) for k, v in params.items()}


def ray_sharding(rules: AxisRules, n_rays: int):
    mesh = rules.mesh
    batch_axes = [a for a in ("pod", "data") if a in mesh.shape]
    size = 1
    for a in batch_axes:
        size *= mesh.shape[a]
    if size > 1 and n_rays % size == 0:
        spec = P(tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0])
    else:
        spec = P()
    return NamedSharding(mesh, spec)


def stream_sharding(rules: AxisRules) -> NamedSharding:
    """Sharding for the resident field's encoded streams (bitmap words /
    rowptr / values, COO coords / values, dense factors, MLP): replicated.
    Every device walks the whole stream — the streams are KB-to-MB scale and
    read-only, while rays are the hot, shardable axis (`ray_sharding`)."""
    return NamedSharding(rules.mesh, P())


def place_field(field, rules: AxisRules):
    """device_put a resident serving field onto the mesh: every stream array
    replicated (stream_sharding). Any FieldBackend (or params dict) is a
    registered pytree, so this is one placement call over the whole tree —
    encoded bitmap/COO streams, integer metadata and MLP alike; on a
    single-device mesh it is a plain device placement (the serving engine's
    fallback path)."""
    return jax.device_put(field, stream_sharding(rules))


def shard_rays(rules: AxisRules, rays_o, rays_d):
    """Place one micro-batched ray chunk across the mesh's batch axes
    (falls back to replication when the chunk doesn't divide the mesh —
    the single-device path)."""
    sh = ray_sharding(rules, rays_o.shape[0])
    return jax.device_put(rays_o, sh), jax.device_put(rays_d, sh)


def build_render_step(cfg: NeRFConfig):
    """Batched novel-view rendering: rays -> rgb (uniform pipeline with a
    replicated occupancy grid; the serving analogue of Step 2-1/2-2/3)."""

    def render_step(params, occ, rays_o, rays_d):
        from repro.core.occupancy import CubeSet
        cubes = CubeSet(centers=jnp.zeros((1, 3)), valid=jnp.ones((1,), bool),
                        count=1, radius=0.0, occ=occ)
        rgb, _ = rendering.render_uniform(params, cfg, cubes, rays_o, rays_d)
        return rgb

    return render_step


def build_nerf_train_step(cfg: NeRFConfig, opt):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            rgb, _ = rendering.render_uniform(p, cfg, None, batch["rays_o"],
                                              batch["rays_d"],
                                              use_occupancy=False)
            mse = jnp.mean(jnp.square(rgb - batch["rgb"]))
            return mse + cfg.sigma_sparsity_l1 * tensorf.field_l1(p) \
                + cfg.tv_weight * tensorf.field_tv(p)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def nerf_input_specs(cfg: NeRFConfig, shape: NeRFShape):
    n = shape.n_rays
    specs = {
        "rays_o": jax.ShapeDtypeStruct((n, 3), jnp.float32),
        "rays_d": jax.ShapeDtypeStruct((n, 3), jnp.float32),
    }
    if shape.kind == "train":
        specs["rgb"] = jax.ShapeDtypeStruct((n, 3), jnp.float32)
    return specs


def lower_nerf_cell(cfg: NeRFConfig, shape: NeRFShape, mesh):
    """AOT-lower the rtnerf cell on a production mesh (dry-run entry)."""
    rules = make_rules(mesh)
    params_sds = jax.eval_shape(lambda k: tensorf.init_field(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_sh = nerf_param_sharding(cfg, params_sds, rules)
    in_specs = nerf_input_specs(cfg, shape)
    r_sh = ray_sharding(rules, shape.n_rays)
    repl = NamedSharding(mesh, P())
    info = {"n_params": sum(int(x.size) for x in jax.tree.leaves(params_sds)),
            "n_active": cfg.param_count()}

    if shape.kind == "train":
        opt = adamw(lr=cfg.lr_grid)
        state_sds = jax.eval_shape(opt.init, params_sds)
        s_sh = {"step": repl, "m": p_sh, "v": p_sh}
        fn = build_nerf_train_step(cfg, opt)
        jfn = jax.jit(fn,
                      in_shardings=(p_sh, s_sh,
                                    {k: r_sh for k in in_specs}),
                      out_shardings=(p_sh, s_sh, None),
                      donate_argnums=(0, 1))
        lowered = jfn.lower(params_sds, state_sds, in_specs)
        return lowered, info

    occ_sds = jax.ShapeDtypeStruct((cfg.occ_res,) * 3, jnp.bool_)
    fn = build_render_step(cfg)
    jfn = jax.jit(fn, in_shardings=(p_sh, repl, r_sh, r_sh))
    lowered = jfn.lower(params_sds, occ_sds,
                        in_specs["rays_o"], in_specs["rays_d"])
    return lowered, info
