"""Hybrid sparse encoding (paper H1, Sec. 4.2.2) as a generic codec.

Formats:
  dense   — raw array.
  bitmap  — 1 bit/element metadata + packed non-zero values + row pointers
            (the paper's fixed-latency variant: rowptr[i] = index of row i's
            first non-zero in the packed array, so any (x, y) lookup costs a
            bounded prefix-popcount — 3 cycles in the ASIC, one vectorised
            VMEM pass in the Pallas kernel).
  coo     — sorted linearised coordinates (int32) + values, decoded by
            branchless binary search (the ASIC's search tree, data-parallel).

API: `encode_factor(w, threshold) -> EncodedFactor` picks a format per the
paper's 80% sparsity switch (`choose_format`) and packs the stream;
`EncodedFactor.decode()` is the exact inverse; `.with_value_array(v)`
swaps float payloads without touching the integer support (the hook
compressed-native training optimises through); `storage_bytes` exposes the
size model that justifies the switch (ROADMAP "hybrid bitmap/COO
encoding"). Consumers: TensoRF VM factors via core/field.py and (beyond
paper) MoE dispatch mode selection in models/moe.py.

This module is the pure codec layer. The field-level container that packages
a whole TensoRF factor set in encoded form — and the dense/compressed
dispatch — live in core/field.py (`FieldBackend` / `CompressedField`); the
renderer samples the encoded streams through core/tensorf.gather_factor.
All encoded containers are registered as JAX pytrees so fields flow through
jit / grad / device_put / checkpointing without special cases.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(eq=False)
class BitmapEncoded:
    shape: tuple
    words: jax.Array      # (rows, ceil(cols/32)) uint32 bitmap
    rowptr: jax.Array     # (rows,) int32 — start of each row in `values`
    values: jax.Array     # (nnz_pad,) packed non-zeros (padded)
    nnz: int
    # Per-word rank table (rows, W) int32: rank[r, w] = packed index of word
    # w's first non-zero in row r (rowptr folded in). Derived from
    # words/rowptr at encode time (never serialized — see bitmap_rank), it
    # turns a lookup's O(W) masked prefix-popcount into O(1): one rank read
    # plus the popcount of a single masked word. The fused kernel's
    # "popcount-based rank lookup".
    rank: Optional[jax.Array] = None


@dataclasses.dataclass(eq=False)
class CooEncoded:
    shape: tuple
    coords: jax.Array     # (nnz_pad,) int32 sorted linear indices (pad = INT32_MAX)
    values: jax.Array     # (nnz_pad,)
    nnz: int


jax.tree_util.register_pytree_node(
    BitmapEncoded,
    lambda e: ((e.words, e.rowptr, e.values, e.rank), (e.shape, e.nnz)),
    lambda aux, ch: BitmapEncoded(aux[0], ch[0], ch[1], ch[2], aux[1],
                                  rank=ch[3]))
jax.tree_util.register_pytree_node(
    CooEncoded,
    lambda e: ((e.coords, e.values), (e.shape, e.nnz)),
    lambda aux, ch: CooEncoded(aux[0], ch[0], ch[1], aux[1]))


PAD_COORD = np.iinfo(np.int32).max


def sparsity(w) -> float:
    w = np.asarray(w)
    return float((w == 0).mean())


def choose_format(s: float, threshold: float = 0.80) -> str:
    """The paper's rule: bitmap below the threshold, COO at/above it."""
    return "coo" if s >= threshold else "bitmap"


def bitmap_rank(words, rowptr) -> jax.Array:
    """Per-word rank table for a bitmap stream: rank[r, w] = rowptr[r] +
    popcount(words[r, :w]). Pure function of (words, rowptr), so restore
    paths recompute it instead of serializing it (checkpoints stay
    byte-compatible across PRs)."""
    pc = jax.lax.population_count(jnp.asarray(words)).astype(jnp.int32)
    prefix = jnp.cumsum(pc, axis=1) - pc
    return jnp.asarray(rowptr, jnp.int32)[:, None] + prefix


def encode_bitmap(w, pad_to: Optional[int] = None) -> BitmapEncoded:
    w = np.asarray(w)
    assert w.ndim == 2, "bitmap codec operates on matrices (vectors: (1, n))"
    rows, cols = w.shape
    nz = w != 0
    wc = ((cols + 31) // 32) * 32
    bits = np.zeros((rows, wc), np.uint32)
    bits[:, :cols] = nz
    words = np.zeros((rows, wc // 32), np.uint32)
    for b in range(32):
        words |= bits[:, b::32] << np.uint32(b)
    counts = nz.sum(axis=1)
    rowptr = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int32)
    vals = w[nz].astype(w.dtype)
    nnz = int(vals.size)
    pad = pad_to if pad_to is not None else ((nnz + 127) // 128) * 128 or 128
    values = np.zeros((pad,), w.dtype)
    values[:nnz] = vals
    return BitmapEncoded((rows, cols), jnp.asarray(words),
                         jnp.asarray(rowptr), jnp.asarray(values), nnz,
                         rank=bitmap_rank(words, rowptr))


def encode_coo(w, pad_to: Optional[int] = None) -> CooEncoded:
    w = np.asarray(w)
    flat = w.reshape(-1)
    idx = np.nonzero(flat)[0].astype(np.int32)
    vals = flat[idx]
    nnz = int(idx.size)
    pad = pad_to if pad_to is not None else ((nnz + 127) // 128) * 128 or 128
    coords = np.full((pad,), PAD_COORD, np.int32)
    coords[:nnz] = idx
    values = np.zeros((pad,), w.dtype)
    values[:nnz] = vals
    return CooEncoded(w.shape, jnp.asarray(coords), jnp.asarray(values), nnz)


def decode_bitmap(enc: BitmapEncoded) -> jax.Array:
    """jnp oracle: reconstruct the dense matrix."""
    rows, cols = enc.shape
    wc = enc.words.shape[1] * 32
    bpos = jnp.arange(wc, dtype=jnp.uint32)
    bits = (enc.words[:, bpos // 32] >> (bpos % 32)) & 1       # (rows, wc)
    bits = bits[:, :cols].astype(jnp.int32)
    pos = jnp.cumsum(bits, axis=1) - bits                       # prefix count
    addr = enc.rowptr[:, None] + pos
    vals = enc.values[jnp.clip(addr, 0, enc.values.shape[0] - 1)]
    return jnp.where(bits > 0, vals, 0).astype(enc.values.dtype)


def decode_coo(enc: CooEncoded) -> jax.Array:
    flat = jnp.zeros((int(np.prod(enc.shape)),), enc.values.dtype)
    ok = enc.coords != PAD_COORD
    safe = jnp.where(ok, enc.coords, 0)
    flat = flat.at[safe].add(jnp.where(ok, enc.values, 0))
    return flat.reshape(enc.shape)


def bitmap_lookup_linear(words: jax.Array, rowptr: jax.Array,
                         values: jax.Array, queries: jax.Array,
                         cols: int, rank: Optional[jax.Array] = None
                         ) -> jax.Array:
    """jnp oracle: random access into a bitmap-encoded matrix (raw arrays).

    queries (Q,) linear indices into the row-major (rows, cols) matrix. The
    lookup is the paper's fixed-latency path: one bit test plus a bounded
    prefix-popcount over the query row's bitmap words to find the packed
    address (3 cycles in the ASIC; one word-vector popcount here). This is
    the single source of truth for the decode math; kernels/ref.py delegates
    here and the Pallas kernels (kernels/bitmap_decode.py,
    kernels/fused_sample.py) mirror it.

    Without `rank`, the prefix is a masked popcount over the whole query row
    (O(W) per query — the from-first-principles reference form). With the
    precomputed `bitmap_rank` table the same address is rank[r, wi] +
    popcount of ONE masked word (O(1) per query — the fused fast path);
    the two are tested equal.
    """
    r = queries // cols
    c = queries % cols
    wi = (c // 32).astype(jnp.int32)
    bi = (c % 32).astype(jnp.uint32)
    below = jnp.left_shift(jnp.uint32(1), bi) - jnp.uint32(1)
    if rank is None:
        qwords = words[r]                                   # (Q, W)
        widx = jnp.arange(words.shape[1], dtype=jnp.int32)[None, :]
        mask = jnp.where(widx < wi[:, None], jnp.uint32(0xFFFFFFFF),
                         jnp.where(widx == wi[:, None], below[:, None],
                                   jnp.uint32(0)))
        prefix = jnp.sum(jax.lax.population_count(qwords & mask), axis=1)
        addr = rowptr[r] + prefix.astype(jnp.int32)
    else:
        word_at = words[r, wi]
        prefix = jax.lax.population_count(word_at & below)
        addr = rank[r, wi] + prefix.astype(jnp.int32)
    bit = (words[r, wi] >> bi) & jnp.uint32(1)
    vals = values[jnp.clip(addr, 0, values.shape[0] - 1)]
    return jnp.where(bit > 0, vals, 0).astype(values.dtype)


def bitmap_lookup(enc: BitmapEncoded, queries: jax.Array) -> jax.Array:
    """bitmap_lookup_linear over an encoded container (rank-accelerated
    when the table is present)."""
    return bitmap_lookup_linear(enc.words, enc.rowptr, enc.values, queries,
                                enc.shape[1], rank=enc.rank)


def coo_lookup(enc: CooEncoded, queries: jax.Array) -> jax.Array:
    """Branchless binary search over sorted coords. queries (Q,) linear idx."""
    n = enc.coords.shape[0]
    steps = max(int(np.ceil(np.log2(n))), 1) + 1   # +1: converge to lo == hi
    lo = jnp.zeros_like(queries)
    hi = jnp.full_like(queries, n)
    for _ in range(steps):
        mid = (lo + hi) // 2
        go_right = enc.coords[mid] < queries
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    found = (lo < n) & (enc.coords[jnp.clip(lo, 0, n - 1)] == queries)
    return jnp.where(found, enc.values[jnp.clip(lo, 0, n - 1)], 0)


def storage_bytes(shape, nnz: int, fmt: str, elem_bytes: int = 4) -> int:
    """Size model behind the 80% threshold (paper Sec. 4.2.2 / DESIGN §3)."""
    total = int(np.prod(shape))
    rows = shape[0] if len(shape) == 2 else 1
    if fmt == "dense":
        return total * elem_bytes
    if fmt == "bitmap":
        return total // 8 + rows * 4 + nnz * elem_bytes
    if fmt == "coo":
        return nnz * (4 + elem_bytes)
    raise ValueError(fmt)


def encode_hybrid(w, threshold: float = 0.80):
    """The full H1 codec: measure sparsity, pick format, encode."""
    s = sparsity(w)
    fmt = choose_format(s, threshold)
    enc = encode_coo(w) if fmt == "coo" else encode_bitmap(np.atleast_2d(np.asarray(w)))
    return fmt, s, enc


# --------------------------------------------------------------------------
# Encoded VM factor — the renderer-facing unit of the H1 codec
# --------------------------------------------------------------------------

FACTOR_KEYS = ("sigma_planes", "sigma_lines", "app_planes", "app_lines")


@dataclasses.dataclass(eq=False)
class EncodedFactor:
    """One VM factor slice (mode m of a plane/line tensor) in its chosen
    format. The matrix view is (R, ncols): ncols = G*G for planes, G for
    lines; `nd_shape` remembers the original (R, G[, G]) layout."""
    fmt: str                        # "dense" | "bitmap" | "coo"
    nd_shape: tuple                 # original per-mode factor shape
    shape: tuple                    # (R, ncols) matrix view
    nnz: int
    sparsity: float
    dense: Optional[jax.Array] = None       # fmt == "dense"
    bitmap: Optional[BitmapEncoded] = None  # fmt == "bitmap"
    coo: Optional[CooEncoded] = None        # fmt == "coo"

    @property
    def ncols(self) -> int:
        return self.shape[1]

    @property
    def value_array(self) -> jax.Array:
        """The float payload of this factor — the packed non-zeros for
        bitmap/COO, the raw matrix for dense. This is the *trainable* leaf:
        gradients applied here update the encoded field in place (the
        bitmap/coords structure stays fixed between occupancy rebuilds)."""
        if self.fmt == "dense":
            return self.dense
        if self.fmt == "bitmap":
            return self.bitmap.values
        return self.coo.values

    def with_value_array(self, v: jax.Array) -> "EncodedFactor":
        """Same structure, new float payload (optimizer-step update)."""
        if self.fmt == "dense":
            return dataclasses.replace(self, dense=v)
        if self.fmt == "bitmap":
            return dataclasses.replace(
                self, bitmap=dataclasses.replace(self.bitmap, values=v))
        return dataclasses.replace(
            self, coo=dataclasses.replace(self.coo, values=v))

    def storage(self) -> int:
        return storage_bytes(self.shape, self.nnz, self.fmt)

    def dense_storage(self) -> int:
        return storage_bytes(self.shape, self.nnz, "dense")

    def decode(self) -> jax.Array:
        """Reconstruct the dense (R, ncols) matrix (jnp oracle path)."""
        if self.fmt == "dense":
            return self.dense
        if self.fmt == "bitmap":
            return decode_bitmap(self.bitmap)
        return decode_coo(self.coo)


jax.tree_util.register_pytree_node(
    EncodedFactor,
    lambda e: ((e.dense, e.bitmap, e.coo),
               (e.fmt, e.nd_shape, e.shape, e.nnz, e.sparsity)),
    lambda aux, ch: EncodedFactor(aux[0], aux[1], aux[2], aux[3], aux[4],
                                  ch[0], ch[1], ch[2]))


def encode_factor(wm, threshold: float = 0.80) -> EncodedFactor:
    """Encode one (R, ncols) factor matrix per the 80% rule. A factor whose
    encoded form would not beat its dense bytes stays dense (don't pessimize
    nearly-dense fields); otherwise bitmap below the sparsity threshold, COO
    at/above it. `nd_shape` is attached by the caller (core/field.py)."""
    wm = np.asarray(wm)
    s = sparsity(wm)
    nnz = int((wm != 0).sum())
    fmt = choose_format(s, threshold)
    if storage_bytes(wm.shape, nnz, fmt) >= \
            storage_bytes(wm.shape, nnz, "dense"):
        fmt = "dense"
    ef = EncodedFactor(fmt=fmt, nd_shape=wm.shape, shape=wm.shape,
                       nnz=nnz, sparsity=s)
    if fmt == "dense":
        ef.dense = jnp.asarray(wm)
    elif fmt == "bitmap":
        ef.bitmap = encode_bitmap(wm)
    else:
        ef.coo = encode_coo(wm)
    return ef


def factor_report(params) -> Dict[str, Dict]:
    """Per-factor encoding decision + storage for the TensoRF field params."""
    out = {}
    for k in FACTOR_KEYS:
        w = np.asarray(params[k])
        for m in range(3):
            wm = w[m].reshape(w.shape[1], -1)
            s = sparsity(wm)
            fmt = choose_format(s)
            nnz = int((wm != 0).sum())
            out[f"{k}[{m}]"] = {
                "sparsity": s,
                "format": fmt,
                "dense_bytes": storage_bytes(wm.shape, nnz, "dense"),
                "bitmap_bytes": storage_bytes(wm.shape, nnz, "bitmap"),
                "coo_bytes": storage_bytes(wm.shape, nnz, "coo"),
                "chosen_bytes": storage_bytes(wm.shape, nnz, fmt),
            }
    return out
