"""RT-NeRF core: the paper's contribution as composable JAX modules."""
from repro.core import field, occupancy, pipeline, rendering, sparse, tensorf  # noqa: F401
