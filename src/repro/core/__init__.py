"""RT-NeRF core: the paper's contribution as composable JAX modules."""
from repro.core import occupancy, pipeline, rendering, sparse, tensorf  # noqa: F401
