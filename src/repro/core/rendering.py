"""Volume rendering (paper Eq. 1) + the uniform-sampling baseline pipeline.

The baseline is TensoRF's rendering path (paper Fig. 3): uniform samples
along every ray, occupancy-grid query per sample, feature computation for
surviving samples, early-ray-termination on accumulated transmittance.
RT-NeRF's pipeline (core/pipeline.py) replaces Steps 2-1/2-2; Eq. 1
integration is shared.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.rtnerf import NeRFConfig
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib


class Camera(NamedTuple):
    c2w: jax.Array        # (3,3) rotation, columns = camera axes in world
    origin: jax.Array     # (3,)
    focal: float
    h: int
    w: int


def look_at_camera(origin, target, focal, h, w) -> Camera:
    origin = jnp.asarray(origin, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    fwd = target - origin
    fwd = fwd / jnp.linalg.norm(fwd)
    up0 = jnp.array([0.0, 0.0, 1.0])
    right = jnp.cross(fwd, up0)
    right = right / jnp.maximum(jnp.linalg.norm(right), 1e-8)
    up = jnp.cross(right, fwd)
    # camera axes: x=right, y=up, z=-fwd (OpenGL-style)
    c2w = jnp.stack([right, up, -fwd], axis=1)
    return Camera(c2w, origin, float(focal), int(h), int(w))


def pixel_rays(cam: Camera, px: jax.Array, py: jax.Array):
    """px,py (N,) pixel coords -> unit ray dirs (N,3) in world."""
    x = (px + 0.5 - cam.w / 2.0) / cam.focal
    y = -(py + 0.5 - cam.h / 2.0) / cam.focal
    d_cam = jnp.stack([x, y, -jnp.ones_like(x)], axis=-1)
    d = d_cam @ cam.c2w.T
    return d / jnp.linalg.norm(d, axis=-1, keepdims=True)


def camera_rays(cam: Camera):
    """All H*W rays, row-major."""
    py, px = jnp.meshgrid(jnp.arange(cam.h, dtype=jnp.float32),
                          jnp.arange(cam.w, dtype=jnp.float32), indexing="ij")
    d = pixel_rays(cam, px.reshape(-1), py.reshape(-1))
    o = jnp.broadcast_to(cam.origin, d.shape)
    return o, d


def step_world(cfg: NeRFConfig) -> float:
    return cfg.step_size * (2.0 * cfg.scene_bound / cfg.occ_res)


def composite(sigma, rgb, mask, delta, white_bg=True):
    """Eq. 1 along axis=-1 of samples. sigma (R,N), rgb (R,N,3), mask (R,N)."""
    tau = jnp.where(mask, sigma * delta, 0.0)
    cum = jnp.cumsum(tau, axis=-1)
    t_k = jnp.exp(-(cum - tau))                  # transmittance before k
    alpha = 1.0 - jnp.exp(-tau)
    w = t_k * alpha
    color = jnp.sum(w[..., None] * rgb, axis=-2)
    t_final = jnp.exp(-cum[..., -1])
    if white_bg:
        color = color + t_final[..., None]
    return color, t_final, w


def render_uniform(field, cfg: NeRFConfig, cubes: occ_lib.CubeSet,
                   rays_o, rays_d, *, use_occupancy=True,
                   white_bg=True) -> Tuple[jax.Array, Dict]:
    """Baseline pipeline: uniform samples + occupancy queries + early term.

    `field` is anything `field.as_backend` accepts — a params dict or a
    FieldBackend; encoded fields are sampled through the hybrid codec in
    place. rays_o/rays_d (R,3). Returns (rgb (R,3), stats).
    """
    f = field_lib.as_backend(field, cfg)
    n = cfg.max_samples_per_ray
    delta = step_world(cfg)
    t = cfg.near + (jnp.arange(n) + 0.5) * delta           # (N,)
    t = jnp.broadcast_to(t, (rays_o.shape[0], n))
    pts = rays_o[:, None] + rays_d[:, None] * t[..., None]  # (R,N,3)

    if use_occupancy:
        occ_hit = occ_lib.occupancy_query(cubes.occ, cfg, pts)
    else:
        occ_hit = jnp.all(jnp.abs(pts) <= cfg.scene_bound, axis=-1)
    flat = pts.reshape(-1, 3)
    sigma = f.sigma(flat).reshape(t.shape)
    sigma = jnp.where(occ_hit, sigma, 0.0)

    # early termination mask (T computed from sigma so far)
    tau = sigma * delta
    cum = jnp.cumsum(tau, axis=-1)
    t_before = jnp.exp(-(cum - tau))
    visible = occ_hit & (t_before > cfg.term_eps)

    feats = f.app_features(flat)
    dirs = jnp.broadcast_to(rays_d[:, None], pts.shape).reshape(-1, 3)
    rgb = f.color(feats, dirs).reshape(*t.shape, 3)

    color, t_final, _ = composite(sigma, rgb, visible, delta, white_bg)
    stats = {
        "occ_accesses": jnp.asarray(occ_hit.size, jnp.float32),
        "candidate_samples": jnp.asarray(occ_hit.size, jnp.float32),
        "preexisting_samples": jnp.sum(occ_hit.astype(jnp.float32)),
        "processed_samples": jnp.sum(visible.astype(jnp.float32)),
    }
    return color, stats


def psnr(img, ref) -> jax.Array:
    mse = jnp.mean(jnp.square(img - ref))
    return -10.0 * jnp.log10(jnp.maximum(mse, 1e-10))
