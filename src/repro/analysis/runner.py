"""Orchestrates the repro-lint checkers: load → check → waive → baseline.

Public API (used by scripts/repro_lint.py and tests/test_lint.py):

    report = run(paths, root=repo_root, baseline="lint_baseline.json")
    report.gating      # unwaived, un-baselined findings (CI fails on any)
    report.findings    # everything, including waived/baselined
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence

from repro.analysis import (base, jit_purity, locks, pytrees, recompile,
                            wire)
from repro.analysis.base import Finding, Module

CHECKERS = {
    "locks": locks.check,          # lock-discipline + lock-order
    "jit": jit_purity.check,       # jit-purity
    "recompile": recompile.check,  # recompile-hazard
    "pytrees": pytrees.check,      # pytree-completeness
    "wire": wire.check,            # wire-safety
}


@dataclasses.dataclass
class Report:
    findings: List[Finding]
    modules: List[Module]

    @property
    def gating(self) -> List[Finding]:
        return [f for f in self.findings if not f.waived and not f.baselined]

    @property
    def waived(self) -> List[Finding]:
        return [f for f in self.findings if f.waived]

    def format(self, show_waived: bool = False) -> str:
        shown = self.findings if show_waived else self.gating
        lines = [f.format() for f in shown]
        n_w, n_b = len(self.waived), \
            sum(1 for f in self.findings if f.baselined)
        lines.append(f"repro-lint: {len(self.gating)} finding(s) "
                     f"({n_w} waived, {n_b} baselined, "
                     f"{len(self.modules)} files)")
        return "\n".join(lines)


def run(paths: Sequence[str], root: str,
        baseline: Optional[str] = None,
        rules: Optional[Sequence[str]] = None) -> Report:
    mods = base.load_modules(paths, root)
    by_path: Dict[str, Module] = {m.path: m for m in mods}
    findings: List[Finding] = []
    for chk in CHECKERS.values():
        findings.extend(chk(mods))
    if rules:
        findings = [f for f in findings if f.rule in rules]
    # Inline waivers.
    for f in findings:
        mod = by_path.get(f.path)
        if mod is None:
            continue
        reason = mod.waiver_for(f.rule, f.line)
        if reason is not None:
            f.waived, f.waive_reason = True, reason
    # Committed baseline (grandfathered findings).
    if baseline and os.path.exists(baseline):
        fps = base.load_baseline(baseline)
        for f in findings:
            if not f.waived and f.fingerprint() in fps:
                f.baselined = True
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(findings=findings, modules=mods)
