"""repro-lint: stdlib-ast static analysis for the repo's concurrency and
JIT contracts. See docs/static_analysis.md for the rule catalogue and
scripts/repro_lint.py for the CLI."""
from repro.analysis.base import (ALL_RULES, Finding, Module,  # noqa: F401
                                 load_baseline, write_baseline)
from repro.analysis.runner import CHECKERS, Report, run  # noqa: F401
