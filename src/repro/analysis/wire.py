"""wire-safety checker for the fleet wire protocol modules.

The fleet tier deliberately speaks length-prefixed JSON + raw array
bytes — never pickle — because workers deserialize bytes that crossed a
process (eventually host) boundary. This checker pins that property:

* wire modules must not import ``pickle`` / ``marshal`` / ``dill`` /
  ``shelve`` (arbitrary code execution on deserialize);
* no ``eval`` / ``exec`` calls;
* every ``np.frombuffer`` decode must live in a module that declares a
  ``WIRE_DTYPES`` allowlist, in a function that consults it — decoding
  an attacker-controlled dtype string (e.g. ``object``) is the same
  class of bug as pickle.

A module is a wire module if its basename is ``fleet.py`` or
``router.py``, or if it sets ``LINT_WIRE_MODULE = True``.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis import base
from repro.analysis.base import Finding, Module

_BANNED_IMPORTS = {"pickle", "cPickle", "marshal", "dill", "shelve"}
_WIRE_BASENAMES = {"fleet.py", "router.py"}


def _is_wire_module(mod: Module) -> bool:
    return mod.basename in _WIRE_BASENAMES or \
        bool(mod.decl("LINT_WIRE_MODULE"))


def check(mods: List[Module]) -> List[Finding]:
    findings: List[Finding] = []
    for mod in mods:
        if not _is_wire_module(mod):
            continue
        has_allowlist = "WIRE_DTYPES" in mod.decls or any(
            isinstance(n, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "WIRE_DTYPES"
                for t in n.targets)
            for n in mod.tree.body)
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                root = node.module if isinstance(node, ast.ImportFrom) \
                    else None
                names = [root] if root else []
                if isinstance(node, ast.Import):
                    names = [a.name for a in node.names]
                for name in names:
                    top = (name or "").split(".")[0]
                    if top in _BANNED_IMPORTS:
                        findings.append(Finding(
                            rule=base.RULE_WIRE, path=mod.path,
                            line=node.lineno,
                            message=(f"wire module imports '{top}' — "
                                     "arbitrary code execution on "
                                     "deserialize"),
                            hint="the wire format is JSON + raw arrays; "
                                 "keep it that way",
                            symbol=f"import:{top}"))
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in ("eval", "exec"):
                findings.append(Finding(
                    rule=base.RULE_WIRE, path=mod.path, line=node.lineno,
                    message=(f"'{node.func.id}()' call in wire module"),
                    hint="never evaluate wire-derived strings",
                    symbol=f"call:{node.func.id}"))
        # np.frombuffer decodes must consult the WIRE_DTYPES allowlist.
        for fnode in ast.walk(mod.tree):
            if not isinstance(fnode, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            frombuffer_sites = [
                n for n in ast.walk(fnode)
                if isinstance(n, ast.Call) and
                isinstance(n.func, ast.Attribute) and
                n.func.attr == "frombuffer"]
            if not frombuffer_sites:
                continue
            consults = any(isinstance(n, ast.Name) and
                           n.id == "WIRE_DTYPES"
                           for n in ast.walk(fnode))
            for site in frombuffer_sites:
                if not has_allowlist:
                    findings.append(Finding(
                        rule=base.RULE_WIRE, path=mod.path,
                        line=site.lineno,
                        message=("array decode without a WIRE_DTYPES "
                                 "dtype allowlist in the module"),
                        hint="declare WIRE_DTYPES = {\"float32\", ...} and "
                             "validate the wire dtype before np.frombuffer",
                        symbol=f"frombuffer:{fnode.name}:no-allowlist"))
                elif not consults:
                    findings.append(Finding(
                        rule=base.RULE_WIRE, path=mod.path,
                        line=site.lineno,
                        message=(f"'{fnode.name}' decodes arrays without "
                                 "consulting WIRE_DTYPES"),
                        hint="check the dtype against WIRE_DTYPES before "
                             "np.frombuffer",
                        symbol=f"frombuffer:{fnode.name}:unchecked"))
    return findings
