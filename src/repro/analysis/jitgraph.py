"""Discovery of jit roots and the statically-resolvable call graph
under them — shared by the jit-purity and recompile-hazard checkers.

Roots are functions whose bodies run under a JAX trace:

* functions decorated ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``;
* functions passed to ``jax.jit(...)`` / ``pl.pallas_call(...)`` call
  sites, including through ``functools.partial(f, **static)``;
* nested functions *returned by* a factory whose call result is passed
  to ``jax.jit`` (the ``jax.jit(make_renderer(...))`` idiom);
* names listed in a module-level ``LINT_JIT_ENTRYPOINTS`` tuple
  (``"Class.method"`` or ``"function"``) — for methods dispatched
  dynamically (e.g. FieldBackend implementations) that static call
  resolution cannot see.

Reachability expands through calls that resolve statically: plain names
(nested siblings, module-level functions, ``from mod import f`` aliases),
``alias.f`` where ``alias`` imports an analyzed module, and ``self.m``
within a class. Dynamic dispatch is out of scope — declare those
targets via ``LINT_JIT_ENTRYPOINTS``.

Static-at-trace-time parameters (excluded from tracer taint): names in
``static_argnames``/positions in ``static_argnums``, arguments bound by
``functools.partial`` at the jit/pallas site, and keyword-only
parameters (repo convention: statics are passed by keyword).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import base
from repro.analysis.base import Module

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}


class FuncInfo:
    def __init__(self, mod: Module, node: ast.AST, qualname: str,
                 cls: str = ""):
        self.mod = mod
        self.node = node
        self.qualname = qualname
        self.cls = cls
        self.is_root = False
        self.static_params: Set[str] = set()

    def traced_params(self) -> Set[str]:
        """Positional params that carry tracers when this is a root."""
        a = self.node.args
        names = [p.arg for p in getattr(a, "posonlyargs", [])] + \
                [p.arg for p in a.args]
        if a.vararg:
            names.append(a.vararg.arg)
        # Keyword-only params are static by repo convention.
        return {n for n in names
                if n != "self" and n not in self.static_params}


class JitGraph:
    def __init__(self, mods: List[Module]):
        self.mods = mods
        self.by_dotted: Dict[str, Module] = {}
        for m in mods:
            d = _dotted_module(m.path)
            if d:
                self.by_dotted[d] = m
        # (module_path, qualname) -> FuncInfo
        self.funcs: Dict[Tuple[str, str], FuncInfo] = {}
        self._index()
        self._find_roots()
        self.reachable: Set[Tuple[str, str]] = set()
        self._expand()

    # -- indexing ----------------------------------------------------------

    def _index(self) -> None:
        for mod in self.mods:
            def walk(node: ast.AST, prefix: str, cls: str):
                for child in ast.iter_child_nodes(node):
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        q = f"{prefix}{child.name}"
                        self.funcs[(mod.path, q)] = FuncInfo(
                            mod, child, q, cls)
                        walk(child, q + ".", cls)
                    elif isinstance(child, ast.ClassDef):
                        walk(child, f"{prefix}{child.name}.", child.name)
                    else:
                        walk(child, prefix, cls)
            walk(mod.tree, "", "")

    def lookup(self, mod: Module, qualname: str) -> Optional[FuncInfo]:
        return self.funcs.get((mod.path, qualname))

    # -- root discovery ----------------------------------------------------

    def _find_roots(self) -> None:
        for mod in self.mods:
            # 1. Decorated defs.
            for (path, q), fi in list(self.funcs.items()):
                if path != mod.path:
                    continue
                for dec in getattr(fi.node, "decorator_list", []):
                    statics = _jit_decorator_statics(dec, fi.node)
                    if statics is not None:
                        fi.is_root = True
                        fi.static_params |= statics
            # 2. jit()/pallas_call() call sites.
            imports = base.module_imports(mod)
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                fn_dotted = base.dotted(node.func)
                is_jit = fn_dotted in _JIT_NAMES
                is_pallas = fn_dotted == "pallas_call" or \
                    fn_dotted.endswith(".pallas_call")
                if not (is_jit or is_pallas) or not node.args:
                    continue
                statics = _static_names_from_call(node)
                self._mark_arg_root(mod, imports, node.args[0], statics,
                                    allow_factory=is_jit)
            # 3. Declared entrypoints.
            for entry in mod.decl("LINT_JIT_ENTRYPOINTS", ()) or ():
                fi = self.lookup(mod, entry)
                if fi is not None:
                    fi.is_root = True

    def _mark_arg_root(self, mod: Module, imports: Dict[str, str],
                       arg: ast.AST, statics: Set[str],
                       allow_factory: bool) -> None:
        # functools.partial(f, a, b, k=v): leading positionals + kw static
        if isinstance(arg, ast.Call) and \
                base.dotted(arg.func) in _PARTIAL_NAMES and arg.args:
            fi = self._resolve_expr(mod, imports, arg.args[0])
            if fi is not None:
                fi.is_root = True
                fi.static_params |= {k.arg for k in arg.keywords if k.arg}
                fi.static_params |= _leading_params(fi.node,
                                                    len(arg.args) - 1)
            return
        # jax.jit(factory(...)): the factory's returned nested defs trace.
        if isinstance(arg, ast.Call) and allow_factory:
            factory = self._resolve_expr(mod, imports, arg.func)
            if factory is not None:
                for fi in self._returned_nested(factory):
                    fi.is_root = True
            return
        fi = self._resolve_expr(mod, imports, arg)
        if fi is not None:
            fi.is_root = True
            fi.static_params |= statics

    def _returned_nested(self, factory: FuncInfo) -> List[FuncInfo]:
        names: Set[str] = set()
        for node in ast.walk(factory.node):
            if isinstance(node, ast.Return) and node.value is not None:
                vals = node.value.elts if isinstance(node.value, ast.Tuple) \
                    else [node.value]
                for v in vals:
                    if isinstance(v, ast.Name):
                        names.add(v.id)
        out = []
        for n in names:
            fi = self.lookup(factory.mod, f"{factory.qualname}.{n}")
            if fi is not None:
                out.append(fi)
        return out

    # -- call resolution ---------------------------------------------------

    def _resolve_expr(self, mod: Module, imports: Dict[str, str],
                      expr: ast.AST,
                      scope: Optional[FuncInfo] = None) -> Optional[FuncInfo]:
        if isinstance(expr, ast.Name):
            # Nested sibling within enclosing function scopes.
            if scope is not None:
                prefix = scope.qualname
                while True:
                    fi = self.lookup(mod, f"{prefix}.{expr.id}"
                                     if prefix else expr.id)
                    if fi is not None:
                        return fi
                    if "." not in prefix:
                        break
                    prefix = prefix.rsplit(".", 1)[0]
            fi = self.lookup(mod, expr.id)
            if fi is not None:
                return fi
            target = imports.get(expr.id)
            if target and "." in target:
                m, f = target.rsplit(".", 1)
                if m in self.by_dotted:
                    return self.lookup(self.by_dotted[m], f)
            return None
        if isinstance(expr, ast.Attribute):
            recv = expr.value
            if isinstance(recv, ast.Name):
                if recv.id == "self" and scope is not None and scope.cls:
                    return self.lookup(mod, f"{scope.cls}.{expr.attr}")
                target = imports.get(recv.id)
                if target in self.by_dotted:
                    return self.lookup(self.by_dotted[target], expr.attr)
        return None

    # -- reachability ------------------------------------------------------

    def _expand(self) -> None:
        queue = [k for k, fi in self.funcs.items() if fi.is_root]
        seen = set(queue)
        while queue:
            key = queue.pop()
            self.reachable.add(key)
            fi = self.funcs[key]
            imports = base.module_imports(fi.mod)
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                callee = self._resolve_expr(fi.mod, imports, node.func,
                                            scope=fi)
                if callee is None:
                    continue
                ck = (callee.mod.path, callee.qualname)
                if ck not in seen:
                    seen.add(ck)
                    queue.append(ck)

    def roots(self) -> List[FuncInfo]:
        return [fi for fi in self.funcs.values() if fi.is_root]

    def reachable_funcs(self) -> List[FuncInfo]:
        return [self.funcs[k] for k in sorted(self.reachable)]


def _dotted_module(relpath: str) -> str:
    p = relpath.replace(os.sep, "/")
    if p.startswith("src/"):
        p = p[4:]
    if not p.endswith(".py"):
        return ""
    p = p[:-3]
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


def _leading_params(fn: ast.AST, count: int) -> Set[str]:
    a = fn.args
    names = [p.arg for p in getattr(a, "posonlyargs", [])] + \
            [p.arg for p in a.args]
    return set(names[:count])


def _jit_decorator_statics(dec: ast.AST, fn: ast.AST) -> Optional[Set[str]]:
    """None if not a jit decorator; else the static param-name set."""
    if base.dotted(dec) in _JIT_NAMES:
        return set()
    if isinstance(dec, ast.Call):
        d = base.dotted(dec.func)
        if d in _JIT_NAMES:
            return _static_names_from_call(dec, fn)
        if d in _PARTIAL_NAMES and dec.args and \
                base.dotted(dec.args[0]) in _JIT_NAMES:
            return _static_names_from_call(dec, fn)
    return None


def _static_names_from_call(call: ast.Call,
                            fn: Optional[ast.AST] = None) -> Set[str]:
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            try:
                v = ast.literal_eval(kw.value)
            except (ValueError, SyntaxError):
                continue
            out |= {v} if isinstance(v, str) else set(v)
        elif kw.arg == "static_argnums" and fn is not None:
            try:
                v = ast.literal_eval(kw.value)
            except (ValueError, SyntaxError):
                continue
            nums = [v] if isinstance(v, int) else list(v)
            a = fn.args
            names = [p.arg for p in getattr(a, "posonlyargs", [])] + \
                    [p.arg for p in a.args]
            for i in nums:
                if 0 <= i < len(names):
                    out.add(names[i])
    return out


def static_positions(call: ast.Call) -> Set[int]:
    """static_argnums positions declared on a jit(...) call."""
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            try:
                v = ast.literal_eval(kw.value)
            except (ValueError, SyntaxError):
                return set()
            return {v} if isinstance(v, int) else set(v)
    return set()
