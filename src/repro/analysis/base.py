"""Shared plumbing for the repro-lint AST checkers.

Everything here is stdlib-only (``ast`` + ``tokenize``): the lint suite
must run in CI images and dev sandboxes that have nothing beyond the
runtime deps installed.

Core pieces:

* :class:`Finding` — one diagnostic (rule id, file:line, message, hint).
* :class:`Module` — a parsed source file plus the per-line waiver table
  extracted from ``# lint: waive(<rule>) — <reason>`` comments.
* module-level convention readers (``GUARDED_BY``, ``LOCK_ATTR_CLASSES``,
  ``LINT_JIT_ENTRYPOINTS``, ``WIRE_DTYPES``) used by individual checkers.
* a tiny taint helper shared by the jit-purity and recompile checkers to
  decide whether an expression can carry a tracer value.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# Rule ids — the public vocabulary of the suite (docs/static_analysis.md).
RULE_LOCK = "lock-discipline"
RULE_LOCK_ORDER = "lock-order"
RULE_JIT_PURITY = "jit-purity"
RULE_RECOMPILE = "recompile-hazard"
RULE_PYTREE = "pytree-completeness"
RULE_WIRE = "wire-safety"
ALL_RULES = (RULE_LOCK, RULE_LOCK_ORDER, RULE_JIT_PURITY, RULE_RECOMPILE,
             RULE_PYTREE, RULE_WIRE)

_WAIVE_RE = re.compile(
    r"lint:\s*waive\(\s*([\w\-, ]+?)\s*\)\s*(?:[—–:-]+\s*(\S.*))?")
_GUARDED_COMMENT_RE = re.compile(r"guarded-by:\s*([\w]+)")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str              # repo-relative display path
    line: int
    message: str
    hint: str = ""
    symbol: str = ""       # stable anchor (qualname + detail) for baselines
    waived: bool = False
    waive_reason: str = ""
    baselined: bool = False

    def fingerprint(self) -> str:
        """Line-number-free identity used by the committed baseline, so
        unrelated edits above a grandfathered finding don't churn it."""
        return f"{self.rule}|{self.path}|{self.symbol or self.message}"

    def format(self) -> str:
        tag = ""
        if self.waived:
            tag = f"  [waived: {self.waive_reason}]"
        elif self.baselined:
            tag = "  [baselined]"
        hint = f"\n    hint: {self.hint}" if self.hint and not tag else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}{hint}"


class Module:
    """One parsed source file + its waiver table and convention literals."""

    def __init__(self, abspath: str, relpath: str, source: str):
        self.abspath = abspath
        self.path = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        _attach_parents(self.tree)
        self.waivers = _parse_waivers(source)
        self.guarded_comments = _parse_guarded_comments(source)
        self.decls = _module_literals(self.tree)

    @property
    def basename(self) -> str:
        return os.path.basename(self.path)

    def decl(self, name: str, default=None):
        return self.decls.get(name, default)

    def waiver_for(self, rule: str, line: int) -> Optional[str]:
        """Reason string if `rule` is waived at `line`, else None."""
        w = self.waivers.get(line)
        if w and (rule in w[0] or "*" in w[0]):
            return w[1]
        return None


def _attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._lint_parent = node  # type: ignore[attr-defined]


def parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_lint_parent", None)


def _iter_comments(source: str):
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.start[1], tok.string
    except tokenize.TokenError:
        return


def _parse_waivers(source: str) -> Dict[int, Tuple[Set[str], str]]:
    """``# lint: waive(rule[, rule]) — reason`` → {line: (rules, reason)}.

    A waiver with no reason text is ignored (the policy requires one). A
    comment on its own line waives the next code line as well as itself.
    """
    lines = source.splitlines()
    out: Dict[int, Tuple[Set[str], str]] = {}
    for lno, col, text in _iter_comments(source):
        m = _WAIVE_RE.search(text)
        if not m or not m.group(2):
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        reason = m.group(2).strip()
        out[lno] = (rules, reason)
        own_line = lines[lno - 1] if lno - 1 < len(lines) else ""
        if own_line.strip().startswith("#"):
            # Standalone comment: also cover the next code line.
            nxt = lno + 1
            while nxt - 1 < len(lines) and not lines[nxt - 1].strip():
                nxt += 1
            out.setdefault(nxt, (rules, reason))
    return out


def _parse_guarded_comments(source: str) -> Dict[int, str]:
    """``# guarded-by: _lock`` trailing comments → {line: lockname}."""
    out = {}
    for lno, col, text in _iter_comments(source):
        m = _GUARDED_COMMENT_RE.search(text)
        if m:
            out[lno] = m.group(1)
    return out


def _module_literals(tree: ast.Module) -> dict:
    """Safe-eval module-level ``NAME = <literal>`` assignments the
    checkers use as declarations (GUARDED_BY, WIRE_DTYPES, ...)."""
    out = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name) \
                and node.value is not None:
            name = node.target.id
            node = ast.Assign(targets=[node.target], value=node.value)
        else:
            continue
        try:
            out[name] = ast.literal_eval(node.value)
        except (ValueError, TypeError, SyntaxError):
            continue
    return out


def load_modules(paths: Sequence[str], root: str) -> List[Module]:
    """Parse every .py file under `paths` (files or directories)."""
    files: List[str] = []
    for p in paths:
        p = p if os.path.isabs(p) else os.path.join(root, p)
        if not os.path.exists(p):
            raise SystemExit(f"repro-lint: no such path: {p}")
        if os.path.isfile(p):
            files.append(p)
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(dirpath, f)
                             for f in filenames if f.endswith(".py"))
    mods = []
    for f in sorted(set(files)):
        rel = os.path.relpath(f, root)
        with open(f, encoding="utf-8") as fh:
            src = fh.read()
        try:
            mods.append(Module(f, rel, src))
        except SyntaxError as e:
            raise SystemExit(f"repro-lint: cannot parse {rel}: {e}")
    return mods


# ---------------------------------------------------------------------------
# Baseline file

def load_baseline(path: str) -> Set[str]:
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, dict) or "findings" not in data:
        raise SystemExit(f"repro-lint: malformed baseline {path}")
    return set(data["findings"])


def write_baseline(path: str, findings: Iterable[Finding]) -> int:
    fps = sorted({f.fingerprint() for f in findings if not f.waived})
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"version": 1, "findings": fps}, f, indent=1)
        f.write("\n")
    return len(fps)


# ---------------------------------------------------------------------------
# Small AST helpers shared by checkers

def dotted(node: ast.AST) -> str:
    """Best-effort dotted name for Name/Attribute chains ('' otherwise)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def numpy_aliases(mod: Module) -> Set[str]:
    """Names bound to the host numpy module in this file (np, numpy, ...).

    ``jax.numpy`` aliases are deliberately excluded."""
    out = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    out.add(a.asname or "numpy")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "numpy":
                continue  # from numpy import X — rare here; skip
    return out


def module_imports(mod: Module) -> Dict[str, str]:
    """Local alias -> imported module dotted path, for cross-module call
    resolution (``from repro.core import field as field_lib``)."""
    out = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[(a.asname or a.name.split(".")[0])] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[(a.asname or a.name)] = f"{node.module}.{a.name}"
    return out


class _TaintQuery:
    """Decides whether an expression can carry a traced (tracer) value,
    given a set of tainted local names. Shape/dtype/len extraction
    launders the taint — branching on those is static under jit."""

    _NEUTRAL_ATTRS = {"shape", "ndim", "dtype", "size"}
    _NEUTRAL_CALLS = {"len", "isinstance", "range", "type"}

    def __init__(self, tainted: Set[str]):
        self.tainted = tainted

    def carries(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in self._NEUTRAL_ATTRS:
                return False
            return self.carries(node.value)
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in self._NEUTRAL_CALLS:
                return False
            if isinstance(fn, ast.Attribute) \
                    and fn.attr in self._NEUTRAL_ATTRS:
                return False
            return any(self.carries(a) for a in node.args) or \
                any(self.carries(k.value) for k in node.keywords) or \
                self.carries(fn)
        if isinstance(node, ast.Subscript):
            return self.carries(node.value)
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            # identity checks (`x is None`) are pytree-structural: the
            # treedef, not the tracer, decides them — static under jit
            return False
        if isinstance(node, (ast.Constant, ast.Lambda)):
            return False
        return any(self.carries(c) for c in ast.iter_child_nodes(node))


def propagate_taint(fn: ast.AST, seeds: Set[str]) -> _TaintQuery:
    """Forward-propagate taint through simple assignments in a function
    body (single pass in source order — good enough for lint)."""
    tainted = set(seeds)
    q = _TaintQuery(tainted)
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and q.carries(node.value):
            for tgt in node.targets:
                for n in ast.walk(tgt):
                    if isinstance(n, ast.Name):
                        tainted.add(n.id)
        elif isinstance(node, ast.AugAssign) and q.carries(node.value):
            if isinstance(node.target, ast.Name):
                tainted.add(node.target.id)
    return q
