"""pytree-completeness checker.

Containers that cross a jit boundary must be registered pytrees with
hashable static (aux) data, or jit either fails outright or — worse —
treats the whole object as a static constant and recompiles per call.

Three checks:

1. any class that defines ``tree_flatten`` must also define
   ``tree_unflatten`` and be registered (``@...register_pytree_node_class``
   or a ``register_pytree_node(Cls, ...)`` call);
2. the aux (static) element returned by ``tree_flatten`` — or by the
   flatten lambda passed to ``register_pytree_node`` — must not contain
   list/dict/set displays or array constructors (unhashable: every jit
   call would miss the cache or raise);
3. any ``@dataclass`` with jax-array-annotated fields
   (``jax.Array`` / ``jnp.ndarray``) must be registered — an
   unregistered one passed into jit dies with "Cannot interpret value of
   type ... as an abstract array". NamedTuples are exempt (native
   pytrees).
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Set

from repro.analysis import base
from repro.analysis.base import Finding, Module

_ARRAY_ANN_RE = re.compile(r"\bjax\.Array\b|\bjnp\.ndarray\b|\bArray\b")
_REGISTER_FNS = {"register_pytree_node", "register_pytree_with_keys",
                 "register_dataclass"}


def _registered_classes(mods: List[Module]) -> Set[str]:
    out: Set[str] = set()
    for mod in mods:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                for dec in node.decorator_list:
                    d = base.dotted(dec)
                    if d.endswith("register_pytree_node_class") or \
                            d.endswith("register_static"):
                        out.add(node.name)
            elif isinstance(node, ast.Call):
                d = base.dotted(node.func)
                if d.split(".")[-1] in _REGISTER_FNS and node.args and \
                        isinstance(node.args[0], ast.Name):
                    out.add(node.args[0].id)
    return out


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        d = base.dotted(dec if not isinstance(dec, ast.Call) else dec.func)
        if d.split(".")[-1] == "dataclass":
            return True
    return False


def _is_namedtuple(node: ast.ClassDef) -> bool:
    return any(base.dotted(b).split(".")[-1] == "NamedTuple"
               for b in node.bases)


def _array_fields(node: ast.ClassDef) -> List[str]:
    out = []
    for stmt in node.body:
        if isinstance(stmt, ast.AnnAssign) and \
                isinstance(stmt.target, ast.Name):
            ann = ast.unparse(stmt.annotation)
            # Callable[..., Array] fields hold functions, not array data.
            if _ARRAY_ANN_RE.search(ann) and "Callable" not in ann:
                out.append(stmt.target.id)
    return out


def _unhashable_in(expr: ast.AST) -> Optional[ast.AST]:
    for node in ast.walk(expr):
        if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.SetComp, ast.DictComp)):
            return node
        if isinstance(node, ast.Call):
            d = base.dotted(node.func)
            if d and d.split(".")[-1] in ("array", "asarray") and \
                    d.split(".")[0] in ("np", "numpy", "jnp", "jax"):
                return node
    return None


def _aux_exprs_of_flatten(fn: ast.AST) -> List[ast.AST]:
    """Second tuple element of each `return (children, aux)`."""
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and \
                isinstance(node.value, ast.Tuple) and \
                len(node.value.elts) == 2:
            out.append(node.value.elts[1])
    return out


def check(mods: List[Module]) -> List[Finding]:
    findings: List[Finding] = []
    registered = _registered_classes(mods)
    for mod in mods:
        for cnode in ast.walk(mod.tree):
            if not isinstance(cnode, ast.ClassDef):
                continue
            methods = {n.name: n for n in cnode.body
                       if isinstance(n, ast.FunctionDef)}
            flatten = methods.get("tree_flatten")
            if flatten is not None:
                if "tree_unflatten" not in methods:
                    findings.append(Finding(
                        rule=base.RULE_PYTREE, path=mod.path,
                        line=cnode.lineno,
                        message=(f"'{cnode.name}' defines tree_flatten "
                                 "without tree_unflatten"),
                        hint="jit round-trips pytrees; both halves are "
                             "required",
                        symbol=f"{cnode.name}:no-unflatten"))
                if cnode.name not in registered:
                    findings.append(Finding(
                        rule=base.RULE_PYTREE, path=mod.path,
                        line=cnode.lineno,
                        message=(f"'{cnode.name}' defines tree_flatten but "
                                 "is not registered as a pytree"),
                        hint="decorate with @jax.tree_util."
                             "register_pytree_node_class",
                        symbol=f"{cnode.name}:unregistered-flatten"))
                for aux in _aux_exprs_of_flatten(flatten):
                    bad = _unhashable_in(aux)
                    if bad is not None:
                        findings.append(Finding(
                            rule=base.RULE_PYTREE, path=mod.path,
                            line=bad.lineno,
                            message=(f"'{cnode.name}.tree_flatten' aux "
                                     "data contains an unhashable "
                                     "expression"),
                            hint="aux joins the jit cache key: use tuples "
                                 "/ frozen dataclasses, never lists or "
                                 "arrays",
                            symbol=f"{cnode.name}:unhashable-aux"))
            if _is_dataclass(cnode) and not _is_namedtuple(cnode):
                arr = _array_fields(cnode)
                if arr and cnode.name not in registered:
                    findings.append(Finding(
                        rule=base.RULE_PYTREE, path=mod.path,
                        line=cnode.lineno,
                        message=(f"dataclass '{cnode.name}' has jax array "
                                 f"fields ({', '.join(arr)}) but is not a "
                                 "registered pytree"),
                        hint="register it (register_pytree_node[_class]) "
                             "before it crosses a jit boundary",
                        symbol=f"{cnode.name}:unregistered-dataclass"))
        # Flatten lambdas passed directly to register_pytree_node.
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    base.dotted(node.func).split(".")[-1] == \
                    "register_pytree_node" and len(node.args) >= 2 and \
                    isinstance(node.args[1], ast.Lambda):
                body = node.args[1].body
                if isinstance(body, ast.Tuple) and len(body.elts) == 2:
                    bad = _unhashable_in(body.elts[1])
                    if bad is not None:
                        cls = node.args[0].id if \
                            isinstance(node.args[0], ast.Name) else "?"
                        findings.append(Finding(
                            rule=base.RULE_PYTREE, path=mod.path,
                            line=bad.lineno,
                            message=(f"flatten lambda for '{cls}' returns "
                                     "unhashable aux data"),
                            hint="aux joins the jit cache key: use tuples",
                            symbol=f"{cls}:unhashable-aux-lambda"))
    return findings
