"""lock-discipline + lock-order checkers.

A class opts in by appearing in its module's ``GUARDED_BY`` map::

    GUARDED_BY = {
        "RenderEngine": {
            "lock": "_lock",                 # primary lock attribute
            "aliases": ("_flush_cv",),       # acquiring these == the lock
            "locks": ("_render_lock",),      # extra locks (ordering only)
            "attrs": ("_queue", "_next_id"), # state guarded by the lock
            "assume_held": ("_locked_help",),# methods whose contract is
        },                                   # "caller holds the lock"
    }

or via the inline comment convention on the attribute's initial
assignment: ``self._queue = []  # guarded-by: _lock``.

Rule ``lock-discipline``: every ``self.<attr>`` load/store of a guarded
attribute must occur lexically inside ``with self.<lock>`` (or an alias).
``__init__`` is exempt (pre-publication), as are declared ``assume_held``
methods. Nested functions reset the held set — a closure may run later
without the lock.

Rule ``lock-order``: the acquisition graph (lock held -> lock acquired,
via direct ``with`` nesting and via calls into methods of other declared
classes, resolved through ``LOCK_ATTR_CLASSES = {"Engine.store":
"SceneStore"}``) must be acyclic. Self-edges are ignored — the declared
locks are reentrant RLocks.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import base
from repro.analysis.base import Finding, Module


class _ClassDecl:
    def __init__(self, cls: str, raw: dict):
        self.cls = cls
        self.lock: str = raw.get("lock", "_lock")
        aliases = raw.get("aliases", ())
        if isinstance(aliases, dict):
            self.aliases = dict(aliases)
        else:
            self.aliases = {a: self.lock for a in aliases}
        self.extra_locks: Tuple[str, ...] = tuple(raw.get("locks", ()))
        self.attrs: Dict[str, str] = {a: self.lock
                                      for a in raw.get("attrs", ())}
        self.assume_held: Set[str] = set(raw.get("assume_held", ()))

    def resolve_lock(self, attr: str) -> Optional[str]:
        """Lock attr acquired by ``with self.<attr>`` — canonical name."""
        if attr == self.lock or attr in self.extra_locks:
            return attr
        return self.aliases.get(attr)

    def all_lock_names(self) -> Set[str]:
        return {self.lock, *self.extra_locks, *self.aliases}


def _class_decls(mod: Module) -> Dict[str, _ClassDecl]:
    decls = {}
    raw = mod.decl("GUARDED_BY", {})
    if isinstance(raw, dict):
        for cls, d in raw.items():
            if isinstance(d, dict):
                decls[cls] = _ClassDecl(cls, d)
    # Inline `# guarded-by: <lock>` comments on self.<attr> assignments.
    if mod.guarded_comments:
        for cnode in ast.walk(mod.tree):
            if not isinstance(cnode, ast.ClassDef):
                continue
            for node in ast.walk(cnode):
                if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                    continue
                lockname = mod.guarded_comments.get(node.lineno)
                if not lockname:
                    continue
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    if isinstance(tgt, ast.Attribute) and \
                            isinstance(tgt.value, ast.Name) and \
                            tgt.value.id == "self":
                        d = decls.setdefault(
                            cnode.name,
                            _ClassDecl(cnode.name, {"lock": lockname,
                                                    "attrs": ()}))
                        d.attrs[tgt.attr] = lockname
    return decls


def _held_lock_visit(fn: ast.AST, decl: _ClassDecl, mod: Module,
                     findings: List[Finding], cls: str, fname: str) -> None:
    """Flag guarded-attr accesses outside the guarding lock."""

    def visit(node: ast.AST, held: Set[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn:
            # Closures may outlive the lock scope: reset.
            for child in ast.iter_child_nodes(node):
                visit(child, set())
            return
        if isinstance(node, ast.With):
            new_held = set(held)
            for item in node.items:
                ce = item.context_expr
                if isinstance(ce, ast.Attribute) and \
                        isinstance(ce.value, ast.Name) and \
                        ce.value.id == "self":
                    resolved = decl.resolve_lock(ce.attr)
                    if resolved is not None:
                        new_held.add(resolved)
            for item in node.items:
                visit(item.context_expr, held)
            for child in node.body:
                visit(child, new_held)
            return
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            need = decl.attrs.get(node.attr)
            if need is not None and need not in held \
                    and decl.aliases.get(need, need) not in held:
                findings.append(Finding(
                    rule=base.RULE_LOCK, path=mod.path, line=node.lineno,
                    message=(f"'{cls}.{node.attr}' is guarded by "
                             f"'{need}' but accessed outside "
                             f"'with self.{need}' in {fname}()"),
                    hint=(f"wrap the access in 'with self.{need}:' or add "
                          f"'{fname}' to GUARDED_BY[{cls!r}]['assume_held'] "
                          "with a caller-holds-the-lock contract"),
                    symbol=f"{cls}.{fname}.{node.attr}"))
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    body = getattr(fn, "body", [])
    for stmt in body:
        visit(stmt, set())


# ---------------------------------------------------------------------------
# lock-order

class _MethodInfo:
    def __init__(self, mod: Module, cls: str, name: str, node: ast.AST,
                 decl: Optional[_ClassDecl]):
        self.mod = mod
        self.cls = cls
        self.name = name
        self.node = node
        self.decl = decl
        self.direct: Set[str] = set()        # labels acquired anywhere
        self.calls: Set[Tuple[str, str]] = set()  # (cls, meth) resolved
        self.acquires: Set[str] = set()      # fixpoint closure


def _label(cls: str, lock: str) -> str:
    return f"{cls}.{lock}"


def _collect_methods(mods: List[Module]) -> Dict[Tuple[str, str], _MethodInfo]:
    out: Dict[Tuple[str, str], _MethodInfo] = {}
    for mod in mods:
        decls = _class_decls(mod)
        attr_classes = mod.decl("LOCK_ATTR_CLASSES", {}) or {}
        for cnode in mod.tree.body:
            if not isinstance(cnode, ast.ClassDef):
                continue
            decl = decls.get(cnode.name)
            for fnode in cnode.body:
                if not isinstance(fnode, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                    continue
                info = _MethodInfo(mod, cnode.name, fnode.name, fnode, decl)
                for node in ast.walk(fnode):
                    if isinstance(node, ast.With) and decl is not None:
                        for item in node.items:
                            ce = item.context_expr
                            if isinstance(ce, ast.Attribute) and \
                                    isinstance(ce.value, ast.Name) and \
                                    ce.value.id == "self":
                                r = decl.resolve_lock(ce.attr)
                                if r is not None:
                                    info.direct.add(_label(cnode.name, r))
                    if isinstance(node, ast.Call):
                        fn = node.func
                        if isinstance(fn, ast.Attribute):
                            recv = fn.value
                            if isinstance(recv, ast.Name) and \
                                    recv.id == "self":
                                info.calls.add((cnode.name, fn.attr))
                            elif isinstance(recv, ast.Attribute) and \
                                    isinstance(recv.value, ast.Name) and \
                                    recv.value.id == "self":
                                key = f"{cnode.name}.{recv.attr}"
                                tgt = attr_classes.get(key)
                                if tgt:
                                    info.calls.add((tgt, fn.attr))
                out[(cnode.name, fnode.name)] = info
    # Fixpoint over the resolved call graph.
    changed = True
    for info in out.values():
        info.acquires = set(info.direct)
    while changed:
        changed = False
        for info in out.values():
            for callee in info.calls:
                ci = out.get(callee)
                if ci and not ci.acquires <= info.acquires:
                    info.acquires |= ci.acquires
                    changed = True
    return out


def _order_edges(methods: Dict[Tuple[str, str], _MethodInfo]
                 ) -> Dict[Tuple[str, str], Tuple[str, int]]:
    """(held_label, acquired_label) -> (path, line) provenance."""
    edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
    attr_cache: Dict[str, dict] = {}

    def record(held: Set[str], acquired: str, mod: Module, line: int):
        for h in held:
            if h != acquired:
                edges.setdefault((h, acquired), (mod.path, line))

    for info in methods.values():
        decl = info.decl
        mod = info.mod
        attr_classes = attr_cache.setdefault(
            mod.path, mod.decl("LOCK_ATTR_CLASSES", {}) or {})

        def visit(node: ast.AST, held: Set[str]):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not info.node:
                for child in ast.iter_child_nodes(node):
                    visit(child, set())
                return
            if isinstance(node, ast.With):
                new_held = set(held)
                for item in node.items:
                    ce = item.context_expr
                    if decl is not None and isinstance(ce, ast.Attribute) \
                            and isinstance(ce.value, ast.Name) \
                            and ce.value.id == "self":
                        r = decl.resolve_lock(ce.attr)
                        if r is not None:
                            lbl = _label(info.cls, r)
                            record(held, lbl, mod, node.lineno)
                            new_held.add(lbl)
                for child in node.body:
                    visit(child, new_held)
                return
            if isinstance(node, ast.Call) and held:
                fn = node.func
                callee = None
                if isinstance(fn, ast.Attribute):
                    recv = fn.value
                    if isinstance(recv, ast.Name) and recv.id == "self":
                        callee = (info.cls, fn.attr)
                    elif isinstance(recv, ast.Attribute) and \
                            isinstance(recv.value, ast.Name) and \
                            recv.value.id == "self":
                        tgt = attr_classes.get(f"{info.cls}.{recv.attr}")
                        if tgt:
                            callee = (tgt, fn.attr)
                if callee and callee in methods:
                    for lbl in methods[callee].acquires:
                        record(held, lbl, mod, node.lineno)
            for child in ast.iter_child_nodes(node):
                visit(child, held)

        for stmt in getattr(info.node, "body", []):
            visit(stmt, set())
    return edges


def _find_cycles(edges: Dict[Tuple[str, str], Tuple[str, int]]
                 ) -> List[List[str]]:
    graph: Dict[str, Set[str]] = {}
    for (a, b) in edges:
        graph.setdefault(a, set()).add(b)
        graph.setdefault(b, set())
    cycles: List[List[str]] = []
    seen_cycles: Set[frozenset] = set()

    def dfs(start: str):
        stack: List[Tuple[str, List[str]]] = [(start, [start])]
        while stack:
            node, path = stack.pop()
            for nxt in graph.get(node, ()):
                if nxt == start and len(path) > 1:
                    key = frozenset(path)
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        cycles.append(path + [start])
                elif nxt not in path:
                    stack.append((nxt, path + [nxt]))

    for n in graph:
        dfs(n)
    return cycles


def check(mods: List[Module]) -> List[Finding]:
    findings: List[Finding] = []
    # Discipline: per declared class.
    for mod in mods:
        decls = _class_decls(mod)
        if not decls:
            continue
        for cnode in mod.tree.body:
            if not isinstance(cnode, ast.ClassDef):
                continue
            decl = decls.get(cnode.name)
            if decl is None or not decl.attrs:
                continue
            for fnode in cnode.body:
                if not isinstance(fnode, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                    continue
                if fnode.name == "__init__" or \
                        fnode.name in decl.assume_held:
                    continue
                _held_lock_visit(fnode, decl, mod, findings,
                                 cnode.name, fnode.name)
    # Ordering: global graph across all declared classes.
    methods = _collect_methods(mods)
    edges = _order_edges(methods)
    path_of = {m.path: m for m in mods}
    for cycle in _find_cycles(edges):
        first_edge = (cycle[0], cycle[1])
        path, line = edges.get(first_edge, ("<unknown>", 0))
        findings.append(Finding(
            rule=base.RULE_LOCK_ORDER, path=path, line=line,
            message=("lock-order cycle: " + " -> ".join(cycle) +
                     " (acquisition order inversion can deadlock)"),
            hint=("pick one global order for these locks and acquire them "
                  "consistently; see docs/static_analysis.md#rules"),
            symbol="cycle:" + "|".join(sorted(set(cycle)))))
    return findings
