"""jit-purity checker: no host syncs inside jit-reachable code.

Flags, in every function reachable from a jit/pallas root (see
``jitgraph``):

* ``print(...)`` — host I/O forces a device sync per trace-miss and is
  silently dropped on cache hits (use ``jax.debug.print``);
* any use of host ``numpy`` (``np.*``) — materialises tracers;
* ``time.*()`` calls — host clocks read trace time, not run time;
* ``.item()`` — blocking device->host transfer;
* ``float(x)`` / ``int(x)`` on a traced value (roots only, with simple
  forward taint) — raises ``TracerConversionError`` at trace time;
* metrics-registry calls (``.inc``/``.record``/``.observe`` or
  ``.counter``/``.gauge``/``.histogram`` on registry-like receivers) —
  the registry takes host locks; record metrics outside the jitted body.
"""
from __future__ import annotations

import ast
import re
from typing import List, Set

from repro.analysis import base, jitgraph
from repro.analysis.base import Finding, Module

_METRIC_METHODS = {"inc", "record", "observe", "counter", "gauge",
                   "histogram"}
_METRIC_RECV_RE = re.compile(r"metric|registry|tracer|_m_|_g_")


def _check_func(fi: jitgraph.FuncInfo, findings: List[Finding]) -> None:
    mod = fi.mod
    imports = base.module_imports(mod)
    time_aliases = {a for a, m in imports.items() if m == "time"}
    where = fi.qualname

    taint = None
    if fi.is_root:
        taint = base.propagate_taint(fi.node, fi.traced_params())

    def flag(node: ast.AST, msg: str, hint: str, detail: str) -> None:
        findings.append(Finding(
            rule=base.RULE_JIT_PURITY, path=mod.path, line=node.lineno,
            message=f"{msg} in jit-reachable '{where}'",
            hint=hint, symbol=f"{where}:{detail}"))

    for node in ast.walk(fi.node):
        if isinstance(node, ast.Call):
            fn = node.func
            d = base.dotted(fn)
            if isinstance(fn, ast.Name) and fn.id == "print":
                flag(node, "host print()",
                     "use jax.debug.print or log outside the jitted body",
                     "print")
            elif d and d.split(".")[0] in time_aliases:
                flag(node, f"host clock call '{d}()'",
                     "timestamps taken under trace record trace time, not "
                     "run time; time outside the jitted body", d)
            elif isinstance(fn, ast.Attribute) and fn.attr == "item":
                flag(node, "blocking '.item()' transfer",
                     "keep the value on device (jnp) or move the read "
                     "outside the jitted body", "item")
            elif isinstance(fn, ast.Attribute) and \
                    fn.attr in _METRIC_METHODS:
                recv = base.dotted(fn.value)
                if recv.startswith("self.") or _METRIC_RECV_RE.search(recv):
                    flag(node, f"metrics-registry call '{recv}.{fn.attr}()'",
                         "the registry takes host locks; record metrics "
                         "from the caller, outside jit", f"metric:{fn.attr}")
            elif isinstance(fn, ast.Name) and fn.id in ("float", "int") \
                    and taint is not None and node.args and \
                    taint.carries(node.args[0]):
                flag(node, f"'{fn.id}()' on a traced value",
                     "this raises at trace time; keep it as a jnp scalar "
                     "or make the argument static", f"{fn.id}-on-tracer")


def _check_numpy(fi: jitgraph.FuncInfo, findings: List[Finding]) -> None:
    """Separate pass: any `np.<...>` expression inside the body."""
    np_aliases = base.numpy_aliases(fi.mod)
    if not np_aliases:
        return
    seen_lines: Set[int] = set()
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id in np_aliases:
            if node.lineno in seen_lines:
                continue
            seen_lines.add(node.lineno)
            findings.append(Finding(
                rule=base.RULE_JIT_PURITY, path=fi.mod.path,
                line=node.lineno,
                message=(f"host numpy use '{base.dotted(node)}' in "
                         f"jit-reachable '{fi.qualname}'"),
                hint="use jnp instead — np materialises tracers "
                     "(ConcretizationTypeError) or silently constant-folds",
                symbol=f"{fi.qualname}:np:{node.attr}"))


def check(mods: List[Module]) -> List[Finding]:
    graph = jitgraph.JitGraph(mods)
    findings: List[Finding] = []
    for fi in graph.reachable_funcs():
        _check_func(fi, findings)
        _check_numpy(fi, findings)
    return findings
