"""recompile-hazard checker.

Two hazards that silently wreck jit cache hit rates (or error at trace):

1. **Non-hashable static arguments.** A call site that passes a list /
   dict / set display (or an ``np.array(...)``) in a position declared
   ``static_argnums``/``static_argnames`` raises ``Unhashable static
   arguments`` at call time — or, with a tuple-of-arrays, recompiles on
   every call because the hash never matches.

2. **Python branches on traced values.** ``if x > 0:`` where ``x`` is a
   tracer raises ``TracerBoolConversionError``; the sneakier version is
   branching on a value *derived* from a tracer. Branching on
   ``.shape`` / ``.ndim`` / ``.dtype`` / ``len()`` is static and fine —
   the taint query launders those. Checked on jit roots only, where the
   parameter list is known to be the traced signature.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis import base, jitgraph
from repro.analysis.base import Finding, Module

_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.SetComp,
               ast.DictComp, ast.GeneratorExp)
_ARRAY_CTORS = {"array", "asarray", "zeros", "ones", "arange"}


def _is_unhashable_expr(node: ast.AST) -> bool:
    if isinstance(node, _UNHASHABLE):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        d = base.dotted(node.func)
        head = d.split(".")[0] if d else ""
        if node.func.attr in _ARRAY_CTORS and head in ("np", "numpy",
                                                       "jnp", "jax"):
            return True
    return False


def _collect_static_specs(mods: List[Module]
                          ) -> Dict[str, Tuple[Set[str], Set[int]]]:
    """bare function name -> (static kw names, static positions)."""
    specs: Dict[str, Tuple[Set[str], Set[int]]] = {}
    for mod in mods:
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    names = jitgraph._jit_decorator_statics(dec, node)
                    if names:
                        call = dec if isinstance(dec, ast.Call) else None
                        nums = jitgraph.static_positions(call) if call \
                            else set()
                        specs[node.name] = (names, nums)
            elif isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    base.dotted(node.value.func) in jitgraph._JIT_NAMES:
                call = node.value
                names = jitgraph._static_names_from_call(call)
                nums = jitgraph.static_positions(call)
                if not (names or nums):
                    continue
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        specs[tgt.id] = (names, nums)
                    elif isinstance(tgt, ast.Attribute):
                        specs[tgt.attr] = (names, nums)
    return specs


def _check_call_sites(mods: List[Module],
                      specs: Dict[str, Tuple[Set[str], Set[int]]],
                      findings: List[Finding]) -> None:
    for mod in mods:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            d = base.dotted(node.func)
            name = d.split(".")[-1] if d else ""
            if name not in specs:
                continue
            static_names, static_nums = specs[name]
            bad = []
            for kw in node.keywords:
                if kw.arg in static_names and \
                        _is_unhashable_expr(kw.value):
                    bad.append((kw.value, kw.arg))
            for i, arg in enumerate(node.args):
                if i in static_nums and _is_unhashable_expr(arg):
                    bad.append((arg, f"arg {i}"))
            for expr, which in bad:
                findings.append(Finding(
                    rule=base.RULE_RECOMPILE, path=mod.path,
                    line=expr.lineno,
                    message=(f"non-hashable value passed for static "
                             f"argument '{which}' of jitted '{name}'"),
                    hint="static args join the jit cache key and must be "
                         "hashable — pass a tuple / frozen value instead",
                    symbol=f"static:{name}:{which}"))


def _check_tracer_branches(graph: jitgraph.JitGraph,
                           findings: List[Finding]) -> None:
    for fi in graph.roots():
        taint = base.propagate_taint(fi.node, fi.traced_params())
        for node in ast.walk(fi.node):
            if isinstance(node, (ast.If, ast.While)) and \
                    taint.carries(node.test):
                kind = "if" if isinstance(node, ast.If) else "while"
                findings.append(Finding(
                    rule=base.RULE_RECOMPILE, path=fi.mod.path,
                    line=node.lineno,
                    message=(f"Python '{kind}' on a traced value in jit "
                             f"root '{fi.qualname}'"),
                    hint="use jax.lax.cond / jnp.where, or derive the "
                         "predicate from static shapes (.shape, len())",
                    symbol=f"branch:{fi.qualname}:{node.lineno - fi.node.lineno}"))


def check(mods: List[Module]) -> List[Finding]:
    findings: List[Finding] = []
    specs = _collect_static_specs(mods)
    _check_call_sites(mods, specs, findings)
    _check_tracer_branches(jitgraph.JitGraph(mods), findings)
    return findings
