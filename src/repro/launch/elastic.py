"""Fault tolerance + elastic scaling runtime.

Large-scale posture (DESIGN.md §7): at 1000+ nodes, node loss is routine.
The framework's contract:

  1. every state mutation flows through `TrainState` and is checkpointed
     (atomic + async, ckpt/checkpoint.py) every `ckpt_every` steps;
  2. `HealthMonitor` wraps each step: a step that raises (device loss) or
     exceeds `timeout_factor` x EWMA step time (straggler) triggers recovery;
  3. recovery = rebuild the mesh from surviving hosts (the device set is a
     constructor argument, so tests inject failures), re-resolve shardings
     on the SMALLER mesh, restore the latest checkpoint re-sharded onto it —
     possible because checkpoints store global arrays (ckpt docstring);
  4. the data stream is a pure function of (step, shard) (data/tokens.py),
     so resumed training replays no batch and skips none.

This container has one process, so multi-host failure is *simulated* by
shrinking the virtual device list — the same code path a real deployment
takes through jax.distributed, minus the TCP barrier. Straggler mitigation
follows the checkpoint-elastic-resume pattern rather than backup-task
re-execution (TPU pods fail as slices; MapReduce-style speculative
execution does not apply).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.ckpt import CheckpointManager


class NodeFailure(RuntimeError):
    """Raised by the step wrapper when a device/host is lost."""


@dataclasses.dataclass
class HealthMonitor:
    """EWMA step timer with straggler detection."""
    alpha: float = 0.1
    timeout_factor: float = 5.0
    warmup_steps: int = 3
    _ewma: Optional[float] = None
    _steps: int = 0

    def observe(self, dt: float) -> bool:
        """Record a step time; True if this step counts as a straggler."""
        self._steps += 1
        if self._ewma is None:
            self._ewma = dt
            return False
        straggler = (self._steps > self.warmup_steps
                     and dt > self.timeout_factor * self._ewma)
        self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        return straggler

    @property
    def ewma(self) -> Optional[float]:
        return self._ewma


def make_mesh_from(devices: Sequence, model_axis: int):
    """Largest (data, model) mesh on the surviving device list."""
    n = len(devices)
    model = min(model_axis, n)
    while n % model:
        model -= 1
    data = n // model
    arr = np.asarray(devices[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


@dataclasses.dataclass
class ElasticRunner:
    """Drives train steps with checkpoint/restart + elastic re-meshing."""
    build: Callable  # (mesh) -> (step_fn, state, shardings) — rebuildable
    ckpt_dir: str
    model_axis: int = 1
    ckpt_every: int = 50
    max_recoveries: int = 8

    def run(self, n_steps: int, batches: Callable[[int], dict],
            devices: Optional[List] = None, inject_failure_at: int = -1):
        """Run n_steps; `inject_failure_at` kills half the devices once (test
        hook). Returns (state, log)."""
        devices = list(devices if devices is not None else jax.devices())
        mgr = CheckpointManager(self.ckpt_dir)
        monitor = HealthMonitor()
        log = []
        recoveries = 0
        mesh = make_mesh_from(devices, self.model_axis)
        step_fn, state, shardings = self.build(mesh)
        start, restored = mgr.restore_latest(state, shardings)
        step0 = 0
        if restored is not None:
            state = restored
            step0 = start + 1
            log.append(("restore", start, len(devices)))

        step = step0
        while step < n_steps:
            try:
                if step == inject_failure_at and recoveries == 0:
                    devices = devices[: max(len(devices) // 2, 1)]
                    raise NodeFailure(f"injected loss at step {step}")
                t0 = time.time()
                state, metrics = step_fn(state, batches(step))
                dt = time.time() - t0
                if monitor.observe(dt):
                    log.append(("straggler", step, dt))
                if step % self.ckpt_every == 0:
                    mgr.save_async(step, state)
                log.append(("step", step, float(metrics.get("loss", 0.0))))
                step += 1
            except (NodeFailure, jax.errors.JaxRuntimeError) as e:
                recoveries += 1
                if recoveries > self.max_recoveries:
                    raise
                log.append(("failure", step, str(e)[:80]))
                mgr.wait()
                mesh = make_mesh_from(devices, self.model_axis)
                step_fn, state, shardings = self.build(mesh)
                start, restored = mgr.restore_latest(state, shardings)
                if restored is not None:
                    state = restored
                    step = start + 1
                else:
                    step = 0
                log.append(("remesh", step, len(devices)))
        mgr.wait()
        mgr.save_async(n_steps - 1, state)
        mgr.wait()
        return state, log
