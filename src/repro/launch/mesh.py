"""Production meshes. A FUNCTION, not a module-level constant — importing
this module never touches jax device state (dryrun.py must set XLA_FLAGS
before any jax initialisation)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pipeline_mesh(*, stages: int = 4, data: int = 8, model: int = 8):
    """Optional PP mesh variant (launch/pipeline.py)."""
    return jax.make_mesh((stages, data, model), ("stage", "data", "model"))


def make_host_mesh():
    """Whatever this host has — used by tests and the CPU examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
