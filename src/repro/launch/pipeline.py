"""Pipeline parallelism (GPipe schedule) over a "stage" mesh axis.

`shard_map` + `ppermute` realisation: layer-stack params are sharded over
stages; micro-batch activations flow stage->stage through collective
permutes; the bubble is the usual (S-1)/(M+S-1). Autodiff through ppermute
gives the reverse schedule for backward. This is the scale-out option for
deep archs (granite-34b's 88 layers) when a pure TP/FSDP mesh runs out of
parallel axes; covered by an 8-virtual-device subprocess test.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe(apply_stage: Callable, mesh: Mesh, *, axis: str = "stage"):
    """Build a pipelined apply: (params_stacked, x_micro) -> y_micro.

    apply_stage(params_local, x) applies ONE stage's layer block.
    params_stacked leaves: (n_stages * per_stage, ...) — sharded on dim 0.
    x_micro: (n_micro, micro_batch, ...) — replicated; stage 0 ingests.
    """
    n_stage = mesh.shape[axis]

    def pipelined(params, x_micro):
        s = jax.lax.axis_index(axis)
        n_micro = x_micro.shape[0]
        ticks = n_micro + n_stage - 1
        perm = [(i, i + 1) for i in range(n_stage - 1)]

        def tick(carry, t):
            buf, outs = carry
            inp = jnp.where(s == 0,
                            x_micro[jnp.minimum(t, n_micro - 1)], buf)
            h = apply_stage(params, inp)
            # emit on the last stage once the pipe is full
            out_idx = t - (n_stage - 1)
            emit = (s == n_stage - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            buf = jax.lax.ppermute(h, axis, perm)
            return (buf, outs), None

        buf0 = jnp.zeros_like(x_micro[0])
        outs0 = jnp.zeros_like(x_micro)
        (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                      jnp.arange(ticks))
        # replicate final outputs from the last stage
        mask = (s == n_stage - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * mask, axis)
        return outs

    in_specs = (P(axis), P())          # params sharded on stage; x replicated
    out_specs = P()
    return shard_map(pipelined, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def mlp_stage(params_local, x):
    """Demonstrator stage: a block of gelu-MLP layers (scan over local dim)."""
    def body(h, lp):
        h = h + jax.nn.gelu((h @ lp["w1"])) @ lp["w2"]
        return h, None
    y, _ = jax.lax.scan(body, x, params_local)
    return y


def reference_apply(params_stacked, x_micro):
    """Sequential oracle for tests: same math, no pipeline."""
    def body(h, lp):
        h = h + jax.nn.gelu((h @ lp["w1"])) @ lp["w2"]
        return h, None

    def one(x):
        y, _ = jax.lax.scan(body, x, params_stacked)
        return y
    return jax.vmap(one)(x_micro)
