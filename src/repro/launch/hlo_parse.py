"""Trip-count-aware HLO text analysis.

XLA's `compiled.cost_analysis()` counts a `while` (lax.scan) body ONCE,
regardless of trip count — useless for scanned-layer transformers. This
module parses the partitioned HLO text into computations, extracts each
while loop's trip count from its condition computation, and walks the call
graph with multipliers to produce trip-weighted:

  * flops            (dot: 2 * |result| * |contraction|; conv approximated)
  * bytes accessed   (per op: operand + result bytes, fusion interiors free —
                      XLA's own fusion accounting convention)
  * collective bytes (operand + ring-model wire bytes per collective kind)

This is the "profile" of the dry-run perf loop (no real TPU available).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_CALLED_RE = re.compile(r"(?:body|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_BRACE_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "reshape", "opt-barrier", "domain", "token",
}


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str           # result type string
    opcode: str
    line: str            # metadata-stripped


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    defs: Dict[str, str]         # value name -> result type string


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.split(" metadata={")[0].split(", metadata={")[0]
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        d = _DEF_RE.match(line)
        if d:
            name, shape, opcode = d.group(1), d.group(2), d.group(3)
            cur.defs[name] = shape
            cur.ops.append(Op(name, shape, opcode, line.strip()))
    return comps


def while_trip_counts(comps: Dict[str, Computation]) -> Dict[str, int]:
    """Map while BODY computation name -> trip count (from its condition)."""
    trips: Dict[str, int] = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode != "while":
                continue
            mb = _CALLED_RE.search(op.line)
            mc = _COND_RE.search(op.line)
            if not (mb and mc):
                continue
            cond = comps.get(mc.group(1))
            trip = 1
            if cond is not None:
                consts = [int(x) for o in cond.ops
                          for x in _CONST_RE.findall(o.line)]
                if consts:
                    trip = max(consts)
            trips[mb.group(1)] = max(trip, 1)
    return trips


def _operand_names(op: Op) -> List[str]:
    inner = op.line.split("(", 1)[1]
    depth = 1
    args = ""
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args += ch
    return re.findall(r"%([\w.\-]+)", args)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _BRACE_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def _dot_flops(op: Op, comp: Computation) -> float:
    res = _shape_dims(op.shape)
    n_res = 1
    for _, dims in res:
        for d in dims:
            n_res *= d
    operands = _operand_names(op)
    contract = 1
    m = _CONTRACT_RE.search(op.line)
    if m and operands:
        lhs_shape = comp.defs.get(operands[0])
        if lhs_shape:
            dims = _shape_dims(lhs_shape)[0][1]
            for idx in (m.group(1).split(",") if m.group(1) else []):
                i = int(idx)
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * n_res * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    # approximate: 2 * |result| * prod(kernel spatial dims) (depthwise-ish)
    res = _shape_bytes(op.shape) / 4.0
    m = re.search(r"window=\{size=([0-9x]+)", op.line)
    k = 1
    if m:
        for d in m.group(1).split("x"):
            k *= int(d)
    return 2.0 * res * k


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_operand: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_wire: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVES})


def _walk(comp: Computation, comps: Dict[str, Computation],
          trips: Dict[str, int], mult: float, costs: Costs,
          in_fusion: bool = False):
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            mb = _CALLED_RE.search(op.line)
            if mb and mb.group(1) in comps:
                body = mb.group(1)
                _walk(comps[body], comps, trips,
                      mult * trips.get(body, 1), costs)
            continue
        if oc == "fusion":
            # fusion-call-level bytes; recurse for dots (flops only)
            if not in_fusion:
                b = _shape_bytes(op.shape)
                for nm in _operand_names(op):
                    if nm in comp.defs:
                        b += _shape_bytes(comp.defs[nm])
                costs.bytes += mult * b
            mc = _CALLED_RE.search(op.line)
            if mc and mc.group(1) in comps:
                _walk(comps[mc.group(1)], comps, trips, mult, costs,
                      in_fusion=True)
            continue
        base = oc.replace("-start", "")
        if base in COLLECTIVES:
            res = float(_shape_bytes(op.shape))
            g = _group_size(op.line)
            if base == "all-gather":
                ob, wb = res / g, res * (g - 1) / g
            elif base == "all-reduce":
                ob, wb = res, 2.0 * res * (g - 1) / g
            elif base == "reduce-scatter":
                ob, wb = res * g, res * (g - 1)
            elif base == "all-to-all":
                ob, wb = res, res * (g - 1) / g
            else:
                ob, wb = res, res
            costs.coll_operand[base] += mult * ob
            costs.coll_wire[base] += mult * wb
            costs.coll_count[base] += 1
            costs.bytes += mult * res
            continue
        if oc.endswith("-done") or oc in _FREE_OPS:
            continue
        if oc == "dot":
            costs.flops += mult * _dot_flops(op, comp)
        elif oc == "convolution":
            costs.flops += mult * _conv_flops(op, comp)
        if not in_fusion:
            b = _shape_bytes(op.shape)
            for nm in _operand_names(op):
                if nm in comp.defs:
                    b += _shape_bytes(comp.defs[nm])
            costs.bytes += mult * b


def top_collectives(text: str, k: int = 20):
    """Top-k collective ops by trip-weighted wire bytes (perf-loop probe)."""
    comps = parse_computations(text)
    trips = while_trip_counts(comps)
    entries = []

    def walk(comp, mult):
        for op in comp.ops:
            if op.opcode == "while":
                mb = _CALLED_RE.search(op.line)
                if mb and mb.group(1) in comps:
                    walk(comps[mb.group(1)], mult * trips.get(mb.group(1), 1))
                continue
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                res = float(_shape_bytes(op.shape))
                g = _group_size(op.line)
                wire = {"all-gather": res * (g - 1) / g,
                        "all-reduce": 2.0 * res * (g - 1) / g,
                        "reduce-scatter": res * (g - 1),
                        "all-to-all": res * (g - 1) / g,
                        "collective-permute": res}[base]
                entries.append((mult * wire, mult, base, op.shape[:60],
                                op.name))
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    walk(comps[m.group(1) if m else next(iter(comps))], 1.0)
    return sorted(entries, reverse=True)[:k]


def analyze(text: str, entry: Optional[str] = None) -> Dict[str, float]:
    comps = parse_computations(text)
    trips = while_trip_counts(comps)
    # entry: the computation marked ENTRY — detect from text
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        entry_name = m.group(1) if m else next(iter(comps))
    costs = Costs()
    _walk(comps[entry_name], comps, trips, 1.0, costs)
    rec = {
        "flops": costs.flops,
        "bytes": costs.bytes,
        "coll_operand_total": sum(costs.coll_operand.values()),
        "coll_wire_total": sum(costs.coll_wire.values()),
    }
    for k in COLLECTIVES:
        rec[f"op_{k}"] = costs.coll_operand[k]
        rec[f"wire_{k}"] = costs.coll_wire[k]
        rec[f"n_{k}"] = costs.coll_count[k]
    rec["n_while_bodies"] = len(trips)
    rec["trip_counts"] = sorted(trips.values(), reverse=True)[:12]
    return rec
