import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
512 placeholder devices and record memory/cost/roofline artifacts.

MUST be executed as its own process (python -m repro.launch.dryrun ...) so
the XLA_FLAGS above take effect before jax initialises. `--all` mode forks a
fresh subprocess per cell (fresh device state, bounded memory) and is
resumable — existing JSONs are skipped unless --force.
"""

import argparse
import json
import sys
import time
import traceback


def _parse_override(v: str):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, overrides=None) -> dict:
    import dataclasses

    import jax
    from repro.configs.base import LM_SHAPES, shapes_for
    from repro.configs.registry import get_arch
    from repro.launch import hlo_analysis as ha
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell

    if arch == "rtnerf":
        return _run_nerf_cell(shape_name, mesh_kind, overrides)

    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = LM_SHAPES[shape_name]
    skip = None
    for s, why in shapes_for(cfg):
        if s.name == shape_name:
            skip = why
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "status": "skip" if skip else "pending",
        "skip_reason": skip,
        "overrides": overrides or {},
    }
    if skip:
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = mesh.devices.size
    t0 = time.time()
    lowered, info = lower_cell(cfg, shape, mesh)
    rec.update(info)
    rec["lower_s"] = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t0

    # --- memory analysis (proves it fits) ---
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_size_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_size_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_size_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "temp_size_in_bytes", 0))
            + int(getattr(ma, "argument_size_in_bytes", 0))
            + int(getattr(ma, "output_size_in_bytes", 0))
            - int(getattr(ma, "alias_size_in_bytes", 0)),
        }
        print("memory_analysis:", rec["memory_analysis"], flush=True)
    except Exception as e:           # pragma: no cover
        rec["memory_analysis"] = {"error": str(e)}

    # --- raw XLA cost analysis (NOTE: counts while bodies once; reference
    # only — the trip-weighted HLO parse below is authoritative) ---
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost_analysis_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
    except Exception as e:           # pragma: no cover
        rec["cost_analysis_raw"] = {"error": str(e)}

    # --- trip-weighted per-device flops / bytes / collective bytes ---
    from repro.launch import hlo_parse
    txt = compiled.as_text()
    rec["hlo_lines"] = txt.count("\n")
    parsed = hlo_parse.analyze(txt)
    rec["hlo_costs"] = parsed
    flops = parsed["flops"]
    byts = parsed["bytes"]
    print(f"hlo_costs: flops={flops:.3e} bytes={byts:.3e} "
          f"coll_wire={parsed['coll_wire_total']:.3e}", flush=True)

    # --- roofline terms (collective term uses per-device wire bytes) ---
    terms = ha.roofline_terms(flops, byts, parsed["coll_wire_total"])
    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    mf = ha.model_flops(rec["n_params"], rec.get("n_active", 0), n_tokens,
                        shape.kind)
    terms["model_flops"] = mf
    terms["model_flops_per_dev"] = mf / n_dev
    terms["useful_flops_ratio"] = (mf / n_dev) / flops if flops else 0.0
    rec["roofline"] = terms
    rec["n_devices"] = n_dev
    rec["status"] = "ok"
    print(f"roofline: {terms}", flush=True)
    return rec


def _run_nerf_cell(shape_name: str, mesh_kind: str, overrides=None) -> dict:
    """The paper's own workload on the production mesh."""
    import dataclasses
    import time

    from repro.configs.rtnerf import CONFIG, NERF_SHAPES
    from repro.core.distributed import lower_nerf_cell
    from repro.launch import hlo_analysis as ha
    from repro.launch import hlo_parse
    from repro.launch.mesh import make_production_mesh

    cfg = dataclasses.replace(CONFIG, **(overrides or {}))
    shape = NERF_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rec = {"arch": "rtnerf", "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "n_rays": shape.n_rays,
           "overrides": overrides or {}}
    t0 = time.time()
    lowered, info = lower_nerf_cell(cfg, shape, mesh)
    rec.update(info)
    rec["lower_s"] = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t0
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_size_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        }
    except Exception as e:                    # pragma: no cover
        rec["memory_analysis"] = {"error": str(e)}
    txt = compiled.as_text()
    parsed = hlo_parse.analyze(txt)
    rec["hlo_costs"] = parsed
    terms = ha.roofline_terms(parsed["flops"], parsed["bytes"],
                              parsed["coll_wire_total"])
    rec["roofline"] = terms
    rec["n_devices"] = mesh.devices.size
    rec["status"] = "ok"
    print("roofline:", terms, flush=True)
    return rec


def cell_path(out_dir, arch, shape, mesh_kind, tag=""):
    sfx = f"__{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{sfx}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for perf variants")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (perf hillclimb)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = _parse_override(v)
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        import subprocess
        from repro.configs.registry import ARCHS
        from repro.configs.base import LM_SHAPES
        failures = []
        for mesh_kind in ("pod", "multipod"):
            for arch in ARCHS:
                for shape in LM_SHAPES:
                    p = cell_path(args.out, arch, shape, mesh_kind)
                    if os.path.exists(p) and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", mesh_kind, "--out", args.out]
                    print(">>>", " ".join(cmd), flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh_kind))
        print("failures:", failures)
        sys.exit(1 if failures else 0)

    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                       force=args.force, overrides=overrides)
    except Exception:
        traceback.print_exc()
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": traceback.format_exc()}
        with open(cell_path(args.out, args.arch, args.shape, args.mesh,
                            args.tag), "w") as f:
            json.dump(rec, f, indent=1)
        sys.exit(1)
    with open(cell_path(args.out, args.arch, args.shape, args.mesh,
                        args.tag), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{rec['status']}] {args.arch} x {args.shape} x {args.mesh} "
          f"{args.tag}")


if __name__ == "__main__":
    main()
