"""Serving launcher: batched prefill + decode loop (LM) or batched
novel-view rendering (rtnerf).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --arch rtnerf \
        --scene lego --views 2 --res 64 \
        --field-mode hybrid --prune-sparsity 0.9
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_arch, reduced
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models import transformer as tf
from repro.models.common import split_pl
from repro.models.sharding import make_rules
from repro.launch.mesh import make_host_mesh


def serve_lm(args):
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    key = jax.random.PRNGKey(0)
    params, _ = split_pl(tf.init_model(cfg, key))

    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    batch = {"tokens": jax.random.randint(key, (B, P), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.zeros((B, cfg.n_frontend_tokens, cfg.d_model),
                                      jnp.bfloat16)
    if cfg.enc_dec:
        batch["enc_frames"] = jax.random.normal(key, (B, P, cfg.d_model),
                                                jnp.bfloat16)

    prefill = jax.jit(build_prefill_step(cfg, rules))
    decode = jax.jit(build_decode_step(cfg, rules, total),
                     static_argnames=())

    t0 = time.time()
    logits, cache = prefill(params, batch)
    # grow caches to the serving horizon (cross-KV at true encoder length)
    shapes, _ = tf.serve_cache_spec(cfg, B, total, enc_len=P)

    def fit(c, s):
        if c.shape == s.shape:
            return c
        pad = [(0, a - b) for a, b in zip(s.shape, c.shape)]
        return jnp.pad(c.astype(s.dtype), pad)
    cache = jax.tree.map(fit, cache, shapes)
    print(f"prefill: {time.time() - t0:.2f}s logits {logits.shape}")

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = decode(params, tok, jnp.int32(P + i), cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decoded {B}x{G - 1} tokens in {dt:.2f}s "
          f"({B * (G - 1) / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", toks[0, :12].tolist())


def serve_nerf(args):
    from repro.configs.rtnerf import NeRFConfig
    from repro.core import occupancy as occ_lib
    from repro.core import sparse, tensorf
    from repro.core import train as nerf_train
    from repro.data import rays as rays_lib

    cfg = NeRFConfig(grid_res=48, occ_res=48, cube_size=4, max_cubes=1024,
                     r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                     max_samples_per_ray=128, train_rays=1024)
    res = nerf_train.train_nerf(cfg, args.scene, steps=args.train_steps,
                                n_views=8, image_hw=args.res, log_every=100)
    params, cubes = res.params, res.cubes
    if args.prune_sparsity > 0.0:
        # magnitude-sparsify then rebuild occupancy (the field changed)
        params = tensorf.prune_to_sparsity(params, args.prune_sparsity)
        occ = occ_lib.build_occupancy(params, cfg, sigma_thresh=0.5)
        cubes = occ_lib.extract_cubes(occ, cfg)
    field = params
    if args.field_mode == "hybrid":
        # encode once, serve every view from the compressed stream
        field = sparse.compress_field(params, cfg)
        print(f"compressed field: {field.factor_bytes()} B factors "
              f"(dense {field.dense_factor_bytes()} B, "
              f"{field.compression_ratio():.2f}x)")
    scene = rays_lib.make_scene(args.scene)
    cams = rays_lib.make_cameras(args.views, args.res, args.res)
    total = 0.0
    for i, cam in enumerate(cams):
        gt = rays_lib.render_gt(scene, cam)
        t0 = time.time()
        p, stats, _ = nerf_train.eval_view(field, cfg, cubes, cam,
                                           gt, pipeline="rtnerf", chunk=8,
                                           field_mode=args.field_mode)
        dt = time.time() - t0
        total += dt
        print(f"view {i}: psnr={p:.2f} {dt:.2f}s "
              f"occ_accesses={stats['occ_accesses']:.0f} "
              f"factor_bytes={stats['factor_bytes']:.0f}")
    print(f"served {args.views} views, {args.views / total:.3f} FPS (CPU), "
          f"field_mode={args.field_mode}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=sorted(ARCHS) + ["rtnerf"])
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--views", type=int, default=2)
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--field-mode", choices=("dense", "hybrid"),
                    default="dense",
                    help="rtnerf only: evaluate raw factors or the hybrid "
                         "bitmap/COO compressed stream (Sec. 4.2.2)")
    ap.add_argument("--prune-sparsity", type=float, default=0.0,
                    help="rtnerf only: magnitude-prune factors to this "
                         "sparsity before serving (0 = training prune only)")
    args = ap.parse_args()
    if args.arch == "rtnerf":
        serve_nerf(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
