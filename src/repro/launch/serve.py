"""Serving launcher: batched prefill + decode loop (LM) or batched
novel-view rendering (rtnerf).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --arch rtnerf \
        --scene lego --views 2 --res 64 \
        --prune-sparsity 0.9 --ckpt-dir /tmp/lego-ckpt
    PYTHONPATH=src python -m repro.launch.serve --arch rtnerf \
        --scene lego --finetune-steps 200 --finetune-every 50
    PYTHONPATH=src python -m repro.launch.serve --arch rtnerf \
        --scenes lego,chair,mic --max-resident-mb 2 --finetune-steps 100
    PYTHONPATH=src python -m repro.launch.serve --arch rtnerf \
        --scenes lego,chair,mic --fleet-workers 2 --max-resident-mb 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_arch, reduced
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models import transformer as tf
from repro.models.common import split_pl
from repro.models.sharding import make_rules
from repro.launch.mesh import make_host_mesh


def serve_lm(args):
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    key = jax.random.PRNGKey(0)
    params, _ = split_pl(tf.init_model(cfg, key))

    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    batch = {"tokens": jax.random.randint(key, (B, P), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.zeros((B, cfg.n_frontend_tokens, cfg.d_model),
                                      jnp.bfloat16)
    if cfg.enc_dec:
        batch["enc_frames"] = jax.random.normal(key, (B, P, cfg.d_model),
                                                jnp.bfloat16)

    prefill = jax.jit(build_prefill_step(cfg, rules))
    decode = jax.jit(build_decode_step(cfg, rules, total),
                     static_argnames=())

    t0 = time.time()
    logits, cache = prefill(params, batch)
    # grow caches to the serving horizon (cross-KV at true encoder length)
    shapes, _ = tf.serve_cache_spec(cfg, B, total, enc_len=P)

    def fit(c, s):
        if c.shape == s.shape:
            return c
        pad = [(0, a - b) for a, b in zip(s.shape, c.shape)]
        return jnp.pad(c.astype(s.dtype), pad)
    cache = jax.tree.map(fit, cache, shapes)
    print(f"prefill: {time.time() - t0:.2f}s logits {logits.shape}")

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = decode(params, tok, jnp.int32(P + i), cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decoded {B}x{G - 1} tokens in {dt:.2f}s "
          f"({B * (G - 1) / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", toks[0, :12].tolist())


def serve_nerf(args):
    """Streaming multi-view serving from a store of resident compressed
    fields.

    --scenes a,b,c serves several named scenes from ONE process: each is
    restored from its per-scene subdirectory of --ckpt-dir when a
    checkpoint exists (trained once — compressed-native — and saved there
    in encoded form otherwise), registered in the engine's SceneStore, and
    every queued view is rendered by the engine's single jitted
    micro-batched step, grouped per scene at flush time. --max-resident-mb
    bounds the encoded bytes resident at once: cold scenes are LRU-evicted
    to encoded checkpoints and revived transparently when their next
    request arrives. --deadline fails stale requests instead of rendering
    them late. --finetune-steps starts the online fine-tuning service
    (serving.FineTuneLoop): one background trainer PER RESIDENT SCENE
    refreshes its field through the store every --finetune-every steps
    while the request streams keep rendering.
    """
    import contextlib
    import json

    from repro.configs.base import mib_to_bytes
    from repro.configs.rtnerf import NeRFConfig
    from repro.data import rays as rays_lib
    from repro.obs import (MetricsRegistry, MetricsServer, StatsReporter,
                           snapshot_json)
    from repro.serving import FineTuneLoop, RenderEngine

    scenes = [s for s in args.scenes.split(",") if s] if args.scenes \
        else [args.scene]
    cfg = NeRFConfig(grid_res=48, occ_res=48, cube_size=4, max_cubes=1024,
                     r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                     max_samples_per_ray=128, train_rays=1024,
                     max_resident_bytes=mib_to_bytes(args.max_resident_mb))

    # the registry is created BEFORE the engine (which may train scenes for
    # minutes) so the exposition endpoint answers scrapes from the start;
    # the engine and every fine-tune loop record into this same registry
    registry = MetricsRegistry()
    holder = {"engine": None}

    def _extra_stats():
        eng = holder["engine"]
        return eng.stats() if eng is not None else {"phase": "loading"}

    mserver = None
    if args.metrics_port is not None:
        mserver = MetricsServer(registry, port=args.metrics_port,
                                extra=_extra_stats)
        print(f"[obs] metrics: http://127.0.0.1:{mserver.port}/metrics "
              f"(Prometheus) and /metrics.json (snapshot)", flush=True)

    engine = RenderEngine.from_scenes(
        cfg, scenes, ckpt_root=args.ckpt_dir,
        train_steps=args.train_steps, n_views=8, image_hw=args.res,
        prune_sparsity=args.prune_sparsity, encode=not args.dense,
        ray_chunk=args.res * args.res, max_batch_views=args.views,
        auto_flush_interval=(0.25 if args.finetune_steps else None),
        registry=registry)
    holder["engine"] = engine

    reporter = None
    if args.stats_interval:
        def _stats_line():
            s = engine.stats()
            return (f"[obs] views={s['views_served']} fps={s['fps']:.3f} "
                    f"p50={s['latency_p50_s'] * 1e3:.0f}ms "
                    f"p99={s['latency_p99_s'] * 1e3:.0f}ms "
                    f"flushes={s['flushes']} timeouts={s['timeouts']} "
                    f"dropped={s['dropped_pairs']} swaps={s['field_swaps']}")
        reporter = StatsReporter(_stats_line, args.stats_interval)
    for name in scenes:
        s = engine.stats(scene=name)
        print(f"scene '{name}': {s['field_kind']}, "
              f"{s['factor_bytes']:.0f} B factors "
              f"(dense {s['factor_bytes_dense']:.0f} B, "
              f"{s['compression_ratio']:.2f}x)")
    if engine.store.max_resident_bytes:
        print(f"resident budget {engine.store.max_resident_bytes} B, "
              f"resident now: {engine.store.resident_scenes()}")

    loops = []
    if args.finetune_steps:
        # one trainer thread per resident scene, all publishing through
        # the store (ROADMAP "multi-scene fine-tuning")
        loops = [FineTuneLoop.attach(engine.store, name,
                                     steps=args.finetune_steps,
                                     publish_every=args.finetune_every,
                                     n_views=8, image_hw=args.res,
                                     verbose=True).start()
                 for name in scenes]

    gt_scenes = {name: rays_lib.make_scene(name) for name in scenes}
    cams = rays_lib.make_cameras(args.views, args.res, args.res)
    gts = {name: [rays_lib.render_gt(gt_scenes[name], cam) for cam in cams]
           for name in scenes}
    rounds = 1 if not loops else max(args.finetune_rounds, 1)
    # --profile-dir captures an XLA device profile of the serving rounds;
    # the jax.named_scope markers in core/pipeline.py tag the HLO so the
    # capture lines up with the host-side request spans
    prof = (jax.profiler.trace(args.profile_dir) if args.profile_dir
            else contextlib.nullcontext())
    with prof:
        for rnd in range(rounds):
            futures = [(name, engine.submit(cam, gt, scene=name,
                                            deadline_s=args.deadline))
                       for name in scenes
                       for cam, gt in zip(cams, gts[name])]
            for i, (name, fut) in enumerate(futures):
                r = fut.result()
                if r.timed_out:
                    print(f"{name} view {i}: TIMED OUT after "
                          f"{r.latency_s:.2f}s")
                    continue
                print(f"{name} view {i}: psnr={r.psnr:.2f} "
                      f"latency={r.latency_s:.2f}s "
                      f"occ_accesses={r.stats['occ_accesses']:.0f} "
                      f"factor_bytes={r.stats['factor_bytes']:.0f}")
    if args.profile_dir:
        print(f"[obs] XLA profile written to {args.profile_dir}")
    if loops:
        for loop in loops:
            loop.join()
        engine.close()
        total_steps = sum(loop.trainer.step_count for loop in loops)
        total_swaps = sum(len(loop.swaps) for loop in loops)
        print(f"fine-tuned {total_steps} steps over {len(loops)} scenes, "
              f"{total_swaps} live swaps "
              f"(max swap {engine.stats()['swap_latency_s_max'] * 1e3:.1f}ms)")
    s = engine.stats()
    print(f"served {s['views_served']} views over {s['n_scenes']} scenes, "
          f"{s['fps']:.3f} FPS (CPU), "
          f"p50={s['latency_p50_s']:.2f}s p95={s['latency_p95_s']:.2f}s, "
          f"ordering-cache hits={s['ordering_cache']['hits']}, "
          f"timeouts={s['timeouts']}, swaps={s['field_swaps']}, "
          f"evictions={s['evictions']}, revivals={s['revivals']}, "
          f"pair_budget={s['pair_budget']} "
          f"(init {s['pair_budget_initial']}, "
          f"{s['pair_budget_resizes']} resizes)")
    br = engine.stage_breakdown()
    if br:
        print("stage breakdown (per request):")
        for stage, d in br.items():
            print(f"  {stage:>10s}  n={d['count']:4d} "
                  f"p50={d['p50_s'] * 1e3:8.2f}ms "
                  f"p99={d['p99_s'] * 1e3:8.2f}ms "
                  f"total={d['total_s']:7.3f}s")
    if args.metrics_dump:
        snap = snapshot_json(registry, extra=s)
        with open(args.metrics_dump, "w") as f:
            json.dump(snap, f, indent=2)
        print(f"[obs] metrics snapshot written to {args.metrics_dump}")
    if reporter is not None:
        reporter.close()
    if mserver is not None:
        mserver.close()


def serve_fleet(args):
    """Fleet tier: shard --scenes across --fleet-workers worker processes
    by consistent hashing (serving.FleetRouter).

    Each worker is a full RenderEngine in its own process; scenes are
    trained/restored once in the launcher (same --ckpt-dir contract as the
    single-process path), exported in encoded form, and registered lazily
    on their owning worker. --max-resident-mb applies PER WORKER — the
    point of sharding on a memory-bounded box is that each worker's ~1/K
    shard stays resident instead of one engine LRU-thrashing across all
    scenes. --fleet-replicas R pins the first scene (the designated hot
    scene) on R workers behind one key; the router picks the least-loaded
    replica per request. --deadline, --metrics-port and --metrics-dump
    behave as in the single-process path, with the fleet_* metric
    families layered on top (docs/observability.md).
    """
    import contextlib
    import json
    import os
    import shutil
    import tempfile

    from repro.configs.base import mib_to_bytes
    from repro.configs.rtnerf import NeRFConfig
    from repro.data import rays as rays_lib
    from repro.obs import MetricsRegistry, MetricsServer, snapshot_json
    from repro.serving import FleetRouter, export_scene, prepare_field

    if args.finetune_steps:
        raise SystemExit(
            "--fleet-workers does not combine with --finetune-steps yet: "
            "fleet workers own their engines, so the fine-tune loop would "
            "train a field no worker serves (ROADMAP: fleet fine-tuning)")
    scenes = [s for s in args.scenes.split(",") if s] if args.scenes \
        else [args.scene]
    cfg = NeRFConfig(grid_res=48, occ_res=48, cube_size=4, max_cubes=1024,
                     r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                     max_samples_per_ray=128, train_rays=1024,
                     max_resident_bytes=mib_to_bytes(args.max_resident_mb))

    registry = MetricsRegistry()
    holder = {"router": None}

    def _extra_stats():
        r = holder["router"]
        return r.stats() if r is not None else {"phase": "loading"}

    mserver = None
    if args.metrics_port is not None:
        mserver = MetricsServer(registry, port=args.metrics_port,
                                extra=_extra_stats)
        print(f"[obs] metrics: http://127.0.0.1:{mserver.port}/metrics "
              f"(Prometheus) and /metrics.json (snapshot)", flush=True)

    # Train/restore in the launcher (one jit, reuses --ckpt-dir exactly
    # like the single-process path), then export each scene's encoded
    # streams + cubes once; workers register from these paths, so every
    # replica and every post-crash re-registration serves the identical
    # representation.
    export_root = tempfile.mkdtemp(prefix="repro-fleet-")
    paths = {}
    for name in scenes:
        ckpt = os.path.join(args.ckpt_dir, name) if args.ckpt_dir else None
        field = prepare_field(cfg, name, ckpt_dir=ckpt,
                              train_steps=args.train_steps, n_views=8,
                              image_hw=args.res)
        if args.prune_sparsity > 0.0:
            field = field.prune(sparsity=args.prune_sparsity)
        paths[name] = export_scene(os.path.join(export_root, name),
                                   field, cfg=cfg, scene=name)

    router = FleetRouter(
        cfg, paths, n_workers=args.fleet_workers,
        engine_kwargs=dict(ray_chunk=args.res * args.res,
                           max_batch_views=args.views),
        registry=registry)
    holder["router"] = router
    try:
        for name in scenes:
            print(f"scene '{name}' -> worker {router.owner_of(name)}")
        if args.fleet_replicas > 1:
            hot = scenes[0]
            router.set_replicas(hot, args.fleet_replicas)
            print(f"hot scene '{hot}' replicated on "
                  f"{router.replica_workers(hot)}")

        gt_scenes = {name: rays_lib.make_scene(name) for name in scenes}
        cams = rays_lib.make_cameras(args.views, args.res, args.res)
        gts = {name: [rays_lib.render_gt(gt_scenes[name], cam)
                      for cam in cams] for name in scenes}
        prof = (jax.profiler.trace(args.profile_dir) if args.profile_dir
                else contextlib.nullcontext())
        with prof:
            futures = [(name, router.submit(cam, gt, scene=name,
                                            deadline_s=args.deadline))
                       for name in scenes
                       for cam, gt in zip(cams, gts[name])]
            for i, (name, fut) in enumerate(futures):
                r = fut.result()
                if r.timed_out:
                    print(f"{name} view {i}: TIMED OUT after "
                          f"{r.latency_s:.2f}s")
                    continue
                print(f"{name} view {i}: psnr={r.psnr:.2f} "
                      f"latency={r.latency_s:.2f}s worker={r.worker}"
                      f"{' (replayed)' if r.replayed else ''}")
        if args.profile_dir:
            print(f"[obs] XLA profile written to {args.profile_dir}")

        s = router.stats()
        print(f"fleet: {s['results_total']} results over "
              f"{len(scenes)} scenes / {s['workers_alive']} workers, "
              f"p95={s['latency_p95_s']:.2f}s, "
              f"timeouts={s['timeouts_total']}, "
              f"replays={s['replays_total']}, "
              f"deaths={s['worker_deaths']}, "
              f"routing v{s['routing_version']}")
        for wname, ws in sorted(s["workers"].items()):
            print(f"  {wname}: views={ws.get('views_served', 0)} "
                  f"fps={ws.get('fps', 0.0):.3f} "
                  f"resident={ws.get('resident_scenes', [])} "
                  f"evictions={ws.get('evictions', 0)} "
                  f"revivals={ws.get('revivals', 0)}")
        if args.metrics_dump:
            snap = snapshot_json(registry, extra=s)
            with open(args.metrics_dump, "w") as f:
                json.dump(snap, f, indent=2)
            print(f"[obs] metrics snapshot written to {args.metrics_dump}")
    finally:
        router.close()
        shutil.rmtree(export_root, ignore_errors=True)
        if mserver is not None:
            mserver.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=sorted(ARCHS) + ["rtnerf"])
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--scenes", default=None,
                    help="rtnerf only: comma-separated scene list to serve "
                         "from one process (e.g. lego,chair,mic); overrides "
                         "--scene. Each scene checkpoints under its own "
                         "subdirectory of --ckpt-dir")
    ap.add_argument("--max-resident-mb", type=float, default=None,
                    help="rtnerf only: device-memory budget (MiB) for "
                         "resident encoded fields across scenes; cold "
                         "scenes are LRU-evicted to encoded checkpoints "
                         "and revived on their next request (default: "
                         "unlimited)")
    ap.add_argument("--fleet-workers", type=int, default=0,
                    help="rtnerf only: serve through K worker processes "
                         "sharded by consistent hashing instead of one "
                         "in-process engine (serving.FleetRouter); "
                         "--max-resident-mb then applies per worker "
                         "(0 = single-process path)")
    ap.add_argument("--fleet-replicas", type=int, default=1,
                    help="rtnerf only, with --fleet-workers: replicate the "
                         "first --scenes entry (the hot scene) on this many "
                         "workers behind one key; the router load-balances "
                         "across the replicas")
    ap.add_argument("--views", type=int, default=2)
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--dense", action="store_true",
                    help="rtnerf only: serve the raw factor arrays instead "
                         "of the hybrid bitmap/COO compressed stream "
                         "(Sec. 4.2.2; replaces the removed --field-mode)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="rtnerf only: per-request deadline in seconds; "
                         "stale requests fail with a timeout result "
                         "instead of rendering late")
    ap.add_argument("--finetune-steps", type=int, default=0,
                    help="rtnerf only: run the online fine-tuning service "
                         "for this many background training steps while "
                         "serving (0 = off); refreshed fields are published "
                         "live via swap_field")
    ap.add_argument("--finetune-every", type=int, default=50,
                    help="rtnerf only: publish the refreshed field to the "
                         "running engine every N fine-tune steps")
    ap.add_argument("--finetune-rounds", type=int, default=3,
                    help="rtnerf only: how many passes over the view set "
                         "to stream while the fine-tuner runs")
    ap.add_argument("--prune-sparsity", type=float, default=0.0,
                    help="rtnerf only: magnitude-prune factors to this "
                         "sparsity before serving (0 = training prune only)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="rtnerf only: expose the metrics registry over "
                         "HTTP on 127.0.0.1:<port> (/metrics Prometheus "
                         "text, /metrics.json snapshot); 0 picks an "
                         "ephemeral port (printed at startup)")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="rtnerf only: print a one-line serving summary "
                         "every N seconds while serving (0 = off)")
    ap.add_argument("--metrics-dump", default=None,
                    help="rtnerf only: write the final metrics snapshot "
                         "(JSON, schema repro.obs/v1) to this path on exit "
                         "— the input of scripts/obs_report.py")
    ap.add_argument("--profile-dir", default=None,
                    help="rtnerf only: capture an XLA profiler trace of "
                         "the serving rounds into this directory "
                         "(jax.profiler.trace; named scopes from "
                         "core/pipeline.py tag the pipeline stages)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="rtnerf only: restore trained fields from "
                         "per-scene subdirectories of this root when "
                         "checkpoints exist; otherwise train once and save "
                         "there (repeated serves reuse them instead of "
                         "retraining)")
    args = ap.parse_args()
    if args.fleet_workers and args.arch != "rtnerf":
        ap.error("--fleet-workers requires --arch rtnerf")
    if args.arch == "rtnerf":
        if args.fleet_workers:
            serve_fleet(args)
        else:
            serve_nerf(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
