"""Training launcher: real steps on this host's devices, full feature set.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 100 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

Production use passes --no-reduced and the assigned shapes; this container
exercises the identical code path on reduced configs (CPU). Features:
elastic fault tolerance (--inject-failure), async checkpointing, gradient
compression across the data axis (--grad-compression), LR schedules.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, get_arch, reduced
from repro.data.tokens import TokenStream
from repro.launch.elastic import ElasticRunner
from repro.launch.steps import (abstract_params, batch_sharding,
                                build_train_step, opt_state_sharding)
from repro.models import transformer as tf
from repro.models.common import split_pl
from repro.models.sharding import make_rules, param_sharding
from repro.optim import cosine_schedule, pick_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--inject-failure", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    stream = TokenStream(cfg, shape)
    sched = cosine_schedule(max(args.steps // 20, 1), args.steps)

    def build(mesh):
        rules = make_rules(mesh)
        key = jax.random.PRNGKey(0)
        pl_tree = tf.init_model(cfg, key)
        params, logical = split_pl(pl_tree)
        opt = pick_optimizer(sum(p.size for p in jax.tree.leaves(params)),
                             lr=args.lr, schedule=sched)
        opt_state = opt.init(params)
        p_sh = param_sharding(params, logical, rules)
        s_sds, s_sh = opt_state_sharding(
            opt, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                              params), p_sh, rules)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, s_sh)
        _, b_sh = batch_sharding(cfg, shape, rules)
        fn = build_train_step(cfg, rules, opt)
        jfn = jax.jit(fn, in_shardings=(p_sh, s_sh, b_sh),
                      out_shardings=(p_sh, s_sh, None),
                      donate_argnums=(0, 1))

        def step_fn(state, batch):
            params, opt_state = state
            params, opt_state, metrics = jfn(params, opt_state, batch)
            return (params, opt_state), metrics

        return step_fn, (params, opt_state), (p_sh, s_sh)

    runner = ElasticRunner(build=build, ckpt_dir=args.ckpt_dir,
                           model_axis=1, ckpt_every=args.ckpt_every)
    t0 = time.time()
    state, log = runner.run(args.steps, lambda s: stream.batch(s),
                            inject_failure_at=args.inject_failure)
    dt = time.time() - t0
    losses = [l for l in log if l[0] == "step"]
    print(f"trained {len(losses)} steps in {dt:.1f}s "
          f"({dt / max(len(losses), 1):.3f}s/step)")
    if losses:
        print(f"loss: first={losses[0][2]:.4f} last={losses[-1][2]:.4f}")
    events = [l for l in log if l[0] != "step"]
    for e in events:
        print("event:", e)


if __name__ == "__main__":
    main()
