"""Extract roofline terms from a compiled (AOT) step.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. cost_analysis() numbers from a partitioned executable are
PER-DEVICE; collective bytes are summed over the per-device HLO's collective
ops' operand shapes (as specified in the task brief).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce = f32[16,32]{1,0} all-reduce(%dot), channel_id=1,
#         replica_groups=[4,4]<=[16], use_global_device_ids=true, ...
# The modern printer omits operand shapes, so we read the RESULT shape and
# the replica-group size and derive operand/wire bytes per op semantics.
_OP_RE = re.compile(
    r"=\s+(?P<lhs>\(?[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_BRACE_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _BRACE_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2  # collective-permute etc.


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic from partitioned HLO text.

    For each op: `operand` bytes follow the brief (sum of operand sizes);
    `wire` bytes use a ring model (what actually crosses ICI links per
    device): AG (g-1)/g*R, AR 2(g-1)/g*R, RS (g-1)*R, A2A (g-1)/g*R, CP R,
    where R = result bytes, g = replica-group size.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue                         # counted at -start
        kind = m.group("kind")
        shapes = [_shape_bytes(d, s) for d, s in
                  _SHAPE_RE.findall(m.group("lhs"))]
        if not shapes:
            continue
        res = float(max(shapes))             # tuple results: take payload
        g = _group_size(line)
        if kind == "all-gather":
            op_b, wire_b = res / g, res * (g - 1) / g
        elif kind == "all-reduce":
            op_b, wire_b = res, 2.0 * res * (g - 1) / g
        elif kind == "reduce-scatter":
            op_b, wire_b = res * g, res * (g - 1)
        elif kind == "all-to-all":
            op_b, wire_b = res, res * (g - 1) / g
        else:                                # collective-permute
            op_b, wire_b = res, res
        out[kind] += op_b
        wire[kind] += wire_b
        count[kind] += 1
    rec = dict(out)
    rec.update({f"wire_{k}": wire[k] for k in _COLLECTIVES})
    rec.update({f"n_{k}": count[k] for k in _COLLECTIVES})
    rec["total"] = sum(out[k] for k in _COLLECTIVES)
    rec["wire_total"] = sum(wire[k] for k in _COLLECTIVES)
    return rec


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_s, collective_s),
    }


def model_flops(n_params: int, n_active: int, n_tokens: int,
                kind: str) -> float:
    """6*N*D for training, 2*N*D for single forward (prefill/decode)."""
    n = n_active or n_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * n_tokens
