"""train_step / serve_step builders with full sharding specification.

These are what both the real launcher (train.py/serve.py) and the dry-run
(dryrun.py) lower; the dry-run passes ShapeDtypeStructs, the launcher passes
real arrays — same functions, same shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import tokens as tok_lib
from repro.models import transformer as tf
from repro.models.common import log_parse, split_pl
from repro.models.sharding import (AxisRules, make_rules, param_sharding,
                                   resolve_spec, use_rules)
from repro.optim import clip_by_global_norm, pick_optimizer
from repro.optim.optimizers import Optimizer

GRAD_CLIP = 1.0


# --------------------------------------------------------------------------
# abstract params + shardings
# --------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig, key=None):
    """(ShapeDtypeStruct tree, logical tree) without allocating anything."""
    box = {}

    def f(k):
        params, logical = split_pl(tf.init_model(cfg, k))
        box["logical"] = logical
        return params

    if key is None:
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    sds = jax.eval_shape(f, key)
    return sds, box["logical"]


def count_params(sds) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(sds))


def batch_sharding(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules):
    specs = tok_lib.input_specs(cfg, shape)
    logical = tok_lib.input_logical(cfg, shape)

    def one(s, log):
        axes = log_parse(log)
        spec = resolve_spec(s.shape, axes, rules.act_rules, rules)
        return NamedSharding(rules.mesh, spec)

    return specs, jax.tree.map(one, specs, logical)


def opt_state_sharding(opt: Optimizer, param_sds, param_sh, rules: AxisRules):
    """Shardings for the optimizer state (m/v mirror params; adafactor's
    factored stats drop the relevant param axis)."""
    state_sds = jax.eval_shape(opt.init, param_sds)
    repl = NamedSharding(rules.mesh, P())

    if opt.name == "adamw":
        return state_sds, {"step": repl, "m": param_sh, "v": param_sh}
    if opt.name == "adafactor":
        def one(v_dict, sh):
            spec = sh.spec
            out = {}
            for k in v_dict:
                if k == "vr":
                    out[k] = NamedSharding(rules.mesh, P(*spec[:-1]))
                elif k == "vc":
                    out[k] = NamedSharding(rules.mesh,
                                           P(*(spec[:-2] + spec[-1:])))
                else:
                    out[k] = NamedSharding(rules.mesh, P(*spec))
            return out
        is_vd = lambda x: isinstance(x, dict) and set(x) <= {"vr", "vc", "v"}
        v_sh = jax.tree.map(one, state_sds["v"], param_sh, is_leaf=is_vd)
        return state_sds, {"step": repl, "v": v_sh}
    return state_sds, jax.tree.map(lambda _: repl, state_sds)


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, rules: AxisRules, opt: Optimizer,
                     param_sh=None):
    """param_sh: param-sharding tree; with cfg.constrain_grads it pins each
    grad to its param's sharding, so the partitioner emits reduce-scatter-
    shaped communication instead of full all-reduce + slice (§Perf H1)."""

    def grad_fn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: tf.model_loss(p, cfg, batch), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            if cfg.grad_accum > 1:
                m = cfg.grad_accum
                micro = jax.tree.map(
                    lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                    batch)

                def mb(carry, b):
                    g_acc, loss_acc = carry
                    loss, _, grads = grad_fn(params, b)
                    if cfg.constrain_grads and param_sh is not None:
                        grads = jax.tree.map(
                            jax.lax.with_sharding_constraint, grads, param_sh)
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                    return (g_acc, loss_acc + loss), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                                  params)
                if cfg.constrain_grads and param_sh is not None:
                    g0 = jax.tree.map(jax.lax.with_sharding_constraint,
                                      g0, param_sh)
                (grads, loss_sum), _ = jax.lax.scan(
                    mb, (g0, jnp.float32(0)), micro)
                grads = jax.tree.map(lambda g: g / m, grads)
                metrics = {"loss": loss_sum / m}
            else:
                loss, metrics, grads = grad_fn(params, batch)
                if cfg.constrain_grads and param_sh is not None:
                    grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                         grads, param_sh)
                metrics = dict(metrics)
            grads, gn = clip_by_global_norm(grads, GRAD_CLIP)
            new_params, new_state = opt.update(grads, opt_state, params)
        metrics["grad_norm"] = gn
        return new_params, new_state, metrics
    return train_step


def build_prefill_step(cfg: ModelConfig, rules: AxisRules):
    def prefill_step(params, batch):
        with use_rules(rules):
            logits, cache = tf.model_prefill(params, cfg, batch)
        return logits, cache
    return prefill_step


def build_decode_step(cfg: ModelConfig, rules: AxisRules, seq_len: int):
    def decode_step(params, token, pos, cache):
        with use_rules(rules):
            logits, new_cache = tf.model_decode(params, cfg, token, pos,
                                                cache, seq_len=seq_len)
        return logits, new_cache
    return decode_step


def cache_sharding(cfg: ModelConfig, batch: int, seq_len: int,
                   rules: AxisRules):
    shapes, logical = tf.serve_cache_spec(cfg, batch, seq_len)

    def one(s, log):
        axes = log_parse(log)
        spec = resolve_spec(s.shape, axes, rules.act_rules, rules)
        return NamedSharding(rules.mesh, spec)

    # None entries are empty pytree nodes — skipped by tree.map and treated
    # as empty subtrees by jit's in_shardings, so no special handling.
    sh = jax.tree.map(one, shapes, logical)
    return shapes, sh


# --------------------------------------------------------------------------
# the full lowering bundle for one (arch, shape, mesh) cell
# --------------------------------------------------------------------------


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               donate: bool = True):
    """Build + lower the right step for this cell. Returns (lowered, info)."""
    rules = make_rules(mesh)
    param_sds, logical = abstract_params(cfg)
    param_sh = param_sharding(param_sds, logical, rules)
    n_params = count_params(param_sds)
    repl = NamedSharding(mesh, P())
    info: Dict[str, Any] = {"n_params": n_params,
                            "n_active": cfg.active_param_count()}

    if shape.kind == "train":
        opt = pick_optimizer(n_params)
        info["optimizer"] = opt.name
        state_sds, state_sh = opt_state_sharding(opt, param_sds, param_sh,
                                                 rules)
        batch_sds, batch_sh = batch_sharding(cfg, shape, rules)
        fn = build_train_step(cfg, rules, opt, param_sh=param_sh)
        jfn = jax.jit(fn,
                      in_shardings=(param_sh, state_sh, batch_sh),
                      out_shardings=(param_sh, state_sh, None),
                      donate_argnums=(0, 1) if donate else ())
        lowered = jfn.lower(param_sds, state_sds, batch_sds)
        return lowered, info

    if shape.kind == "prefill":
        batch_sds, batch_sh = batch_sharding(cfg, shape, rules)
        fn = build_prefill_step(cfg, rules)
        jfn = jax.jit(fn, in_shardings=(param_sh, batch_sh))
        lowered = jfn.lower(param_sds, batch_sds)
        return lowered, info

    # decode: one token against a seq_len cache
    b = shape.global_batch
    cache_sds, cache_sh = cache_sharding(cfg, b, shape.seq_len, rules)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, resolve_spec((b, 1), ("batch", None),
                                              rules.act_rules, rules))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    fn = build_decode_step(cfg, rules, shape.seq_len)
    jfn = jax.jit(fn,
                  in_shardings=(param_sh, tok_sh, repl, cache_sh),
                  out_shardings=(None, cache_sh),
                  donate_argnums=(3,) if donate else ())
    lowered = jfn.lower(param_sds, tok_sds, pos_sds, cache_sds)
    return lowered, info
