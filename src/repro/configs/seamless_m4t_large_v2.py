"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf] — encoder-decoder, multimodal.

Audio frontend is a STUB per spec: ``input_specs()`` provides precomputed
frame embeddings feeding the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                # decoder layers
    n_enc_layers=24,            # encoder layers
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    enc_memory_len=4096,
    frontend="audio",
    act="gelu",
)
