"""Config dataclasses shared by every architecture and the launcher.

Every assigned architecture is a `ModelConfig`; input shapes are
`ShapeConfig`s. Both are frozen so they can be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def mib_to_bytes(mb: Optional[float]) -> Optional[int]:
    """CLI-facing memory budgets (`--max-resident-mb` style knobs) -> byte
    counts for config fields like `NeRFConfig.max_resident_bytes`.
    None/0/negative mean "unlimited" and map to None."""
    if not mb or mb <= 0:
        return None
    return int(mb * 1024 * 1024)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- attention ---
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    attention: str = "gqa"          # gqa | mla | none
    rope_theta: float = 1e4

    # --- MLA (DeepSeek-V3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0         # leading dense layers (DeepSeek-V3: 3)
    moe_dispatch: str = "auto"      # bitmap | coo | auto (paper's 80% rule)
    capacity_factor: float = 1.25

    # --- encoder-decoder ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_memory_len: int = 4096      # encoder memory length for decode shapes

    # --- modality frontend (stub: precomputed embeddings via input_specs) ---
    frontend: Optional[str] = None  # vision | audio
    n_frontend_tokens: int = 0

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0             # zamba2: shared attn block every k blocks

    # --- misc architecture ---
    mtp: bool = False               # DeepSeek multi-token-prediction head
    act: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # --- runtime knobs (defaults = paper-faithful baseline; hillclimb flips) ---
    dtype: str = "bfloat16"
    remat_policy: str = "full"      # none | full | dots
    attention_impl: str = "naive"   # naive | chunked
    seq_shard_attn: bool = False    # sequence-parallel attention (qwen1.5)
    window: int = 0                 # sliding window for hybrid long-context
    scan_layers: bool = True        # lax.scan over stacked layer params
    # --- §Perf hillclimb knobs (EXPERIMENTS.md) ---
    ssm_impl: str = "scan"          # scan | chunked (chunk-parallel SSD)
    grad_accum: int = 1             # microbatch accumulation (activation mem)
    constrain_grads: bool = False   # force reduce-scatter-shaped grad comm
    moe_out_shard: bool = False     # constrain MoE combine output sharding

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        # pad so the vocab axis shards evenly over a 16-way model axis
        return round_up(self.vocab, 16)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def dispatch_sparsity(self) -> float:
        """Sparsity of the token->expert assignment matrix (paper Fig.5 analogue)."""
        if not self.is_moe:
            return 0.0
        return 1.0 - self.top_k / self.n_experts

    def resolved_dispatch(self) -> str:
        """RT-NeRF hybrid-encoding rule (80% threshold) applied to MoE routing."""
        if self.moe_dispatch != "auto":
            return self.moe_dispatch
        return "coo" if self.dispatch_sparsity >= 0.80 else "bitmap"

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count N (analytic; matches init shapes)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        V = self.vocab_padded
        total = V * d                               # embedding
        if not self.tie_embeddings:
            total += V * d                          # lm head
        n_layers = self.n_layers
        enc_layers = self.n_enc_layers if self.enc_dec else 0

        def attn_params() -> int:
            if self.attention == "mla":
                p = d * self.q_lora_rank
                p += self.q_lora_rank * n_q * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * n_q * (self.qk_nope_head_dim + self.v_head_dim)
                p += n_q * self.v_head_dim * d
                p += self.q_lora_rank + self.kv_lora_rank   # lora norms
                return p
            p = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            if self.qkv_bias:
                p += (n_q + 2 * n_kv) * hd
            return p

        def dense_ff_params(dff: int) -> int:
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            return mult * d * dff

        def moe_ff_params() -> int:
            dff = self.d_ff_expert or self.d_ff
            per_exp = dense_ff_params(dff)
            p = self.n_experts * per_exp + d * self.n_experts   # router
            p += self.n_shared_experts * per_exp
            return p

        def mamba_params() -> int:
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            p = d * (2 * d_in + 2 * self.ssm_state + nh)        # in_proj(x,z) + B,C + dt
            p += self.ssm_conv * (d_in + 2 * self.ssm_state)    # conv over x,B,C
            p += nh + nh                                        # A_log, D
            p += d_in * d                                       # out_proj
            p += d_in                                           # gated norm
            return p

        def rwkv_params() -> int:
            # time-mix: r,k,v,g,w projections + lora for data-dependent decay + out
            p = 6 * d * d + 2 * d * 64 + 5 * d  # approx lora rank 64, token-shift mixes
            p += 2 * d * self.d_ff + d * self.d_ff  # channel mix (r,k,v)
            return p

        if self.family == "ssm":     # rwkv6
            total += n_layers * (rwkv_params() + 2 * d)
            return total
        if self.family == "hybrid":  # zamba2: n_layers mamba blocks + 1 shared attn block
            total += n_layers * (mamba_params() + d)
            n_shared = 1
            total += n_shared * (attn_params() + dense_ff_params(self.d_ff) + 2 * d)
            return total

        per_layer_attn = attn_params() + 2 * d      # + norms
        for li in range(n_layers + enc_layers):
            total += per_layer_attn
            is_dec_moe = self.is_moe and (li >= enc_layers) and \
                ((li - enc_layers) >= self.n_dense_layers)
            if is_dec_moe:
                total += moe_ff_params()
            else:
                total += dense_ff_params(self.d_ff)
            if self.enc_dec and li >= enc_layers:
                total += attn_params() + d          # cross-attention
        if self.mtp:
            total += attn_params() + dense_ff_params(self.d_ff) + 4 * d + 2 * d * d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top_k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        dff = self.d_ff_expert or self.d_ff
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        per_exp = mult * self.d_model * dff
        n_moe_layers = self.n_layers - self.n_dense_layers
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_exp
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The four assigned LM shapes (see prompt block; identical for all 10 archs).
LM_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def long_context_ok(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM / hybrid)."""
    return cfg.family in ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig):
    """The (shape, skip_reason) list for one arch — 4 cells each."""
    out = []
    for s in LM_SHAPES.values():
        skip = None
        if s.name == "long_500k" and not long_context_ok(cfg):
            skip = "full-attention arch: 500k KV cache is quadratic-regime; skipped per spec"
        out.append((s, skip))
    return out
