from repro.configs.base import (  # noqa: F401
    LM_SHAPES,
    ModelConfig,
    ShapeConfig,
    long_context_ok,
    round_up,
    shapes_for,
)
