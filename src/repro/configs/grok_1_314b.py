"""Grok-1 314B [hf:xai-org/grok-1; unverified] — 8 experts top-2 MoE."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    top_k=2,
    d_ff_expert=32768,
    n_dense_layers=0,
    act="geglu",            # gated GELU MLP (mult-3 param shape)
    # beyond-paper: the 80%-threshold "auto" rule puts grok (75% sparse) in
    # bitmap/dense-masked mode, which costs E/k=4x compute on TPU; measured
    # in EXPERIMENTS.md §Perf cell C -> sort/gather dispatch is the default.
    moe_dispatch="coo",
)
