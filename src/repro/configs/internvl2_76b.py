"""InternVL2-76B [arXiv:2404.16821; unverified] — InternViT frontend + LLM backbone.

The vision frontend is a STUB per spec: ``input_specs()`` provides precomputed
patch embeddings (n_frontend_tokens, d_model) prepended to the token stream.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    frontend="vision",
    n_frontend_tokens=256,      # patch embeddings per image (pixel-unshuffled)
    rope_theta=5e5,
)
