"""--arch <id> registry. IDs use the public names verbatim."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, LM_SHAPES, ShapeConfig, shapes_for
from repro.configs import (
    deepseek_v3_671b,
    grok_1_314b,
    internvl2_76b,
    seamless_m4t_large_v2,
    granite_3_8b,
    qwen1_5_32b,
    llama3_2_1b,
    granite_34b,
    zamba2_7b,
    rwkv6_1_6b,
    rtnerf,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_v3_671b,
        grok_1_314b,
        internvl2_76b,
        seamless_m4t_large_v2,
        granite_3_8b,
        qwen1_5_32b,
        llama3_2_1b,
        granite_34b,
        zamba2_7b,
        rwkv6_1_6b,
    )
}

NERF = rtnerf.CONFIG


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)} + ['rtnerf']")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    return LM_SHAPES[name]


def all_cells():
    """All 40 (arch, shape, skip_reason) dry-run cells, in registry order."""
    cells = []
    for cfg in ARCHS.values():
        for shape, skip in shapes_for(cfg):
            cells.append((cfg, shape, skip))
    return cells


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (one fwd/train step)."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=128,
        vocab=256,
        head_dim=16 if cfg.head_dim else 0,
    )
    if cfg.attention == "mla":
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16)
    if cfg.is_moe:
        kw.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                  d_ff_expert=64, n_dense_layers=min(cfg.n_dense_layers, 1))
    if cfg.enc_dec:
        kw.update(n_enc_layers=2, enc_memory_len=32)
    if cfg.frontend:
        kw.update(n_frontend_tokens=8)
    if cfg.family in ("hybrid", "ssm"):
        kw.update(ssm_state=16, ssm_head_dim=16)
        if cfg.attn_every:
            kw.update(attn_every=2, n_layers=5)
    return dataclasses.replace(cfg, **kw)
