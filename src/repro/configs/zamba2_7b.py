"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 trunk + shared attention blocks.

81 Mamba2 blocks; ONE weight-shared attention+MLP block is applied every 6
Mamba blocks (per-invocation LoRA of the original is omitted; DESIGN.md §5).
For long_500k the shared attention uses a 4096-token sliding window so the
arch stays sub-quadratic (documented deviation).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,                # mamba2 blocks
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    window=4096,
)
