"""Granite-3 8B [hf:ibm-granite/granite-3.0-2b-base; hf] — GQA dense."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,                # padded to 49168 for the 16-way model axis
    rope_theta=1e4,
)
