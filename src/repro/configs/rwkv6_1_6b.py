"""RWKV-6 (Finch) 1.6B [arXiv:2404.05892; unverified] — attn-free, data-dependent decay."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                 # wkv heads of size 64
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    head_dim=64,
    attention="none",
)
