"""DeepSeek-V3 671B [arXiv:2412.19437; hf] — MLA, 1 shared + 256 routed top-8, MTP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                 # dense d_ff for the 3 leading dense layers
    vocab=129280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    d_ff_expert=2048,           # per-expert d_ff (spec: d_ff=2048, MoE 256e top-8)
    n_dense_layers=3,
    mtp=True,
    rope_theta=1e4,
)
