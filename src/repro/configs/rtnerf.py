"""RT-NeRF's own workload config (the paper's contribution).

A TensoRF VM-decomposed radiance field + the RT-NeRF efficient rendering
pipeline. Shapes mirror the paper's evaluation: 800x800 novel-view rendering
on Synthetic-NeRF-like scenes, plus the ray-batch training shape.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class NeRFConfig:
    name: str = "rtnerf"
    family: str = "nerf"
    # --- TensoRF VM decomposition ---
    grid_res: int = 160              # embedding-grid resolution per axis
    r_sigma: int = 16                # density components R (Eq. 2)
    r_color: int = 48                # appearance components
    app_dim: int = 27                # appearance feature dim fed to the MLP
    mlp_hidden: int = 128            # view-dependent color MLP
    pe_view: int = 2                 # positional-encoding bands for direction
    pe_feat: int = 2                 # positional-encoding bands for features
    # --- occupancy / RT-NeRF pipeline ---
    occ_res: int = 160               # binary occupancy grid resolution
    cube_size: int = 4               # voxels per occupancy cube (A1 unit)
    max_cubes: int = 8192            # static bound on non-zero cubes
    step_size: float = 0.5           # march step in voxel units
    max_samples_per_ray: int = 512   # static bound (uniform baseline N)
    occ_sigma_thresh: float = 0.5    # sigma cutoff for occupancy rebuilds
                                     # after pruning / before serving (thin
                                     # scenes like mic need a low cutoff)
    term_eps: float = 1e-4           # early-ray-termination threshold on T
    near: float = 2.0
    far: float = 6.0
    scene_bound: float = 1.5         # AABB half-extent
    # --- rendering / training ---
    image_hw: int = 800
    train_rays: int = 4096           # rays per training batch
    sigma_sparsity_l1: float = 5e-5  # L1 that induces the factor sparsity H1 exploits
    tv_weight: float = 1e-3
    lr_grid: float = 2e-2
    lr_mlp: float = 1e-3
    # --- sparse encoding (H1) ---
    sparse_threshold: float = 0.80   # bitmap (<) vs COO (>=) switch
    dtype: str = "float32"
    # --- multi-scene serving (SceneStore) ---
    max_resident_bytes: Optional[int] = None
                                     # device-memory budget for resident
                                     # encoded factor streams across ALL
                                     # scenes in a serving.SceneStore; cold
                                     # scenes are LRU-evicted to encoded
                                     # checkpoints and revived on demand.
                                     # None/0 = unlimited (single-scene
                                     # behaviour). CLI: --max-resident-mb
                                     # via configs.base.mib_to_bytes.

    @property
    def cube_grid_res(self) -> int:
        return self.occ_res // self.cube_size

    def cube_world(self) -> float:
        return 2.0 * self.scene_bound * self.cube_size / self.occ_res

    def cube_ball_radius(self) -> float:
        """Step 2-1-a: bounding-ball radius of one occupancy cube."""
        return self.cube_world() * (3.0 ** 0.5) / 2.0

    def param_count(self) -> int:
        g, rs, rc = self.grid_res, self.r_sigma, self.r_color
        planes = 3 * (rs + rc) * g * g
        lines = 3 * (rs + rc) * g
        basis = 3 * rc * self.app_dim
        in_mlp = self.app_dim + 3 + 2 * 3 * self.pe_view + 2 * self.app_dim * self.pe_feat
        mlp = in_mlp * self.mlp_hidden + self.mlp_hidden * self.mlp_hidden + self.mlp_hidden * 3
        return planes + lines + basis + mlp


CONFIG = NeRFConfig()


def demo_config(tiny: bool = False) -> NeRFConfig:
    """The shared example/benchmark field shapes — ONE definition of the
    "tiny CI smoke" and "full demo" configs, so examples/ and benchmarks/
    exercising the same workload can't drift apart silently."""
    if tiny:
        return NeRFConfig(grid_res=24, occ_res=24, cube_size=4,
                          max_cubes=256, r_sigma=4, r_color=8, app_dim=8,
                          mlp_hidden=16, max_samples_per_ray=64,
                          train_rays=256)
    return NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                      r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                      max_samples_per_ray=112, train_rays=1024)


@dataclasses.dataclass(frozen=True)
class NeRFShape:
    name: str
    n_rays: int                      # rays per step (render: H*W, train: batch)
    kind: str                        # train | render


NERF_SHAPES = {
    "train_rays":  NeRFShape("train_rays", 4096, "train"),
    "render_800":  NeRFShape("render_800", 800 * 800, "render"),
}
