"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B; hf] — QKV bias, 40 heads (MHA).

40 heads % 16-way model axis != 0 -> this arch uses sequence-parallel
attention sharding instead of head sharding (DESIGN.md §7).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    seq_shard_attn=True,
    rope_theta=1e6,
)
