"""Fault-tolerant checkpointing (no orbax in this container).

API: `save_checkpoint`/`restore_checkpoint` round-trip any pytree through
`step_XXXX/` directories (restore needs a `like` template);
`save_state_dict`/`restore_state_dict` round-trip flat {name: array} dicts
with the key order in the manifest (no template needed);
`save_field`/`restore_field` checkpoint a `core.field.FieldBackend` in its
*current* representation — an encoded field's bitmap/COO streams are
written and rebuilt bit-for-bit, never decompressed (ROADMAP "compressed
training": what the trainer holds is what the checkpoint stores and the
serving engine restores). `CheckpointManager` adds async save + retention
for the elastic training loop.

Guarantees used by launch/elastic.py:
  * atomicity     — write to `step_XXXX.tmp/`, fsync, rename; a crash never
                    leaves a readable-but-partial checkpoint.
  * asynchrony    — `save_async` snapshots device arrays to host then writes
                    on a daemon thread; training continues.
  * shard safety  — every leaf stores its *global* array (fully replicated
                    read), so a restore can re-shard onto ANY mesh — this is
                    what makes elastic restarts on a smaller survivor mesh
                    possible. On multi-host deployments each host writes its
                    addressable shards (`process_index` suffix); this
                    container is single-process so the general path is
                    exercised with process_count=1.
  * retention     — keep the last `keep` checkpoints.
  * integrity     — a manifest (treedef + shapes + dtypes + per-leaf crc32)
                    validated on restore.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: Any, *, keep: int = 3,
                    extra_meta: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    flat, treedef = _leaf_paths(tree)
    tmp = os.path.join(directory, f"step_{step:08d}.tmp")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "treedef": str(treedef), "leaves": [],
                "extra": extra_meta or {}}
    for i, leaf in enumerate(flat):
        arr = np.asarray(leaf)
        fn = os.path.join(tmp, f"leaf_{i:05d}.npy")
        np.save(fn, arr)
        manifest["leaves"].append({
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _apply_retention(directory, keep)
    return final


def _apply_retention(directory: str, keep: int):
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = latest_steps(directory)
    return steps[-1] if steps else None


def read_manifest(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def _load_leaf(path: str, i: int, meta: dict) -> np.ndarray:
    arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
    crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
    if crc != meta["crc"]:
        raise IOError(f"checkpoint corruption in leaf {i} of {path}")
    return arr.astype(np.dtype(meta["dtype"]))


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       shardings: Any = None) -> Any:
    """Restore into the structure of `like`; optionally re-shard each leaf
    with `shardings` (a matching tree of NamedSharding) — the elastic path."""
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = read_manifest(directory, step)
    flat_like, treedef = jax.tree.flatten(like)
    assert len(flat_like) == len(manifest["leaves"]), \
        f"leaf count mismatch: {len(flat_like)} vs {len(manifest['leaves'])}"
    shard_flat = (jax.tree.flatten(shardings)[0] if shardings is not None
                  else [None] * len(flat_like))
    out = []
    for i, (meta, ref_leaf, shard) in enumerate(
            zip(manifest["leaves"], flat_like, shard_flat)):
        arr = _load_leaf(path, i, meta)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out)


# --------------------------------------------------------------------------
# Flat state dicts + encoded radiance fields
# --------------------------------------------------------------------------
#
# A CompressedField's pytree structure is data-dependent (per-factor format
# and nnz), so "restore into the shape of `like`" cannot know the treedef up
# front. State-dict checkpoints record the key order in the manifest and the
# codec structure in `extra["field_spec"]` (core/field.field_state), letting
# a restore rebuild the exact encoded representation — the field round-trips
# without ever being decompressed.


def save_state_dict(directory: str, step: int, state: dict, *,
                    keep: int = 3, extra_meta: Optional[dict] = None):
    """Save a flat {name: array} dict; names are recorded in the manifest so
    the restore needs no `like` template."""
    meta = dict(extra_meta or {})
    meta["state_keys"] = sorted(state)
    return save_checkpoint(directory, step, dict(state), keep=keep,
                           extra_meta=meta)


def restore_state_dict(directory: str, step: int):
    """-> ({name: np.ndarray}, extra_meta). Inverse of save_state_dict."""
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = read_manifest(directory, step)
    keys = manifest.get("extra", {}).get("state_keys")
    if keys is None:
        raise ValueError(f"checkpoint at {path} is not a state-dict "
                         f"checkpoint (no state_keys in manifest)")
    assert len(keys) == len(manifest["leaves"]), "manifest key/leaf mismatch"
    # dict pytrees flatten in sorted-key order, so leaf i <-> sorted key i
    arrays = {k: _load_leaf(path, i, meta)
              for i, (k, meta) in enumerate(zip(keys, manifest["leaves"]))}
    return arrays, manifest["extra"]


def save_field(directory: str, step: int, field, *, keep: int = 3,
               extra_meta: Optional[dict] = None):
    """Checkpoint a FieldBackend in its *current* representation — an
    encoded field's bitmap/COO streams are written as-is (no decompress)."""
    from repro.core import field as field_lib

    spec, arrays = field_lib.field_state(field)
    meta = dict(extra_meta or {})
    meta["field_spec"] = spec
    return save_state_dict(directory, step, arrays, keep=keep,
                           extra_meta=meta)


def restore_field(directory: str, step: int, cfg):
    """-> (FieldBackend, extra_meta). Rebuilds the exact representation
    `save_field` wrote (formats, nnz, packed bytes all identical)."""
    from repro.core import field as field_lib

    arrays, extra = restore_state_dict(directory, step)
    spec = extra.get("field_spec")
    if spec is None:
        raise ValueError(f"checkpoint at {directory} step {step} has no "
                         f"field_spec — not a field checkpoint")
    return field_lib.field_from_state(spec, arrays, cfg), extra


SPILL_STEP = 0


def spill_field(directory: str, field, *, extra_meta: Optional[dict] = None):
    """Demote a resident field to disk (the serving SceneStore's eviction
    path): one `save_field` checkpoint at a fixed step with keep=1, so a
    scene's spill directory always holds exactly its latest encoded streams
    — bit-for-bit what `unspill_field` revives."""
    return save_field(directory, SPILL_STEP, field, keep=1,
                      extra_meta=extra_meta)


def unspill_field(directory: str, cfg):
    """-> (FieldBackend, extra_meta). Inverse of `spill_field`: rebuild the
    exact representation that was evicted (formats, nnz, packed bytes all
    identical), so a revived scene renders bit-identically."""
    return restore_field(directory, SPILL_STEP, cfg)


class CheckpointManager:
    """Async save + restore-latest + retention. Thread-safe single writer."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any):
        self.wait()                             # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                keep=self.keep)
            except BaseException as e:          # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like: Any, shardings: Any = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, step, like,
                                        shardings=shardings)
