from repro.ckpt.checkpoint import (  # noqa: F401
    CheckpointManager, save_checkpoint, restore_checkpoint, latest_step,
    save_state_dict, restore_state_dict, save_field, restore_field)
