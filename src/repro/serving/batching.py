"""Micro-batching of per-view ray batches into fixed-size chunks.

API: `plan_microbatches(ray_batches, chunk) -> MicroBatchPlan` packs the
queued views' (rays_o, rays_d) into (n_chunks, chunk, 3) arrays;
`MicroBatchPlan.scatter(outs)` inverts the packing, handing each view back
its contiguous pixel block (pad outputs dropped).

This is the compile-once half of the serving engine's amortisation story
(ROADMAP "streaming / multi-view compressed serving"; the paper's
sustained AR/VR scenario): the engine renders through ONE jitted step
whose ray shape is a static `chunk`; queued views of any resolution are
concatenated, padded to a chunk multiple, and cut into (n_chunks, chunk) —
so compilation cost is paid once per engine, never per view, per
resolution mix, or per `swap_field` refresh.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, Iterable, List, Sequence, Tuple

import numpy as np


def group_requests(items: Iterable, key: Callable) -> Dict[tuple, List]:
    """Stable grouping in first-seen order: the serving engine's flush path
    buckets queued requests by `(scene, ordering-key)` with this, so every
    bucket renders as one micro-batched group against one per-scene
    snapshot while submission order is preserved within and across
    buckets (first scene submitted flushes first)."""
    groups: Dict[tuple, List] = collections.OrderedDict()
    for it in items:
        groups.setdefault(key(it), []).append(it)
    return groups


@dataclasses.dataclass(frozen=True)
class ViewSlice:
    """Where one view's rays live in the packed stream."""
    view_id: int
    start: int
    stop: int


@dataclasses.dataclass(frozen=True)
class MicroBatchPlan:
    """Packed ray stream + the bookkeeping to unpack per-view results."""
    rays_o: np.ndarray          # (n_chunks, chunk, 3)
    rays_d: np.ndarray          # (n_chunks, chunk, 3)
    slices: Tuple[ViewSlice, ...]
    total: int                  # true ray count before padding
    chunk: int

    @property
    def n_chunks(self) -> int:
        return self.rays_o.shape[0]

    def scatter(self, outs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Chunk outputs (each (chunk, C)) -> per-view arrays, pad dropped."""
        flat = np.concatenate([np.asarray(o) for o in outs])[: self.total]
        return [flat[s.start: s.stop] for s in self.slices]


def plan_microbatches(ray_batches: Sequence[Tuple[np.ndarray, np.ndarray]],
                      chunk: int) -> MicroBatchPlan:
    """Pack per-view (rays_o, rays_d) batches into fixed-size chunks.

    Padding rays originate far outside every scene bound with a unit
    direction, so they intersect no cube — they never register geometric
    hits or compete with real rays for the renderer's per-step pair budget.
    Their outputs are dropped by `scatter`.
    """
    if not ray_batches:
        raise ValueError("plan_microbatches needs at least one view")
    slices, pos = [], 0
    for vid, (ro, _) in enumerate(ray_batches):
        n = int(np.asarray(ro).shape[0])
        slices.append(ViewSlice(vid, pos, pos + n))
        pos += n
    total = pos
    pad = (-total) % chunk
    ro = np.concatenate([np.asarray(o, np.float32) for o, _ in ray_batches])
    rd = np.concatenate([np.asarray(d, np.float32) for _, d in ray_batches])
    if pad:
        ro = np.concatenate([ro, np.full((pad, 3), 1e6, np.float32)])
        pad_d = np.zeros((pad, 3), np.float32)
        pad_d[:, 2] = 1.0                    # unit dir, points away
        rd = np.concatenate([rd, pad_d])
    n_chunks = ro.shape[0] // chunk
    return MicroBatchPlan(ro.reshape(n_chunks, chunk, 3),
                          rd.reshape(n_chunks, chunk, 3),
                          tuple(slices), total, chunk)
