from repro.serving.engine import (  # noqa: F401
    RenderEngine, ViewFuture, ViewResult, prepare_field)
from repro.serving.batching import (  # noqa: F401
    MicroBatchPlan, ViewSlice, group_requests, plan_microbatches)
from repro.serving.store import (  # noqa: F401
    SceneRecord, SceneSnapshot, SceneStore)
from repro.serving.finetune import FineTuneLoop  # noqa: F401
from repro.serving.fleet import (  # noqa: F401
    export_scene, load_scene)
from repro.serving.router import (  # noqa: F401
    FleetError, FleetFuture, FleetResult, FleetRouter, HashRing)
