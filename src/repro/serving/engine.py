"""Streaming multi-view serving engine over a resident compressed field.

The RT-NeRF serving story (ROADMAP: "streaming / multi-view compressed
serving"): load — or train once and checkpoint — a scene, encode the TensoRF
factors into ONE resident `sparse.CompressedField`, and serve a stream of
novel-view requests from it. Costs the per-view loop pays on every request
are paid once per engine instead:

  * encode        — the hybrid bitmap/COO encoding is built at engine
                    construction and stays resident,
  * compilation   — one jitted ray-render step (`pipeline.make_ray_renderer`)
                    at a fixed chunk shape; queued views are micro-batched
                    into those chunks (`serving.batching`) so new cameras and
                    mixed resolutions never retrace,
  * ordering      — per-view `order_cubes` schedules are cached by octant
                    ranking (`pipeline.OrderingCache`, the paper's coarse
                    view-dependent ordering) and reused bit-exactly across
                    requests that rank the octants alike,
  * placement     — the encoded streams are replicated and ray chunks
                    sharded across the mesh (`core.distributed.place_field`
                    / `shard_rays`), with a single-device fallback.

API: `submit(cam) -> ViewFuture` queues a request; `flush()` renders the
queue; `stats()` reports FPS, latency percentiles, occupancy accesses,
factor bytes, and ordering-cache hit rates. `benchmarks/serving_throughput.py`
measures this engine against the sequential per-view loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs.rtnerf import NeRFConfig
from repro.core import distributed, occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, sparse, tensorf
from repro.core.occupancy import CubeSet
from repro.core.rendering import Camera
from repro.models.sharding import make_rules
from repro.serving.batching import plan_microbatches


@dataclasses.dataclass
class ViewResult:
    view_id: int
    img: np.ndarray                 # (H*W, 3)
    psnr: Optional[float]           # vs the submitted gt, if any
    latency_s: float                # submit -> resolve (queueing + render)
    stats: Dict[str, float]


class ViewFuture:
    """Handle for one queued view; `result()` flushes the engine if needed."""

    def __init__(self, engine: "RenderEngine", view_id: int):
        self._engine = engine
        self._view_id = view_id
        self._result: Optional[ViewResult] = None

    def done(self) -> bool:
        return self._result is not None

    def result(self) -> ViewResult:
        if self._result is None:
            self._engine.flush()
        assert self._result is not None, "flush did not resolve this future"
        return self._result

    def _set(self, res: ViewResult):
        self._result = res


@dataclasses.dataclass
class _Request:
    cam: Camera
    gt: Optional[np.ndarray]
    future: ViewFuture
    t_submit: float


FIELD_META = "field_meta.json"


def prepare_field(cfg: NeRFConfig, scene: str, *, ckpt_dir: Optional[str],
                  train_steps: int = 200, n_views: int = 8,
                  image_hw: int = 64, seed: int = 0, verbose: bool = True):
    """Load the trained TensoRF params from `ckpt_dir`, or train once and
    checkpoint there (ckpt/checkpoint.py). The *pre-prune* params are
    stored, so one checkpoint serves every prune level. A restore validates
    the checkpoint against the requested scene and cfg shapes (a mismatch
    would otherwise render silently wrong images). Returns params."""
    import json
    import os

    import jax

    from repro.core import train as nerf_train

    if ckpt_dir:
        step = ckpt_lib.latest_step(ckpt_dir)
        if step is not None:
            meta_path = os.path.join(ckpt_dir, FIELD_META)
            if not os.path.exists(meta_path):
                raise ValueError(
                    f"checkpoint at {ckpt_dir} has no {FIELD_META} — can't "
                    f"verify which scene it holds; delete the directory to "
                    f"retrain or restore the meta file")
            with open(meta_path) as f:
                meta = json.load(f)
            if meta.get("scene") != scene:
                raise ValueError(
                    f"checkpoint at {ckpt_dir} holds scene "
                    f"'{meta.get('scene')}', not '{scene}' — use a "
                    f"different --ckpt-dir per scene")
            like = jax.eval_shape(
                lambda k: tensorf.init_field(cfg, k),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            if verbose:
                # recorded steps/seed are reuse-by-design (one checkpoint,
                # many serves) but must be visible, not silent
                print(f"[engine] restoring scene '{scene}' from {ckpt_dir} "
                      f"(trained {meta.get('steps')} steps, "
                      f"seed {meta.get('seed')})")
            params = ckpt_lib.restore_checkpoint(ckpt_dir, step, like)
            # every NeRFConfig yields the same 11 leaves, so the restore's
            # leaf-count check cannot catch a cfg mismatch — compare shapes
            bad = [f"{k}: ckpt {tuple(params[k].shape)} != "
                   f"cfg {tuple(like[k].shape)}"
                   for k in like
                   if tuple(params[k].shape) != tuple(like[k].shape)]
            if bad:
                raise ValueError(
                    f"checkpoint at {ckpt_dir} was trained with a different "
                    f"NeRFConfig: {'; '.join(bad)}")
            return params
    res = nerf_train.train_nerf(cfg, scene, steps=train_steps,
                                n_views=n_views, image_hw=image_hw,
                                log_every=max(train_steps // 2, 1),
                                seed=seed, verbose=verbose)
    if ckpt_dir:
        # meta first: dying between the writes leaves meta + no step, which
        # retrains on the next run rather than failing or serving blind
        os.makedirs(ckpt_dir, exist_ok=True)
        with open(os.path.join(ckpt_dir, FIELD_META), "w") as f:
            json.dump({"scene": scene, "steps": train_steps, "seed": seed,
                       "grid_res": cfg.grid_res}, f)
        path = ckpt_lib.save_checkpoint(ckpt_dir, train_steps, res.params)
        if verbose:
            print(f"[engine] checkpointed field to {path}")
    return res.params


class RenderEngine:
    """Batched novel-view serving from one resident (compressed) field."""

    def __init__(self, cfg: NeRFConfig, field, cubes: CubeSet, *,
                 field_mode: str = "hybrid", ray_chunk: int = 4096,
                 cube_chunk: int = 8, pair_budget: int = None,
                 order_mode: str = "octant", max_batch_views: int = 8,
                 mesh=None):
        import jax

        self.cfg = cfg
        self.field_mode = field_mode
        self.ray_chunk = int(ray_chunk)
        self.cube_chunk = int(cube_chunk)
        self.max_batch_views = int(max_batch_views)

        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.rules = make_rules(mesh)
        self.n_devices = int(np.prod(list(mesh.shape.values())))

        if field_mode == "hybrid" and not isinstance(
                field, sparse.CompressedField):
            field = sparse.compress_field(field, cfg)
        # byte accounting shared with the renderers (pipeline.field_eval_fns)
        _, _, _, self.factor_bytes, self.factor_bytes_dense = \
            rt_pipe.field_eval_fns(field, cfg, field_mode)
        # resident placement: streams replicated, rays are the sharded axis
        self.field = distributed.place_field(field, self.rules)
        self.cubes = cubes
        self.ordering = rt_pipe.OrderingCache(cubes, order_mode)

        self._render = jax.jit(rt_pipe.make_ray_renderer(
            self.field, cfg, field_mode=field_mode, chunk=self.cube_chunk,
            pair_budget=pair_budget))

        self._queue: List[_Request] = []
        self._next_id = 0
        self._latencies: List[float] = []
        self._render_s_total = 0.0
        self._views_served = 0
        self._flushes = 0
        self._dropped_pairs = 0

    # -- field lifecycle ---------------------------------------------------

    @classmethod
    def from_scene(cls, cfg: NeRFConfig, scene: str, *,
                   ckpt_dir: Optional[str] = None, train_steps: int = 200,
                   n_views: int = 8, image_hw: int = 64,
                   prune_sparsity: float = 0.0, seed: int = 0,
                   verbose: bool = True, **kw) -> "RenderEngine":
        """Train-once-or-restore, prune, rebuild occupancy, go resident."""
        params = prepare_field(cfg, scene, ckpt_dir=ckpt_dir,
                               train_steps=train_steps, n_views=n_views,
                               image_hw=image_hw, seed=seed, verbose=verbose)
        if prune_sparsity > 0.0:
            params = tensorf.prune_to_sparsity(params, prune_sparsity)
        occ = occ_lib.build_occupancy(params, cfg,
                                      sigma_thresh=cfg.occ_sigma_thresh)
        cubes = occ_lib.extract_cubes(occ, cfg)
        return cls(cfg, params, cubes, **kw)

    def update_cubes(self, cubes: CubeSet):
        """Occupancy rebuilt (e.g. the field was re-pruned): swap the cube
        set and drop every cached ordering."""
        self.cubes = cubes
        self.ordering.invalidate(cubes)

    # -- request/response --------------------------------------------------

    def submit(self, cam: Camera, gt=None) -> ViewFuture:
        """Queue one novel-view request; returns a future. The queue is
        flushed when it reaches `max_batch_views` (or on flush()/result())."""
        fut = ViewFuture(self, self._next_id)
        self._queue.append(_Request(cam, gt, fut, time.perf_counter()))
        self._next_id += 1
        if len(self._queue) >= self.max_batch_views:
            self.flush()
        return fut

    def flush(self) -> List[ViewResult]:
        """Render every queued view: group by ordering octant, micro-batch
        each group's rays into fixed chunks, run the single jitted step.
        If a render fails, unresolved requests go back on the queue before
        the error propagates."""
        if not self._queue:
            return []
        reqs, self._queue = self._queue, []
        try:
            return self._flush(reqs)
        except BaseException:
            self._queue = [r for r in reqs
                           if r.future._result is None] + self._queue
            raise

    def _flush(self, reqs: List[_Request]) -> List[ViewResult]:
        t0 = time.perf_counter()
        groups: Dict[tuple, List[_Request]] = {}
        for r in reqs:
            groups.setdefault(self.ordering.key_for(r.cam.origin),
                              []).append(r)

        results: List[ViewResult] = []
        try:
            self._flush_groups(groups, results)
        finally:
            # count whatever resolved (and the time spent) even when a
            # later group's render raised, so stats() stays consistent
            # with the latencies recorded for the resolved views
            self._render_s_total += time.perf_counter() - t0
            self._views_served += len(results)
            self._flushes += 1
        return results

    def _flush_groups(self, groups: Dict[tuple, List[_Request]],
                      results: List[ViewResult]):
        for reqs_g in groups.values():
            for r in reqs_g:                      # one cache access per view
                centers, valid = self.ordering.get_ordered(r.cam.origin)
            batches = []
            for r in reqs_g:
                o, d = rendering.camera_rays(r.cam)
                batches.append((np.asarray(o), np.asarray(d)))
            plan = plan_microbatches(batches, self.ray_chunk)
            outs = []
            for i in range(plan.n_chunks):
                ro, rd = distributed.shard_rays(
                    self.rules, jnp.asarray(plan.rays_o[i]),
                    jnp.asarray(plan.rays_d[i]))
                rgb, aux = self._render(centers, valid, ro, rd)
                outs.append(np.asarray(rgb))
                self._dropped_pairs += int(aux["dropped_pairs"])
            imgs = plan.scatter(outs)
            t_done = time.perf_counter()
            for r, img in zip(reqs_g, imgs):
                psnr = None
                if r.gt is not None:
                    psnr = float(rendering.psnr(
                        jnp.clip(jnp.asarray(img), 0, 1), jnp.asarray(r.gt)))
                lat = t_done - r.t_submit
                self._latencies.append(lat)
                results.append(ViewResult(
                    view_id=r.future._view_id, img=img, psnr=psnr,
                    latency_s=lat, stats={
                        "occ_accesses": float(self.cubes.count),
                        "factor_bytes": float(self.factor_bytes),
                        "factor_bytes_dense": float(self.factor_bytes_dense),
                    }))
                r.future._set(results[-1])

    def render_views(self, cams, gts=None) -> List[ViewResult]:
        """Convenience: submit a batch of cameras and flush."""
        gts = gts if gts is not None else [None] * len(cams)
        futs = [self.submit(c, g) for c, g in zip(cams, gts)]
        self.flush()
        return [f.result() for f in futs]

    # -- telemetry ---------------------------------------------------------

    def stats(self) -> Dict:
        lat = np.asarray(self._latencies, np.float64)
        return {
            "views_served": self._views_served,
            "flushes": self._flushes,
            "fps": (self._views_served / self._render_s_total
                    if self._render_s_total > 0 else 0.0),
            "render_s_total": self._render_s_total,
            "latency_p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "latency_p95_s": float(np.percentile(lat, 95)) if lat.size else 0.0,
            "latency_mean_s": float(lat.mean()) if lat.size else 0.0,
            "occ_accesses_per_view": float(self.cubes.count),
            "factor_bytes": float(self.factor_bytes),
            "factor_bytes_dense": float(self.factor_bytes_dense),
            "compression_ratio": (self.factor_bytes_dense
                                  / max(self.factor_bytes, 1)),
            "dropped_pairs": self._dropped_pairs,
            "ordering_cache": self.ordering.stats(),
            "field_mode": self.field_mode,
            "ray_chunk": self.ray_chunk,
            "cube_chunk": self.cube_chunk,
            "n_devices": self.n_devices,
        }
