"""Scene-routed streaming serving engine over a store of resident
compressed fields.

The RT-NeRF serving story (ROADMAP: "streaming / multi-view compressed
serving"), now multi-scene: a `serving.store.SceneStore` keeps any number
of named scenes resident — encoded hybrid bitmap/COO fields, per-scene
occupancy cubes and ordering caches — under one device-memory budget
(`NeRFConfig.max_resident_bytes`, LRU eviction to encoded checkpoints with
transparent revival), and ONE `RenderEngine` serves request streams
against all of them. Costs the per-view loop pays on every request are
paid once per engine (or once per scene):

  * encode        — the hybrid encoding is built at scene registration
                    (or arrives pre-encoded from compressed-native
                    training) and stays resident in the store,
  * compilation   — one jitted ray-render step (`pipeline.make_ray_renderer`)
                    at a fixed chunk shape, taking the field as a pytree
                    argument; queued views are micro-batched into those
                    chunks (`serving.batching`), so new cameras, mixed
                    resolutions, hot-swapped fields — and different scenes
                    with the same encoded structure — never retrace,
  * ordering      — per-view `order_cubes` schedules are cached per scene
                    by octant ranking (`pipeline.OrderingCache`),
  * placement     — encoded streams replicated, ray chunks sharded
                    (`core.distributed`), single-device fallback included,
  * pair budget   — the active-pair compaction budget adapts to observed
                    occupancy (`aux["active_pairs_max"]`) with hysteresis
                    instead of sitting at the static config default.

API: `submit(cam, scene="lego", deadline_s=...) -> ViewFuture` queues a
request against a scene handle (scene=None routes to the default scene, so
every single-scene PR 2–4 call site keeps working); `flush()` renders the
queue grouped by (scene, ordering-octant) — one jitted step serves
micro-batches per scene while several scenes flush in the same cycle;
`swap_field(field, scene=...)` / `update_cubes(cubes, scene=...)` publish
through the store (the train->serve loop `serving.finetune.FineTuneLoop`
closes per scene); `register_scene(name, field)` adds scenes to a running
engine; `stats()` aggregates and `stats(scene=...)` itemises. All entry
points are thread-safe; renders run OUTSIDE the engine lock against
consistent per-scene snapshots. With `auto_flush_interval` set a
background flush thread renders on queue-full or interval expiry;
`close()` (or the context manager) joins it cleanly.
`benchmarks/serving_throughput.py` measures single- and multi-scene
serving; `benchmarks/finetune_serving.py` measures it under concurrent
fine-tuning.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs.rtnerf import NeRFConfig
from repro.core import distributed
from repro.core import field as field_lib
from repro.core import occupancy as occ_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, tensorf
from repro.core.occupancy import CubeSet
from repro.core.rendering import Camera
from repro.models.sharding import make_rules
from repro.obs import MetricsRegistry, Tracer, lockdebug
from repro.obs.tracing import ViewTrace
from repro.serving import temporal
from repro.serving.batching import group_requests, plan_microbatches
from repro.serving.store import SceneSnapshot, SceneStore


@dataclasses.dataclass
class ViewResult:
    view_id: int
    img: Optional[np.ndarray]       # (H*W, 3); None when timed_out
    psnr: Optional[float]           # vs the submitted gt, if any
    latency_s: float                # submit -> resolve (queueing + render)
    stats: Dict[str, float]
    timed_out: bool = False         # deadline passed before render started
    scene: str = ""                 # which resident scene rendered this
    trace: Optional[Dict] = None    # span tree (obs.ViewTrace.tree()), if
                                    # tracing was enabled at submit
    depth: Optional[np.ndarray] = None    # (H*W,) accumulated E[w·t]
    opacity: Optional[np.ndarray] = None  # (H*W,) 1 - final transmittance
    cam: Optional[Camera] = None    # the camera this frame was rendered for
                                    # (depth/opacity/cam feed submit_delta's
                                    # radiance warp for the NEXT frame)
    warp_fraction: float = 0.0      # fraction served by the temporal warp
                                    # (0.0 = fully rendered / keyframe)


class ViewFuture:
    """Handle for one queued view.

    `result()` resolves the future: with the engine's background flush
    thread running it just waits (the flusher renders); without it, the
    caller's thread flushes the engine — and if a concurrent flush already
    claimed this request, waits for that render to land."""

    def __init__(self, engine: "RenderEngine", view_id: int):
        self._engine = engine
        self._view_id = view_id
        self._result: Optional[ViewResult] = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._result is not None

    def result(self, timeout: Optional[float] = None) -> ViewResult:
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        while self._result is None:
            if not self._engine._auto_flush_on():
                self._engine.flush()         # propagates render errors
                if self._result is not None:
                    break
            # flusher active, or a concurrent flush claimed this request:
            # wait for the render (short slices so errors surface)
            wait = 0.1
            if deadline is not None:
                wait = min(wait, deadline - time.perf_counter())
                if wait <= 0:
                    raise TimeoutError(
                        f"view {self._view_id} unresolved after {timeout}s")
            self._event.wait(max(wait, 1e-3))
            self._engine._raise_flush_error()
        return self._result

    def _set(self, res: ViewResult):
        self._result = res
        self._event.set()


@dataclasses.dataclass(eq=False)       # identity only: fields hold jax
class _Request:                        # arrays, value-eq is ill-defined
    cam: Camera
    gt: Optional[np.ndarray]
    future: ViewFuture
    t_submit: float
    deadline: Optional[float] = None     # absolute perf_counter time
    scene: str = ""                      # routing key into the SceneStore
    trace: Optional[ViewTrace] = None    # span tree; None = tracing off
    delta: Optional[temporal.DeltaPlan] = None  # sparse-ray work order;
                                         # None = render the full frame


FIELD_META = "field_meta.json"

# repro-lint declarations (scripts/repro_lint.py, docs/static_analysis.md):
# mutable RenderEngine state below is guarded by `_lock` (`_flush_cv` is a
# Condition over the same lock); `_render_lock` serializes renders and
# participates in lock ordering only. Methods in `assume_held` have a
# caller-holds-the-lock contract (reentrant RLock callers).
GUARDED_BY = {
    "RenderEngine": {
        "lock": "_lock",
        "aliases": ("_flush_cv",),
        "locks": ("_render_lock",),
        "attrs": ("_queue", "_next_id", "_flusher", "_flush_error",
                  "auto_flush_interval", "_pair_budget", "_pair_window",
                  "_low_occ_streak", "_pair_occupancy_last",
                  "_budget_resizes", "_render"),
        "assume_held": ("_note_flush_pairs", "_build_render"),
    },
}
# Attribute -> class map for static lock-order edges (calls made while a
# lock is held resolve into these classes' own lock acquisitions).
LOCK_ATTR_CLASSES = {
    "RenderEngine.store": "SceneStore",
    "RenderEngine.metrics": "MetricsRegistry",
    "RenderEngine._g_queue": "Gauge",
    "RenderEngine._g_budget": "Gauge",
    "RenderEngine._m_render_s": "Counter",
    "RenderEngine._m_flushes": "Counter",
    "RenderEngine._m_latency": "Histogram",
}


def prepare_field(cfg: NeRFConfig, scene: str, *, ckpt_dir: Optional[str],
                  train_steps: int = 200, n_views: int = 8,
                  image_hw: int = 64, seed: int = 0, verbose: bool = True
                  ) -> field_lib.FieldBackend:
    """Load the trained field from `ckpt_dir`, or train once (compressed-
    native) and checkpoint there. The field is stored in its *encoded*
    representation (`ckpt.save_field` — bitmap/COO streams as-is, no
    decompress); serve-time pruning stacks on top via `FieldBackend.prune`.
    A restore validates the checkpoint against the requested scene and cfg
    shapes (a mismatch would otherwise render silently wrong images).
    Returns a FieldBackend."""
    import json

    from repro.core import train as nerf_train

    if ckpt_dir:
        step = ckpt_lib.latest_step(ckpt_dir)
        if step is not None:
            meta_path = os.path.join(ckpt_dir, FIELD_META)
            if not os.path.exists(meta_path):
                raise ValueError(
                    f"checkpoint at {ckpt_dir} has no {FIELD_META} — can't "
                    f"verify which scene it holds; delete the directory to "
                    f"retrain or restore the meta file")
            with open(meta_path) as f:
                meta = json.load(f)
            if meta.get("scene") != scene:
                raise ValueError(
                    f"checkpoint at {ckpt_dir} holds scene "
                    f"'{meta.get('scene')}', not '{scene}' — use a "
                    f"different --ckpt-dir per scene")
            if verbose:
                # recorded steps/seed are reuse-by-design (one checkpoint,
                # many serves) but must be visible, not silent
                print(f"[engine] restoring scene '{scene}' from {ckpt_dir} "
                      f"(trained {meta.get('steps')} steps, "
                      f"seed {meta.get('seed')})")
            try:
                restored, _ = ckpt_lib.restore_field(ckpt_dir, step, cfg)
            except ValueError:
                # legacy checkpoint (pre-FieldBackend: raw params dict saved
                # without state_keys/field_spec) — restore through the old
                # like-template path and serve it as a DenseField
                import jax

                like = jax.eval_shape(
                    lambda k: tensorf.init_field(cfg, k),
                    jax.ShapeDtypeStruct((2,), jnp.uint32))
                params = ckpt_lib.restore_checkpoint(ckpt_dir, step, like)
                restored = field_lib.DenseField(params, cfg)
                if verbose:
                    print(f"[engine] {ckpt_dir} holds a legacy params-dict "
                          f"checkpoint; restored dense (re-save with "
                          f"ckpt.save_field to keep it encoded)")
            bad = field_lib.cfg_mismatches(restored, cfg)
            if bad:
                raise ValueError(
                    f"checkpoint at {ckpt_dir} was trained with a different "
                    f"NeRFConfig: {'; '.join(bad)}")
            return restored
    res = nerf_train.train_nerf(cfg, scene, steps=train_steps,
                                n_views=n_views, image_hw=image_hw,
                                log_every=max(train_steps // 2, 1),
                                seed=seed, verbose=verbose)
    if ckpt_dir:
        # meta first: dying between the writes leaves meta + no step, which
        # retrains on the next run rather than failing or serving blind
        os.makedirs(ckpt_dir, exist_ok=True)
        with open(os.path.join(ckpt_dir, FIELD_META), "w") as f:
            json.dump({"scene": scene, "steps": train_steps, "seed": seed,
                       "grid_res": cfg.grid_res}, f)
        path = ckpt_lib.save_field(ckpt_dir, train_steps, res.field)
        if verbose:
            print(f"[engine] checkpointed field to {path}")
    return res.field


class RenderEngine:
    """Batched novel-view serving, scene-routed over a SceneStore.

    The single-scene constructor `RenderEngine(cfg, field, cubes, ...)` is
    the deprecation shim for pre-store call sites: it builds a one-scene
    store (under `scene_name`, default "default") and every scene-less
    entry point (`submit`, `swap_field`, `stats`, ...) routes to that
    default scene. Multi-scene serving passes `store=` (or calls
    `register_scene` on a running engine) and keys each call with
    `scene=`."""

    def __init__(self, cfg: NeRFConfig, field=None, cubes: CubeSet = None,
                 *, store: Optional[SceneStore] = None,
                 scene_name: str = "default",
                 encode: bool = True, ray_chunk: int = 4096,
                 cube_chunk: int = 8, pair_budget: int = None,
                 adaptive_pair_budget: bool = True,
                 order_mode: str = "octant", max_batch_views: int = 8,
                 delta_ray_bucket: Optional[int] = None,
                 auto_flush_interval: Optional[float] = None,
                 max_resident_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 registry: Optional[MetricsRegistry] = None,
                 trace_requests: bool = True,
                 mesh=None):
        import collections

        self.cfg = cfg
        self.ray_chunk = int(ray_chunk)
        self.cube_chunk = int(cube_chunk)
        self.max_batch_views = int(max_batch_views)

        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.rules = make_rules(mesh)
        self.n_devices = int(np.prod(list(mesh.shape.values())))

        if store is not None:
            if field is not None or cubes is not None:
                raise ValueError(
                    "pass either store= or a (field, cubes) pair, not both")
            if registry is not None and registry is not store.metrics:
                raise ValueError(
                    "registry= conflicts with store= — the engine shares "
                    "its store's registry")
            self.store = store
        else:
            self.store = SceneStore(
                cfg, rules=self.rules, encode=encode, order_mode=order_mode,
                max_resident_bytes=max_resident_bytes, spill_dir=spill_dir,
                registry=registry)
            if field is not None:
                self.store.register(scene_name, field, cubes)
            elif cubes is not None:
                raise ValueError("cubes given without a field")

        # ONE registry for the whole serving stack of this store: engine
        # totals, per-scene records, fine-tune loops, and request-stage
        # histograms all land here; stats() and the exposition endpoints
        # (serve --metrics-port) read it. trace_requests=False disables
        # span tracing only — the self-overhead toggle the serving
        # benchmark gates; metrics counters always run.
        self.metrics = self.store.metrics
        self.tracer = Tracer(self.metrics, enabled=trace_requests)
        m = self.metrics
        self._m_views = m.counter("engine_views_served")
        self._m_flushes = m.counter("engine_flushes")
        self._m_render_s = m.counter("engine_render_s")
        self._m_dropped = m.counter("engine_dropped_pairs")
        self._m_timeouts = m.counter("engine_timeouts")
        self._m_latency = m.histogram("engine_latency_s", maxlen=65536)
        self._g_queue = m.gauge("engine_queue_depth")
        self._g_budget = m.gauge("engine_pair_budget")
        # temporal tier (submit_delta): created eagerly so every metrics
        # snapshot carries the warp schema even before the first delta
        # frame — the CI metrics-smoke pins these names
        self._m_warp_rays = m.counter("warp_rays_total")
        self._m_delta_rays = m.counter("engine_delta_rays")
        self._m_delta_views = m.counter("engine_delta_views")
        self._m_delta_fallbacks = m.counter("engine_delta_full_fallbacks")
        self._m_warp_frac = m.histogram("warp_fraction", maxlen=4096)
        m.counter("render_dispatch_total", path="delta")
        # fresh-ray counts are padded to this bucket so a delta frame's
        # chunk count doesn't track the disocclusion count frame-to-frame
        self.delta_ray_bucket = int(delta_ray_bucket if delta_ray_bucket
                                    else max(self.ray_chunk // 8, 32))

        # ONE jitted step shared by every scene; the field is a pytree
        # argument, so swapped fields — and different scenes — with the
        # same encoded structure hit the compiled cache. The active-pair
        # budget starts at the static default (or `pair_budget`) and, with
        # `adaptive_pair_budget`, resizes to observed occupancy (hysteresis
        # + cap; a resize rebuilds the jitted step once).
        n_pairs = self.cube_chunk * self.ray_chunk
        self._pair_budget = min(
            int(pair_budget) if pair_budget else max(n_pairs // 4, 128),
            n_pairs)
        self.pair_budget_initial = self._pair_budget
        self._adaptive_budget = bool(adaptive_pair_budget)
        self._budget_resizes = 0
        self._pair_window = collections.deque(maxlen=8)
        self._low_occ_streak = 0
        self._pair_occupancy_last = 0.0
        self._g_budget.set(self._pair_budget)
        self._build_render()

        # _lock guards queue / stats / budget; renders run OUTSIDE it
        # (serialized by _render_lock) against per-scene store snapshots,
        # so producers, swap_field, and eviction never wait behind a render
        self._lock = lockdebug.make_lock("engine", kind="rlock")
        self._render_lock = lockdebug.make_lock("engine.render")
        self._flush_cv = threading.Condition(self._lock)

        self._queue: List[_Request] = []
        self._next_id = 0

        self._flusher: Optional[threading.Thread] = None
        self._flusher_stop = threading.Event()
        self._flush_error: Optional[BaseException] = None
        self.auto_flush_interval: Optional[float] = None
        if auto_flush_interval is not None:
            self.start_auto_flush(auto_flush_interval)

    def _build_render(self):
        import jax

        self._render = jax.jit(rt_pipe.make_ray_renderer(
            self.cfg, chunk=self.cube_chunk,
            pair_budget=self._pair_budget))

    # -- observability -----------------------------------------------------

    @property
    def _latencies(self) -> np.ndarray:
        """The recent-latency window (compat view over the registry
        histogram the old deque became)."""
        return self._m_latency.window()

    def queue_depth(self) -> int:
        """Requests currently queued (not yet claimed by a flush) — the
        fleet worker reports this in its `stats` reply so the router's
        `fleet_worker_queue_depth{worker=}` gauge tracks real backlog."""
        with self._lock:
            return len(self._queue)

    def set_tracing(self, enabled: bool):
        """Toggle per-request span tracing (metrics counters always run).
        Requests already queued keep the tracing mode they were submitted
        under; the serving benchmark's self-overhead gate flips this."""
        self.tracer.enabled = bool(enabled)

    # -- scene routing -----------------------------------------------------

    @property
    def default_scene(self) -> Optional[str]:
        """Where scene-less calls route: the earliest-registered scene."""
        return self.store.first_scene()

    def _scene_key(self, scene: Optional[str]) -> str:
        if scene is not None:
            return scene
        name = self.default_scene
        if name is None:
            raise RuntimeError("engine has no registered scenes — call "
                               "register_scene() or pass field/cubes")
        return name

    def register_scene(self, name: str, field,
                       cubes: Optional[CubeSet] = None) -> str:
        """Add a resident scene to the running engine (budget-enforced —
        may LRU-evict a colder scene). Returns the scene key."""
        self.store.register(name, field, cubes)
        return name

    # -- legacy single-scene views (default-scene routed) ------------------

    @property
    def field(self):
        return self.store.get_field(self._scene_key(None))

    @property
    def cubes(self) -> CubeSet:
        return self.store.snapshot(self._scene_key(None)).cubes

    @property
    def ordering(self) -> rt_pipe.OrderingCache:
        return self.store.snapshot(self._scene_key(None)).ordering

    # -- background flush thread -------------------------------------------

    def _auto_flush_on(self) -> bool:
        with self._lock:
            t = self._flusher
        return t is not None and t.is_alive()

    def _raise_flush_error(self):
        with self._lock:
            err, self._flush_error = self._flush_error, None
        if err is not None:
            raise err

    def start_auto_flush(self, interval_s: float):
        """Start the background flush thread: producers only ever enqueue
        (submit never renders inline); the flusher renders when the queue
        reaches `max_batch_views` or every `interval_s` seconds, whichever
        comes first. Pair with `close()` (or use the engine as a context
        manager) — the thread is non-daemon so leaks are loud."""
        with self._lock:
            if self._flusher is not None:
                raise RuntimeError("auto-flush thread already running")
            self.auto_flush_interval = float(interval_s)
            self._flusher_stop.clear()
            self._flusher = threading.Thread(
                target=self._flush_loop, name="engine-auto-flush")
            self._flusher.start()

    def _flush_loop(self):
        while True:
            with self._flush_cv:
                # a pending error means the last flush failed and requeued
                # its batch: always wait out the interval then (backoff)
                # instead of spinning on a queue that stays >= max
                if not self._flusher_stop.is_set() and \
                        (self._flush_error is not None or
                         len(self._queue) < self.max_batch_views):
                    self._flush_cv.wait(self.auto_flush_interval)
                if self._flusher_stop.is_set():
                    break
            try:
                self.flush()
            except BaseException as e:   # surfaced via result()/close()
                with self._lock:
                    self._flush_error = e
        try:
            self.flush()                 # drain so close() strands nothing
        except BaseException as e:
            with self._lock:
                self._flush_error = e

    def close(self):
        """Stop the background flush thread (joining it — no daemon-thread
        leaks), drain the queue, and surface any deferred flush error."""
        with self._lock:
            t, self._flusher = self._flusher, None
            self._flusher_stop.set()
            self._flush_cv.notify_all()
        if t is not None:
            t.join()
        self.flush()
        self._raise_flush_error()

    def __enter__(self) -> "RenderEngine":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- field lifecycle ---------------------------------------------------

    @classmethod
    def from_scene(cls, cfg: NeRFConfig, scene: str, *,
                   ckpt_dir: Optional[str] = None, train_steps: int = 200,
                   n_views: int = 8, image_hw: int = 64,
                   prune_sparsity: float = 0.0, seed: int = 0,
                   verbose: bool = True, **kw) -> "RenderEngine":
        """Train-once-or-restore, prune, rebuild occupancy, go resident
        (registered under the scene's own name, so `submit(..., scene=...)`
        and fine-tune attachment address it directly)."""
        field = prepare_field(cfg, scene, ckpt_dir=ckpt_dir,
                              train_steps=train_steps, n_views=n_views,
                              image_hw=image_hw, seed=seed, verbose=verbose)
        if prune_sparsity > 0.0:
            field = field.prune(sparsity=prune_sparsity)
        occ = occ_lib.build_occupancy(field, cfg)
        cubes = occ_lib.extract_cubes(occ, cfg)
        return cls(cfg, field, cubes, scene_name=scene, **kw)

    @classmethod
    def from_scenes(cls, cfg: NeRFConfig, scenes: Sequence[str], *,
                    ckpt_root: Optional[str] = None, train_steps: int = 200,
                    n_views: int = 8, image_hw: int = 64,
                    prune_sparsity: float = 0.0, seed: int = 0,
                    verbose: bool = True, **kw) -> "RenderEngine":
        """One engine serving several named scenes: each is trained once or
        restored (per-scene subdirectory of `ckpt_root`) and registered;
        with a `max_resident_bytes` budget the store LRU-evicts cold scenes
        as warmer ones register."""
        if not scenes:
            raise ValueError("from_scenes needs at least one scene")
        engine: Optional[RenderEngine] = None
        for s in scenes:
            ckpt = os.path.join(ckpt_root, s) if ckpt_root else None
            field = prepare_field(cfg, s, ckpt_dir=ckpt,
                                  train_steps=train_steps, n_views=n_views,
                                  image_hw=image_hw, seed=seed,
                                  verbose=verbose)
            if prune_sparsity > 0.0:
                field = field.prune(sparsity=prune_sparsity)
            if engine is None:
                engine = cls(cfg, field, None, scene_name=s, **kw)
            else:
                engine.register_scene(s, field)
        return engine

    def swap_field(self, field, cubes: Optional[CubeSet] = None, *,
                   scene: Optional[str] = None):
        """Atomically publish a newly trained / re-encoded field for one
        scene (the train->serve loop) through the store. Queued requests
        are NOT dropped: they stay queued and render from the new field at
        the next flush; requests racing in from other threads land before
        or after the swap, never astride it; a render already in flight
        finishes from its own consistent snapshot. When `cubes` is None the
        occupancy cube set is rebuilt from the new field at
        cfg.occ_sigma_thresh — pass precomputed cubes (as FineTuneLoop
        does) to keep the swap latency to the pointer switch."""
        self.store.publish(self._scene_key(scene), field, cubes)

    def update_cubes(self, cubes: CubeSet, *, scene: Optional[str] = None):
        """Occupancy rebuilt (e.g. the field was re-pruned): swap the cube
        set and start from an empty ordering cache."""
        self.store.update_cubes(self._scene_key(scene), cubes)

    # -- request/response --------------------------------------------------

    def submit(self, cam: Camera, gt=None, *, scene: Optional[str] = None,
               deadline_s: Optional[float] = None) -> ViewFuture:
        """Queue one novel-view request against a scene handle; returns a
        future. scene=None routes to the default scene. Submitting against
        an evicted scene revives it here, transparently — before the
        engine lock is taken, so a revival's disk I/O never stalls the
        queue or the flush path (producers touching the store during that
        revival briefly serialize on the store lock; ROADMAP tracks moving
        spill I/O off-lock). The queue is flushed when it reaches `max_batch_views`
        (or on flush()/result()). `deadline_s` (seconds from now): if the
        deadline passes before the render starts, the request resolves with
        a timed-out ViewResult instead of being rendered late (AR/VR frames
        are useless stale).

        With the background flush thread running, submit only enqueues and
        notifies — the producer never renders (and never waits behind a
        render: flush holds the engine lock only to take the queue and to
        record stats, not for the render itself)."""
        key = self._scene_key(scene)
        self.store.ensure_resident(key)
        return self._enqueue(cam, gt, key, deadline_s)

    def _enqueue(self, cam: Camera, gt, key: str,
                 deadline_s: Optional[float], *,
                 delta: Optional[temporal.DeltaPlan] = None,
                 t_start: Optional[float] = None,
                 pre_spans: Sequence[tuple] = ()) -> ViewFuture:
        """Shared tail of submit/submit_delta: queue one request under the
        engine lock. `t_start` backdates the request (submit_delta's warp
        runs on the caller's thread before the lock — that time is part of
        the request's latency); `pre_spans` are (name, t0, t1, attrs)
        stage spans measured by the caller before the trace existed."""
        with self._lock:
            fut = ViewFuture(self, self._next_id)
            now = time.perf_counter()
            t0 = now if t_start is None else t_start
            trace = self.tracer.start(self._next_id, key, t_submit=t0)
            deadline = None if deadline_s is None else now + deadline_s
            self._queue.append(
                _Request(cam, gt, fut, t0, deadline, key, trace, delta))
            self._next_id += 1
            self._g_queue.set(len(self._queue))
            if trace is not None:
                for name, s0, s1, attrs in pre_spans:
                    trace.add(name, s0, s1, **attrs)
                trace.add("submit", now, time.perf_counter())
            full = len(self._queue) >= self.max_batch_views
            if full and self._auto_flush_on():
                self._flush_cv.notify()
                full = False
        if full:
            self.flush()
        return fut

    def submit_delta(self, cam: Camera, prev: Optional[ViewResult] = None,
                     gt=None, *, scene: Optional[str] = None,
                     deadline_s: Optional[float] = None,
                     max_delta_frac: float = 0.6) -> ViewFuture:
        """Queue a frame-coherent novel-view request: warp `prev` (the
        previous frame's ViewResult, carrying img/depth/opacity/cam) to
        `cam`, and render only the rays the warp can't vouch for — the
        composited full frame resolves through the returned future exactly
        like `submit`'s, with `warp_fraction` telling how much of it was
        reused. Falls back to a full render (bit-identical to `submit`)
        when there is no usable `prev` (a keyframe, a timed-out prev, or
        one rendered before the engine returned geometry) or when the
        low-confidence set exceeds `max_delta_frac` of the frame — at that
        point warping saves nothing over a clean render.

        The warp + mask run on the submitting thread (traced as the
        `warp`/`mask` stages): O(H*W) numpy pointer math that must not
        serialize against the jitted render steps. Chain results —
        `prev=last.result()` — for streaming; every Nth frame pass
        `prev=None` to cut a keyframe and stop drift accumulation."""
        key = self._scene_key(scene)
        self.store.ensure_resident(key)
        usable = (prev is not None and not prev.timed_out
                  and prev.img is not None and prev.depth is not None
                  and prev.opacity is not None and prev.cam is not None
                  and int(prev.cam.h) == int(cam.h)
                  and int(prev.cam.w) == int(cam.w))
        if not usable:
            return self._enqueue(cam, gt, key, deadline_s)
        t_w0 = time.perf_counter()
        warp = temporal.warp_radiance(prev.img, prev.cam, cam, prev.depth,
                                      opacity=prev.opacity)
        t_w1 = time.perf_counter()
        plan = temporal.plan_delta(warp, bucket=self.delta_ray_bucket)
        t_m1 = time.perf_counter()
        n_pix = int(cam.h) * int(cam.w)
        if plan.n_real > max_delta_frac * n_pix:
            self._m_delta_fallbacks.inc()
            return self._enqueue(cam, gt, key, deadline_s, t_start=t_w0)
        spans = (("warp", t_w0, t_w1, {}),
                 ("mask", t_w1, t_m1,
                  {"fresh_rays": plan.n_rays,
                   "warp_fraction": plan.warp_fraction}))
        return self._enqueue(cam, gt, key, deadline_s, delta=plan,
                             t_start=t_w0, pre_spans=spans)

    def flush(self) -> List[ViewResult]:
        """Render every queued view: group by (scene, ordering octant),
        micro-batch each group's rays into fixed chunks, run the single
        jitted step per group — several scenes flush in one cycle without
        mixing micro-batches. Renders are serialized on `_render_lock` but
        run OUTSIDE the engine lock, against consistent per-scene
        snapshots taken with the queue — submit/swap_field/eviction
        proceed while a flush renders. If a render fails, unresolved
        requests go back on the queue before the error propagates."""
        with self._render_lock:
            with self._lock:
                if not self._queue:
                    return []
                reqs, self._queue = self._queue, []
                self._g_queue.set(0)
                render_fn = self._render
                budget = self._pair_budget
            try:
                # snapshots are taken OUTSIDE the engine lock: reviving a
                # scene evicted since its submit does disk I/O, and
                # producers must not stall behind it — but INSIDE this
                # try, so a failed revival requeues the batch like any
                # render failure instead of dropping futures. A swap
                # landing between the queue-take and the snapshot is the
                # ordinary "request lands after the swap" case — each
                # group still renders from one consistent snapshot.
                snaps: Dict[str, SceneSnapshot] = {}
                for r in reqs:
                    if r.scene not in snaps:
                        snaps[r.scene] = self.store.snapshot(r.scene)
                return self._flush(reqs, snaps, render_fn, budget)
            except BaseException:
                with self._lock:
                    self._queue = [r for r in reqs
                                   if r.future._result is None] + self._queue
                raise

    def _flush(self, reqs: List[_Request], snaps: Dict[str, SceneSnapshot],
               render_fn, budget: int) -> List[ViewResult]:
        t0 = time.perf_counter()
        results: List[ViewResult] = []

        # deadline pass: fail expired requests now, render the rest.
        # Stats commit BEFORE each future's event fires, so a waiter that
        # wakes on resolution always sees them reflected in stats().
        # Every request's queue span closes here — the flush that claimed
        # it ends its time-in-queue, rendered or expired alike.
        live: List[_Request] = []
        for r in reqs:
            if r.trace is not None:
                r.trace.add("queue", r.t_submit, t0)
            if r.deadline is not None and t0 > r.deadline:
                trace_tree = None
                if r.trace is not None:
                    r.trace.add("deliver", t0, t0, timed_out=True)
                    self.tracer.finish(r.trace, t_done=t0)
                    trace_tree = r.trace.tree()
                res = ViewResult(view_id=r.future._view_id, img=None,
                                 psnr=None, latency_s=t0 - r.t_submit,
                                 stats={}, timed_out=True, scene=r.scene,
                                 trace=trace_tree)
                self._m_timeouts.inc()
                r.future._set(res)
                results.append(res)
            else:
                live.append(r)
        if not live:
            return results

        tg = time.perf_counter()
        # delta requests batch separately from full frames: their ray sets
        # are sparse index gathers, and mixing them would make the scatter
        # ambiguous about which rays rebuild a full image
        groups = group_requests(
            live, lambda r: (r.scene, snaps[r.scene].ordering.key_for(
                r.cam.origin), r.delta is not None))
        tg1 = time.perf_counter()
        for r in live:
            if r.trace is not None:
                r.trace.add("group", tg, tg1, n_groups=len(groups),
                            batch_views=len(live))

        flush_pairs = [0, 0]    # [max active pairs, successful render calls]
        flush_dropped = [0]
        try:
            self._flush_groups(groups, results, snaps, render_fn,
                               flush_pairs, flush_dropped)
        finally:
            # time spent counts even when a later group's render raised
            with self._lock:
                self._m_render_s.inc(time.perf_counter() - t0)
                self._m_flushes.inc()
                # zero active pairs is a valid (minimum) occupancy
                # observation — only flushes where no render completed
                # (failure before the first aux) are skipped
                if flush_pairs[1]:
                    self._note_flush_pairs(flush_pairs[0], flush_dropped[0],
                                           budget)
        return results

    def _flush_groups(self, groups: Dict[tuple, List[_Request]],
                      results: List[ViewResult],
                      snaps: Dict[str, SceneSnapshot], render_fn,
                      flush_pairs: List[int], flush_dropped: List[int]):
        for (scene, _okey, is_delta), reqs_g in groups.items():
            snap = snaps[scene]
            ordering = snap.ordering
            traces = [r.trace for r in reqs_g if r.trace is not None]

            def span_all(name, t0, t1, **attrs):
                # group-level stages are shared intervals: each member
                # request spent exactly [t0, t1] in this stage
                for tr in traces:
                    tr.add(name, t0, t1, **attrs)

            tg0 = time.perf_counter()
            for r in reqs_g:                      # one cache access per view
                centers, valid = ordering.get_ordered(r.cam.origin)
            t_ord = time.perf_counter()
            span_all("ordering", tg0, t_ord,
                     cache_entries=len(ordering._entries))
            batches = []
            for r in reqs_g:
                o, d = rendering.camera_rays(r.cam)
                o, d = np.asarray(o), np.asarray(d)
                if r.delta is not None:
                    # only the low-confidence rays render; the rest of the
                    # frame arrives pre-warped in r.delta.warp
                    o, d = o[r.delta.idx], d[r.delta.idx]
                batches.append((o, d))
            plan = plan_microbatches(batches, self.ray_chunk)
            t_plan = time.perf_counter()
            span_all("compaction", t_ord, t_plan, n_chunks=plan.n_chunks,
                     rays=plan.total)
            outs = []
            geo_outs = []
            group_dropped = 0
            group_pairs_max = 0
            for i in range(plan.n_chunks):
                ro, rd = distributed.shard_rays(
                    self.rules, jnp.asarray(plan.rays_o[i]),
                    jnp.asarray(plan.rays_d[i]))
                rgb, aux = render_fn(snap.field, centers, valid, ro, rd)
                outs.append(np.asarray(rgb))
                geo_outs.append(np.stack([np.asarray(aux["depth"]),
                                          np.asarray(aux["opacity"])],
                                         axis=-1))
                group_dropped += int(aux["dropped_pairs"])
                group_pairs_max = max(group_pairs_max,
                                      int(aux["active_pairs_max"]))
                flush_pairs[1] += 1
            flush_pairs[0] = max(flush_pairs[0], group_pairs_max)
            flush_dropped[0] += group_dropped
            imgs = plan.scatter(outs)
            geos = plan.scatter(geo_outs)
            t_done = time.perf_counter()
            # the render span covers the jitted steps AND the host
            # transfer (np.asarray blocks on the device); dispatch_path
            # separates fused / fused_ref / per-op / dense time
            span_all("render", t_plan, t_done,
                     dispatch_path=snap.field.dispatch_path(),
                     n_chunks=plan.n_chunks, dropped_pairs=group_dropped,
                     active_pairs_max=group_pairs_max,
                     path="delta" if is_delta else "full")
            group: List[tuple] = []
            for r, img, geo in zip(reqs_g, imgs, geos):
                if r.delta is not None:
                    img, geo, warp_frac = self._composite_delta(r, img, geo)
                else:
                    warp_frac = 0.0
                psnr = None
                if r.gt is not None:
                    psnr = float(rendering.psnr(
                        jnp.clip(jnp.asarray(img), 0, 1), jnp.asarray(r.gt)))
                lat = time.perf_counter() - r.t_submit
                group.append((r, ViewResult(
                    view_id=r.future._view_id, img=img, psnr=psnr,
                    latency_s=lat, scene=scene,
                    depth=np.ascontiguousarray(geo[:, 0]),
                    opacity=np.ascontiguousarray(geo[:, 1]), cam=r.cam,
                    warp_fraction=warp_frac, stats={
                        "occ_accesses": float(snap.cubes.count),
                        "factor_bytes": float(snap.factor_bytes),
                        "factor_bytes_dense": float(snap.factor_bytes_dense),
                    })))
            # commit the whole group's stats (global, then per-scene), THEN
            # resolve its futures — a render failure in a later group
            # leaves this group counted and resolved, unrendered groups
            # uncounted (they requeue)
            self._m_dropped.inc(group_dropped)
            for _, res in group:
                self._m_latency.record(res.latency_s)
                self._m_views.inc()
            self.store.note_served(scene,
                                   [res.latency_s for _, res in group],
                                   time.perf_counter() - tg0)
            for r, res in group:
                if r.trace is not None:
                    t_del = time.perf_counter()
                    r.trace.add("deliver", t_done, t_del,
                                psnr=res.psnr)
                    self.tracer.finish(r.trace, t_done=t_del)
                    res.trace = r.trace.tree()
                results.append(res)
                r.future._set(res)

    def _composite_delta(self, r: _Request, fresh_img: np.ndarray,
                         fresh_geo: np.ndarray):
        """Composite one delta request: overwrite the warped frame's
        low-confidence pixels with the freshly rendered rays (pad entries
        re-write pixel 0 with its own fresh value — idempotent), record
        the temporal-tier telemetry, and return (img, geo, warp_fraction)
        shaped exactly like a full render's."""
        plan = r.delta
        t_c0 = time.perf_counter()
        warp = plan.warp
        img = warp.rgb.astype(np.float32)
        geo = np.stack([warp.depth, warp.opacity],
                       axis=-1).astype(np.float32)
        img[plan.idx] = fresh_img
        geo[plan.idx] = fresh_geo
        n_pix = warp.confidence.size
        self._m_delta_views.inc()
        self._m_delta_rays.inc(plan.n_real)
        self._m_warp_rays.inc(n_pix - plan.n_real)
        self._m_warp_frac.record(plan.warp_fraction)
        self.metrics.counter("render_dispatch_total", path="delta").inc()
        if r.trace is not None:
            r.trace.add("composite", t_c0, time.perf_counter(),
                        fresh_rays=plan.n_rays,
                        warp_fraction=plan.warp_fraction)
        return img, geo, plan.warp_fraction

    # -- adaptive pair budget ----------------------------------------------

    def _note_flush_pairs(self, max_pairs: int, dropped: int, budget: int):
        """Resize the active-pair compaction budget from observed occupancy
        (engine lock + render lock held — the jitted step is rebuilt here,
        never mid-flush). Hysteresis: grow immediately (x2, capped at the
        full pair count) when pairs were dropped or the budget filled;
        shrink only after 3 consecutive low-occupancy (<25%) flushes, to 2x
        the recent observed max (256-aligned, floor 128) — so one busy view
        doesn't thrash the compiled step."""
        n_pairs = self.cube_chunk * self.ray_chunk
        self._pair_occupancy_last = max_pairs / max(budget, 1)
        if not self._adaptive_budget or budget != self._pair_budget:
            return          # a resize already happened since this snapshot
        self._pair_window.append(max_pairs)
        new = None
        if dropped > 0 or max_pairs >= budget:
            new = min(budget * 2, n_pairs)
            self._low_occ_streak = 0
        elif max_pairs * 4 < budget:
            self._low_occ_streak += 1
            if self._low_occ_streak >= 3:
                want = max(2 * max(self._pair_window), 128)
                want = min(-(-want // 256) * 256, n_pairs)
                if want < budget:
                    new = want
                self._low_occ_streak = 0
        else:
            self._low_occ_streak = 0
        if new is not None and new != budget:
            self._pair_budget = new
            self._budget_resizes += 1
            self._g_budget.set(new)
            self._build_render()

    def render_views(self, cams, gts=None, *,
                     scene: Optional[str] = None) -> List[ViewResult]:
        """Convenience: submit a batch of cameras and flush."""
        gts = gts if gts is not None else [None] * len(cams)
        futs = [self.submit(c, g, scene=scene) for c, g in zip(cams, gts)]
        self.flush()
        return [f.result() for f in futs]

    # -- telemetry ---------------------------------------------------------

    def stats(self, scene: Optional[str] = None) -> Dict:
        """stats() aggregates across scenes (single-scene keys unchanged
        from the pre-store engine — every key now sourced from the shared
        metrics registry, computed over the default scene where a single
        scene's identity matters — field_kind, factor bytes);
        stats(scene="lego") itemises one scene."""
        if scene is not None:
            return self.store.stats(scene)
        with self._lock:
            views = int(self._m_views.value)
            render_s = self._m_render_s.value
            out = {
                "views_served": views,
                "flushes": int(self._m_flushes.value),
                "fps": views / render_s if render_s > 0 else 0.0,
                "render_s_total": render_s,
                "latency_p50_s": self._m_latency.percentile(50),
                "latency_p95_s": self._m_latency.percentile(95),
                "latency_p99_s": self._m_latency.percentile(99),
                "latency_mean_s": self._m_latency.mean(),
                "dropped_pairs": int(self._m_dropped.value),
                "timeouts": int(self._m_timeouts.value),
                "pair_budget": self._pair_budget,
                "pair_budget_initial": self.pair_budget_initial,
                "pair_budget_resizes": self._budget_resizes,
                "pair_occupancy_last": self._pair_occupancy_last,
                "auto_flush_interval": self.auto_flush_interval,
                "auto_flush_running": self._auto_flush_on(),
                "ray_chunk": self.ray_chunk,
                "cube_chunk": self.cube_chunk,
                "n_devices": self.n_devices,
                "delta": {
                    "views": int(self._m_delta_views.value),
                    "fresh_rays": int(self._m_delta_rays.value),
                    "warped_rays": int(self._m_warp_rays.value),
                    "full_fallbacks": int(self._m_delta_fallbacks.value),
                    "warp_fraction_mean": self._m_warp_frac.mean(),
                    "ray_bucket": self.delta_ray_bucket,
                },
            }
        ss = self.store.stats()
        scenes = ss["scenes"]
        out.update({
            "n_scenes": ss["n_scenes"],
            "resident_scenes": ss["resident_scenes"],
            "resident_bytes": ss["resident_bytes"],
            "max_resident_bytes": ss["max_resident_bytes"],
            "evictions": ss["evictions"],
            "revivals": ss["revivals"],
            "scenes": scenes,
            "field_swaps": sum(s["swaps"] for s in scenes.values()),
            "swap_latency_s_last": self.store.last_swap_latency_s,
            "swap_latency_s_max": max(
                [s["swap_latency_s_max"] for s in scenes.values()],
                default=0.0),
            "ordering_cache": {
                "hits": sum(s["ordering_cache"]["hits"]
                            for s in scenes.values()),
                "misses": sum(s["ordering_cache"]["misses"]
                              for s in scenes.values()),
                "nn_hits": sum(s["ordering_cache"].get("nn_hits", 0)
                               for s in scenes.values()),
                "entries": sum(s["ordering_cache"]["entries"]
                               for s in scenes.values()),
            },
        })
        default = self.default_scene
        if default is not None:
            d = scenes[default]
            out.update({
                "occ_accesses_per_view": d["occ_accesses_per_view"],
                "factor_bytes": d["factor_bytes"],
                "factor_bytes_dense": d["factor_bytes_dense"],
                "compression_ratio": d["compression_ratio"],
                "field_kind": d["field_kind"],
            })
        return out

    def stage_breakdown(self) -> Dict[str, Dict[str, float]]:
        """Trace-derived per-stage latency table (canonical stage order):
        stage -> {count, p50_s, p95_s, p99_s, mean_s, total_s}, read from
        the `request_stage_s{stage=...}` histograms the tracer folds every
        finished request into. Benchmarks record this as their
        stage-breakdown columns; `scripts/obs_report.py` renders it from
        an exposition snapshot instead. Temporal-tier stages (warp, mask,
        composite) appear once the workload sends delta frames."""
        from repro.obs.tracing import REPORT_STAGES

        out = {}
        for st in REPORT_STAGES:
            h = self.metrics.histogram("request_stage_s", stage=st)
            if h.count:
                out[st] = {"count": h.count, "p50_s": h.percentile(50),
                           "p95_s": h.percentile(95),
                           "p99_s": h.percentile(99), "mean_s": h.mean(),
                           "total_s": h.sum}
        return out
