"""Streaming multi-view serving engine over a resident compressed field.

The RT-NeRF serving story (ROADMAP: "streaming / multi-view compressed
serving"): load — or train once and checkpoint — a scene, encode the field
into ONE resident `field.CompressedField`, and serve a stream of novel-view
requests from it. Costs the per-view loop pays on every request are paid
once per engine instead:

  * encode        — the hybrid bitmap/COO encoding is built at engine
                    construction (or arrives pre-encoded from compressed-
                    native training) and stays resident,
  * compilation   — one jitted ray-render step (`pipeline.make_ray_renderer`)
                    at a fixed chunk shape, taking the field as a pytree
                    argument; queued views are micro-batched into those
                    chunks (`serving.batching`) so new cameras, mixed
                    resolutions — and hot-swapped fields with the same
                    encoded structure — never retrace,
  * ordering      — per-view `order_cubes` schedules are cached by octant
                    ranking (`pipeline.OrderingCache`, the paper's coarse
                    view-dependent ordering) and reused bit-exactly across
                    requests that rank the octants alike,
  * placement     — the encoded streams are replicated and ray chunks
                    sharded across the mesh (`core.distributed.place_field`
                    / `shard_rays`), with a single-device fallback.

API: `submit(cam, deadline_s=...) -> ViewFuture` queues a request (past-
deadline requests resolve with a timeout result instead of rendering late);
`flush()` renders the queue; `swap_field(field)` atomically publishes a
newly trained / re-encoded field to the running engine without dropping
queued requests — the train->serve loop that `serving.finetune.FineTuneLoop`
closes; `stats()` reports FPS, latency percentiles, occupancy accesses,
factor bytes, timeouts, swap counts/latencies, and ordering-cache hit
rates. All entry points are thread-safe, and renders run OUTSIDE the engine
lock against a consistent (field, cubes, ordering) snapshot — so producers
submit, and the trainer swaps, while a flush is mid-render. With
`auto_flush_interval` set (or `start_auto_flush`), a background flush
thread renders on queue-full or interval expiry and producers never block
on flush() at all; `close()` (or the context manager) joins it cleanly.
`benchmarks/serving_throughput.py` measures this engine against the
sequential per-view loop; `benchmarks/finetune_serving.py` measures it
under concurrent fine-tuning.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs.rtnerf import NeRFConfig
from repro.core import distributed, occupancy as occ_lib
from repro.core import field as field_lib
from repro.core import pipeline as rt_pipe
from repro.core import rendering, tensorf
from repro.core.occupancy import CubeSet
from repro.core.rendering import Camera
from repro.models.sharding import make_rules
from repro.serving.batching import plan_microbatches


@dataclasses.dataclass
class ViewResult:
    view_id: int
    img: Optional[np.ndarray]       # (H*W, 3); None when timed_out
    psnr: Optional[float]           # vs the submitted gt, if any
    latency_s: float                # submit -> resolve (queueing + render)
    stats: Dict[str, float]
    timed_out: bool = False         # deadline passed before render started


class ViewFuture:
    """Handle for one queued view.

    `result()` resolves the future: with the engine's background flush
    thread running it just waits (the flusher renders); without it, the
    caller's thread flushes the engine — and if a concurrent flush already
    claimed this request, waits for that render to land."""

    def __init__(self, engine: "RenderEngine", view_id: int):
        self._engine = engine
        self._view_id = view_id
        self._result: Optional[ViewResult] = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._result is not None

    def result(self, timeout: Optional[float] = None) -> ViewResult:
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        while self._result is None:
            if not self._engine._auto_flush_on():
                self._engine.flush()         # propagates render errors
                if self._result is not None:
                    break
            # flusher active, or a concurrent flush claimed this request:
            # wait for the render (short slices so errors surface)
            wait = 0.1
            if deadline is not None:
                wait = min(wait, deadline - time.perf_counter())
                if wait <= 0:
                    raise TimeoutError(
                        f"view {self._view_id} unresolved after {timeout}s")
            self._event.wait(max(wait, 1e-3))
            self._engine._raise_flush_error()
        return self._result

    def _set(self, res: ViewResult):
        self._result = res
        self._event.set()


@dataclasses.dataclass(eq=False)       # identity only: fields hold jax
class _Request:                        # arrays, value-eq is ill-defined
    cam: Camera
    gt: Optional[np.ndarray]
    future: ViewFuture
    t_submit: float
    deadline: Optional[float] = None     # absolute perf_counter time


FIELD_META = "field_meta.json"


def prepare_field(cfg: NeRFConfig, scene: str, *, ckpt_dir: Optional[str],
                  train_steps: int = 200, n_views: int = 8,
                  image_hw: int = 64, seed: int = 0, verbose: bool = True
                  ) -> field_lib.FieldBackend:
    """Load the trained field from `ckpt_dir`, or train once (compressed-
    native) and checkpoint there. The field is stored in its *encoded*
    representation (`ckpt.save_field` — bitmap/COO streams as-is, no
    decompress); serve-time pruning stacks on top via `FieldBackend.prune`.
    A restore validates the checkpoint against the requested scene and cfg
    shapes (a mismatch would otherwise render silently wrong images).
    Returns a FieldBackend."""
    import json
    import os

    from repro.core import train as nerf_train

    if ckpt_dir:
        step = ckpt_lib.latest_step(ckpt_dir)
        if step is not None:
            meta_path = os.path.join(ckpt_dir, FIELD_META)
            if not os.path.exists(meta_path):
                raise ValueError(
                    f"checkpoint at {ckpt_dir} has no {FIELD_META} — can't "
                    f"verify which scene it holds; delete the directory to "
                    f"retrain or restore the meta file")
            with open(meta_path) as f:
                meta = json.load(f)
            if meta.get("scene") != scene:
                raise ValueError(
                    f"checkpoint at {ckpt_dir} holds scene "
                    f"'{meta.get('scene')}', not '{scene}' — use a "
                    f"different --ckpt-dir per scene")
            if verbose:
                # recorded steps/seed are reuse-by-design (one checkpoint,
                # many serves) but must be visible, not silent
                print(f"[engine] restoring scene '{scene}' from {ckpt_dir} "
                      f"(trained {meta.get('steps')} steps, "
                      f"seed {meta.get('seed')})")
            try:
                restored, _ = ckpt_lib.restore_field(ckpt_dir, step, cfg)
            except ValueError:
                # legacy checkpoint (pre-FieldBackend: raw params dict saved
                # without state_keys/field_spec) — restore through the old
                # like-template path and serve it as a DenseField
                import jax

                like = jax.eval_shape(
                    lambda k: tensorf.init_field(cfg, k),
                    jax.ShapeDtypeStruct((2,), jnp.uint32))
                params = ckpt_lib.restore_checkpoint(ckpt_dir, step, like)
                restored = field_lib.DenseField(params, cfg)
                if verbose:
                    print(f"[engine] {ckpt_dir} holds a legacy params-dict "
                          f"checkpoint; restored dense (re-save with "
                          f"ckpt.save_field to keep it encoded)")
            bad = field_lib.cfg_mismatches(restored, cfg)
            if bad:
                raise ValueError(
                    f"checkpoint at {ckpt_dir} was trained with a different "
                    f"NeRFConfig: {'; '.join(bad)}")
            return restored
    res = nerf_train.train_nerf(cfg, scene, steps=train_steps,
                                n_views=n_views, image_hw=image_hw,
                                log_every=max(train_steps // 2, 1),
                                seed=seed, verbose=verbose)
    if ckpt_dir:
        # meta first: dying between the writes leaves meta + no step, which
        # retrains on the next run rather than failing or serving blind
        os.makedirs(ckpt_dir, exist_ok=True)
        with open(os.path.join(ckpt_dir, FIELD_META), "w") as f:
            json.dump({"scene": scene, "steps": train_steps, "seed": seed,
                       "grid_res": cfg.grid_res}, f)
        path = ckpt_lib.save_field(ckpt_dir, train_steps, res.field)
        if verbose:
            print(f"[engine] checkpointed field to {path}")
    return res.field


class RenderEngine:
    """Batched novel-view serving from one resident (compressed) field."""

    def __init__(self, cfg: NeRFConfig, field, cubes: CubeSet, *,
                 encode: bool = True, ray_chunk: int = 4096,
                 cube_chunk: int = 8, pair_budget: int = None,
                 order_mode: str = "octant", max_batch_views: int = 8,
                 auto_flush_interval: Optional[float] = None,
                 mesh=None):
        import jax

        self.cfg = cfg
        self.encode_fields = bool(encode)
        self.ray_chunk = int(ray_chunk)
        self.cube_chunk = int(cube_chunk)
        self.max_batch_views = int(max_batch_views)

        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.rules = make_rules(mesh)
        self.n_devices = int(np.prod(list(mesh.shape.values())))

        # ONE jitted step; the field is a pytree argument, so a hot-swapped
        # field with the same encoded structure hits the compiled cache
        self._render = jax.jit(rt_pipe.make_ray_renderer(
            cfg, chunk=self.cube_chunk, pair_budget=pair_budget))

        # _lock guards queue / stats / published field; renders run OUTSIDE
        # it (serialized by _render_lock) so producers and swap_field never
        # wait a full render behind flush()
        self._lock = threading.RLock()
        self._render_lock = threading.Lock()
        self._flush_cv = threading.Condition(self._lock)
        self.ordering: Optional[rt_pipe.OrderingCache] = None
        self._order_mode = order_mode
        self._install_field(field, cubes)

        self._queue: List[_Request] = []
        self._next_id = 0
        self._latencies: List[float] = []
        self._render_s_total = 0.0
        self._views_served = 0
        self._flushes = 0
        self._dropped_pairs = 0
        self._timeouts = 0
        self._field_swaps = 0
        self._swap_latencies: List[float] = []

        self._flusher: Optional[threading.Thread] = None
        self._flusher_stop = threading.Event()
        self._flush_error: Optional[BaseException] = None
        self.auto_flush_interval: Optional[float] = None
        if auto_flush_interval is not None:
            self.start_auto_flush(auto_flush_interval)

    # -- background flush thread -------------------------------------------

    def _auto_flush_on(self) -> bool:
        t = self._flusher
        return t is not None and t.is_alive()

    def _raise_flush_error(self):
        err, self._flush_error = self._flush_error, None
        if err is not None:
            raise err

    def start_auto_flush(self, interval_s: float):
        """Start the background flush thread: producers only ever enqueue
        (submit never renders inline); the flusher renders when the queue
        reaches `max_batch_views` or every `interval_s` seconds, whichever
        comes first. Pair with `close()` (or use the engine as a context
        manager) — the thread is non-daemon so leaks are loud."""
        with self._lock:
            if self._flusher is not None:
                raise RuntimeError("auto-flush thread already running")
            self.auto_flush_interval = float(interval_s)
            self._flusher_stop.clear()
            self._flusher = threading.Thread(
                target=self._flush_loop, name="engine-auto-flush")
            self._flusher.start()

    def _flush_loop(self):
        while True:
            with self._flush_cv:
                # a pending error means the last flush failed and requeued
                # its batch: always wait out the interval then (backoff)
                # instead of spinning on a queue that stays >= max
                if not self._flusher_stop.is_set() and \
                        (self._flush_error is not None or
                         len(self._queue) < self.max_batch_views):
                    self._flush_cv.wait(self.auto_flush_interval)
                if self._flusher_stop.is_set():
                    break
            try:
                self.flush()
            except BaseException as e:   # surfaced via result()/close()
                self._flush_error = e
        try:
            self.flush()                 # drain so close() strands nothing
        except BaseException as e:
            self._flush_error = e

    def close(self):
        """Stop the background flush thread (joining it — no daemon-thread
        leaks), drain the queue, and surface any deferred flush error."""
        with self._lock:
            t, self._flusher = self._flusher, None
            self._flusher_stop.set()
            self._flush_cv.notify_all()
        if t is not None:
            t.join()
        self.flush()
        self._raise_flush_error()

    def __enter__(self) -> "RenderEngine":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- field lifecycle ---------------------------------------------------

    def _install_field(self, field, cubes: Optional[CubeSet]):
        """Coerce -> normalise representation -> place on the mesh ->
        publish. encode=True serves the hybrid streams (no-op when the
        field arrives pre-encoded, e.g. from compressed-native training);
        encode=False serves the dense factor arrays — it *decodes* an
        encoded field, so the flag is a real dense/compressed toggle (the
        benchmark baseline path). Callers hold the engine lock (or are the
        constructor)."""
        field = field_lib.as_backend(field, self.cfg)
        field = field.encode() if self.encode_fields else field.decode()
        field = distributed.place_field(field, self.rules)
        if cubes is None:
            occ = occ_lib.build_occupancy(field, self.cfg)
            cubes = occ_lib.extract_cubes(occ, self.cfg)
        self.field = field
        self.factor_bytes = field.factor_bytes()
        self.factor_bytes_dense = field.dense_factor_bytes()
        self.cubes = cubes
        # a NEW cache, not invalidate-in-place: an in-flight flush rendering
        # outside the lock keeps its snapshot's (field, cubes, ordering)
        # consistent while the engine moves on (counters carry over)
        prev = self.ordering
        self.ordering = rt_pipe.OrderingCache(cubes, self._order_mode)
        if prev is not None:
            self.ordering.hits, self.ordering.misses = prev.hits, prev.misses

    @classmethod
    def from_scene(cls, cfg: NeRFConfig, scene: str, *,
                   ckpt_dir: Optional[str] = None, train_steps: int = 200,
                   n_views: int = 8, image_hw: int = 64,
                   prune_sparsity: float = 0.0, seed: int = 0,
                   verbose: bool = True, **kw) -> "RenderEngine":
        """Train-once-or-restore, prune, rebuild occupancy, go resident."""
        field = prepare_field(cfg, scene, ckpt_dir=ckpt_dir,
                              train_steps=train_steps, n_views=n_views,
                              image_hw=image_hw, seed=seed, verbose=verbose)
        if prune_sparsity > 0.0:
            field = field.prune(sparsity=prune_sparsity)
        occ = occ_lib.build_occupancy(field, cfg)
        cubes = occ_lib.extract_cubes(occ, cfg)
        return cls(cfg, field, cubes, **kw)

    def swap_field(self, field, cubes: Optional[CubeSet] = None):
        """Atomically publish a newly trained / re-encoded field to the
        running engine (the train->serve loop). Queued requests are NOT
        dropped: they stay queued and render from the new field at the next
        flush; requests racing in from other threads land before or after
        the swap, never astride it; a render already in flight finishes
        from its own consistent (field, cubes, ordering) snapshot. When
        `cubes` is None the occupancy cube set is rebuilt from the new
        field at cfg.occ_sigma_thresh — pass precomputed cubes (as
        FineTuneLoop does) to keep the engine-lock hold time, and with it
        the producer-visible swap latency, to the pointer switch."""
        t0 = time.perf_counter()
        with self._lock:
            self._install_field(field, cubes)
            self._field_swaps += 1
            self._swap_latencies.append(time.perf_counter() - t0)

    def update_cubes(self, cubes: CubeSet):
        """Occupancy rebuilt (e.g. the field was re-pruned): swap the cube
        set and start from an empty ordering cache."""
        with self._lock:
            self.cubes = cubes
            prev = self.ordering
            self.ordering = rt_pipe.OrderingCache(cubes, self._order_mode)
            self.ordering.hits, self.ordering.misses = prev.hits, prev.misses

    # -- request/response --------------------------------------------------

    def submit(self, cam: Camera, gt=None, *,
               deadline_s: Optional[float] = None) -> ViewFuture:
        """Queue one novel-view request; returns a future. The queue is
        flushed when it reaches `max_batch_views` (or on flush()/result()).
        `deadline_s` (seconds from now): if the deadline passes before the
        render starts, the request resolves with a timed-out ViewResult
        instead of being rendered late (AR/VR frames are useless stale).

        With the background flush thread running, submit only enqueues and
        notifies — the producer never renders (and never waits behind a
        render: flush holds the engine lock only to take the queue and to
        record stats, not for the render itself)."""
        with self._lock:
            fut = ViewFuture(self, self._next_id)
            now = time.perf_counter()
            deadline = None if deadline_s is None else now + deadline_s
            self._queue.append(_Request(cam, gt, fut, now, deadline))
            self._next_id += 1
            full = len(self._queue) >= self.max_batch_views
            if full and self._auto_flush_on():
                self._flush_cv.notify()
                full = False
        if full:
            self.flush()
        return fut

    def flush(self) -> List[ViewResult]:
        """Render every queued view: group by ordering octant, micro-batch
        each group's rays into fixed chunks, run the single jitted step.
        Renders are serialized on `_render_lock` but run OUTSIDE the engine
        lock, against a consistent (field, cubes, ordering) snapshot taken
        with the queue — submit/swap_field proceed while a flush renders.
        If a render fails, unresolved requests go back on the queue before
        the error propagates."""
        with self._render_lock:
            with self._lock:
                if not self._queue:
                    return []
                reqs, self._queue = self._queue, []
                snap = (self.field, self.cubes, self.ordering,
                        self.factor_bytes, self.factor_bytes_dense)
            try:
                return self._flush(reqs, snap)
            except BaseException:
                with self._lock:
                    self._queue = [r for r in reqs
                                   if r.future._result is None] + self._queue
                raise

    def _flush(self, reqs: List[_Request], snap) -> List[ViewResult]:
        t0 = time.perf_counter()
        results: List[ViewResult] = []
        ordering = snap[2]

        # deadline pass: fail expired requests now, render the rest.
        # Stats commit BEFORE each future's event fires, so a waiter that
        # wakes on resolution always sees them reflected in stats().
        live: List[_Request] = []
        for r in reqs:
            if r.deadline is not None and t0 > r.deadline:
                res = ViewResult(view_id=r.future._view_id, img=None,
                                 psnr=None, latency_s=t0 - r.t_submit,
                                 stats={}, timed_out=True)
                with self._lock:
                    self._timeouts += 1
                r.future._set(res)
                results.append(res)
            else:
                live.append(r)
        if not live:
            return results

        groups: Dict[tuple, List[_Request]] = {}
        for r in live:
            groups.setdefault(ordering.key_for(r.cam.origin), []).append(r)

        try:
            self._flush_groups(groups, results, snap)
        finally:
            # time spent counts even when a later group's render raised
            with self._lock:
                self._render_s_total += time.perf_counter() - t0
                self._flushes += 1
        return results

    def _flush_groups(self, groups: Dict[tuple, List[_Request]],
                      results: List[ViewResult], snap):
        field, cubes, ordering, fbytes, fbytes_dense = snap
        for reqs_g in groups.values():
            for r in reqs_g:                      # one cache access per view
                centers, valid = ordering.get_ordered(r.cam.origin)
            batches = []
            for r in reqs_g:
                o, d = rendering.camera_rays(r.cam)
                batches.append((np.asarray(o), np.asarray(d)))
            plan = plan_microbatches(batches, self.ray_chunk)
            outs = []
            group_dropped = 0
            for i in range(plan.n_chunks):
                ro, rd = distributed.shard_rays(
                    self.rules, jnp.asarray(plan.rays_o[i]),
                    jnp.asarray(plan.rays_d[i]))
                rgb, aux = self._render(field, centers, valid, ro, rd)
                outs.append(np.asarray(rgb))
                group_dropped += int(aux["dropped_pairs"])
            imgs = plan.scatter(outs)
            t_done = time.perf_counter()
            group: List[tuple] = []
            for r, img in zip(reqs_g, imgs):
                psnr = None
                if r.gt is not None:
                    psnr = float(rendering.psnr(
                        jnp.clip(jnp.asarray(img), 0, 1), jnp.asarray(r.gt)))
                lat = t_done - r.t_submit
                group.append((r, ViewResult(
                    view_id=r.future._view_id, img=img, psnr=psnr,
                    latency_s=lat, stats={
                        "occ_accesses": float(cubes.count),
                        "factor_bytes": float(fbytes),
                        "factor_bytes_dense": float(fbytes_dense),
                    })))
            # commit the whole group's stats, THEN resolve its futures —
            # a render failure in a later group leaves this group counted
            # and resolved, unrendered groups uncounted (they requeue)
            with self._lock:
                self._dropped_pairs += group_dropped
                for _, res in group:
                    self._latencies.append(res.latency_s)
                    self._views_served += 1
            for r, res in group:
                results.append(res)
                r.future._set(res)

    def render_views(self, cams, gts=None) -> List[ViewResult]:
        """Convenience: submit a batch of cameras and flush."""
        gts = gts if gts is not None else [None] * len(cams)
        futs = [self.submit(c, g) for c, g in zip(cams, gts)]
        self.flush()
        return [f.result() for f in futs]

    # -- telemetry ---------------------------------------------------------

    def stats(self) -> Dict:
        with self._lock:
            lat = np.asarray(self._latencies, np.float64)
            return {
                "views_served": self._views_served,
                "flushes": self._flushes,
                "fps": (self._views_served / self._render_s_total
                        if self._render_s_total > 0 else 0.0),
                "render_s_total": self._render_s_total,
                "latency_p50_s": (float(np.percentile(lat, 50))
                                  if lat.size else 0.0),
                "latency_p95_s": (float(np.percentile(lat, 95))
                                  if lat.size else 0.0),
                "latency_mean_s": float(lat.mean()) if lat.size else 0.0,
                "occ_accesses_per_view": float(self.cubes.count),
                "factor_bytes": float(self.factor_bytes),
                "factor_bytes_dense": float(self.factor_bytes_dense),
                "compression_ratio": (self.factor_bytes_dense
                                      / max(self.factor_bytes, 1)),
                "dropped_pairs": self._dropped_pairs,
                "timeouts": self._timeouts,
                "field_swaps": self._field_swaps,
                "swap_latency_s_last": (self._swap_latencies[-1]
                                        if self._swap_latencies else 0.0),
                "swap_latency_s_max": (max(self._swap_latencies)
                                       if self._swap_latencies else 0.0),
                "auto_flush_interval": self.auto_flush_interval,
                "auto_flush_running": self._auto_flush_on(),
                "ordering_cache": self.ordering.stats(),
                "field_kind": self.field.kind,
                "ray_chunk": self.ray_chunk,
                "cube_chunk": self.cube_chunk,
                "n_devices": self.n_devices,
            }
