"""Fleet tier, router side: consistent-hash scene-affinity routing over
`serving.fleet` worker processes.

One `FleetRouter` owns N spawned workers (each a `SceneStore`-backed
`RenderEngine`, see `fleet.worker_main`) and shards scenes across them:

  * **Affinity** — `HashRing` maps each scene to an owner worker (vnode
    consistent hashing), so a scene's encoded field, cube set, ordering
    cache, and jit state stay warm on one process instead of thrashing
    every worker's LRU. Affinity is *policy*, not a constraint: any
    alive worker can serve any scene (the router lazily registers the
    scene there first), which is what makes replay-after-death and the
    tests' `prefer_worker=` overrides work.
  * **Replication** — `set_replicas(scene, n)` makes a hot scene
    resident on the first n ring owners behind the same key; per-request
    the router picks the replica with the fewest outstanding requests.
    Replicas are registered from the same `fleet.export_scene` path, so
    frames are bit-identical across replicas.
  * **Pin / priority** — `pin(scene)` / `set_priority(scene, p)` forward
    to the owning workers' stores so a popularity spike on cold scenes
    cannot evict a pinned hot scene (`SceneStore._enforce_budget`).
  * **Prefetch** — `prefetch(scene)` asks the owner to revive a
    predicted-next scene on a background thread ahead of the requests.
  * **Failure handling** — a dead worker (SIGKILL, crash, closed pipe)
    is detected by its reader thread hitting EOF. The router removes it
    from the ring (routing version bumps), then resolves every in-flight
    request that was pending on it: requests whose deadline already
    passed complete as timed-out results (the engine's existing deadline
    semantics), live ones are *replayed* on a surviving owner
    (`fleet_replays_total`; renders are idempotent, so at-least-once is
    safe). No future is ever left hanging; with zero survivors the
    future fails with `FleetError`.

Fleet-level metrics flow through the PR 7 obs registry (`fleet_*`
families — see `docs/observability.md`); `scripts/check_metrics_schema.py`
pins them in CI.
"""
from __future__ import annotations

import hashlib
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.obs import lockdebug
from repro.obs.registry import MetricsRegistry

from . import fleet

# repro-lint lock-discipline declarations (docs/static_analysis.md).
# `_lock` is an RLock over the routing state: ring membership, the
# replica/pin tables, and the worker table mutate only under it. Metric
# writes may nest inside it (router -> obs.registry is a declared
# lock-order edge; never the reverse).
GUARDED_BY = {
    "FleetRouter": {
        "lock": "_lock",
        "attrs": ("_closed", "_replicas", "_pins", "ring", "_workers"),
        "assume_held": ("_pick_worker", "_ensure_registered",
                        "_set_routing_gauges", "_alive"),
    },
}
LOCK_ATTR_CLASSES = {
    "FleetRouter.registry": "MetricsRegistry",
}


class FleetError(RuntimeError):
    """A request that can no longer be served by any alive worker."""


# -- consistent hashing ----------------------------------------------------


class HashRing:
    """Consistent-hash ring with virtual nodes.

    Each node contributes `vnodes` points at sha1("node/i") on a sorted
    ring; a key is owned by the first node clockwise of sha1(key).
    `owners(key, n)` walks further clockwise for distinct replica owners.
    Adding/removing a node only remaps the keys adjacent to its vnode
    points — ~1/K of the keyspace — which is what keeps worker churn from
    invalidating every worker's resident set (tested property-style in
    `tests/test_fleet.py`). `version` increments on every membership
    change; the router exports it as the `fleet_routing_version` gauge.
    """

    def __init__(self, nodes: Optional[List[str]] = None, *,
                 vnodes: int = 64):
        self.vnodes = int(vnodes)
        self.version = 0
        self._ring: List[tuple] = []      # sorted (hash, node)
        self._nodes: set = set()
        for n in nodes or []:
            self.add(n)

    @staticmethod
    def _hash(s: str) -> int:
        return int.from_bytes(hashlib.sha1(s.encode()).digest()[:8], "big")

    def add(self, node: str):
        if node in self._nodes:
            return
        self._nodes.add(node)
        for i in range(self.vnodes):
            self._ring.append((self._hash(f"{node}/{i}"), node))
        self._ring.sort()
        self.version += 1

    def remove(self, node: str):
        if node not in self._nodes:
            return
        self._nodes.discard(node)
        self._ring = [(h, n) for h, n in self._ring if n != node]
        self.version += 1

    @property
    def nodes(self) -> List[str]:
        return sorted(self._nodes)

    def owners(self, key: str, n: int = 1) -> List[str]:
        """First `n` distinct nodes clockwise of the key's hash point."""
        if not self._ring:
            return []
        n = min(n, len(self._nodes))
        h = self._hash(key)
        import bisect
        start = bisect.bisect_right(self._ring, (h, chr(0x10FFFF)))
        out: List[str] = []
        for idx in range(len(self._ring)):
            node = self._ring[(start + idx) % len(self._ring)][1]
            if node not in out:
                out.append(node)
                if len(out) == n:
                    break
        return out

    def owner(self, key: str) -> str:
        o = self.owners(key, 1)
        if not o:
            raise FleetError("hash ring is empty — no alive workers")
        return o[0]


# -- request plumbing ------------------------------------------------------


@dataclass
class FleetResult:
    """Router-side completion record for one fleet render request."""
    view_id: int
    img: Optional[np.ndarray]
    psnr: Optional[float]
    latency_s: float                     # router submit -> result
    worker_latency_s: float              # worker enqueue -> worker reply
    timed_out: bool
    scene: str
    worker: str
    replayed: bool = False


class FleetFuture:
    """Completion handle for a routed render. Always resolves: with a
    `FleetResult` (possibly timed-out), or raises `FleetError` when no
    alive worker could serve it."""

    def __init__(self, view_id: int, scene: str):
        self.view_id = view_id
        self.scene = scene
        self._event = threading.Event()
        self._result: Optional[FleetResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> FleetResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"fleet request {self.view_id} ({self.scene}) not done "
                f"within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def _set(self, result: FleetResult):
        self._result = result
        self._event.set()

    def _set_error(self, err: BaseException):
        self._error = err
        self._event.set()


@dataclass
class _Pending:
    """One in-flight request as the router tracks it (for completion,
    and for replay/fail when its worker dies)."""
    req: int
    future: FleetFuture
    scene: str
    cam: object
    gt: Optional[np.ndarray]
    deadline_t: Optional[float]          # absolute perf_counter deadline
    t0: float
    replayed: bool = False


@dataclass
class _WorkerState:
    name: str
    proc: object
    conn: object
    send_lock: threading.Lock = field(default_factory=threading.Lock)
    pending: Dict[int, _Pending] = field(default_factory=dict)
    control: Dict[int, threading.Event] = field(default_factory=dict)
    control_reply: Dict[int, Dict] = field(default_factory=dict)
    scenes: set = field(default_factory=set)   # registered on this worker
    alive: bool = True
    reader: Optional[threading.Thread] = None
    last_stats: Dict = field(default_factory=dict)


# -- router ----------------------------------------------------------------


class FleetRouter:
    """Scene-affinity router over `n_workers` fleet worker processes.

    `scenes` maps scene name -> `fleet.export_scene` directory; scenes
    are registered on workers lazily, right before the first render each
    worker sees for that scene (pipe FIFO guarantees ordering), so
    spawning K workers doesn't front-load K full registrations per scene.
    """

    def __init__(self, cfg, scenes: Dict[str, str], *, n_workers: int = 2,
                 engine_kwargs: Optional[Dict] = None,
                 registry: Optional[MetricsRegistry] = None,
                 vnodes: int = 64, deadline_s: Optional[float] = None):
        import multiprocessing as mp

        self.cfg = cfg
        self.scene_paths = dict(scenes)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.default_deadline_s = deadline_s
        self._engine_kwargs = dict(engine_kwargs or {})
        self._replicas: Dict[str, int] = {}
        self._pins: Dict[str, Dict] = {}   # scene -> {pinned, priority}
        self._req_ids = itertools.count(1)
        self._view_ids = itertools.count(0)
        self._lock = lockdebug.make_lock("router", kind="rlock")
        self._closed = False

        # unlabelled fleet families created eagerly so every metrics
        # snapshot carries the full schema (check_metrics_schema pins
        # them) even before the first death/replay/timeout happens
        for fam in ("fleet_replays_total", "fleet_worker_deaths",
                    "fleet_timeouts_total", "fleet_prefetches_total"):
            self.registry.counter(fam)
        self.registry.gauge("fleet_replicas", scene="_none").set(0)
        self.registry.histogram("fleet_latency_s")

        ctx = mp.get_context("spawn")
        self.ring = HashRing(vnodes=vnodes)
        self._workers: Dict[str, _WorkerState] = {}
        for i in range(int(n_workers)):
            name = f"w{i}"
            proc, conn = fleet.spawn_worker(ctx, name, cfg,
                                            self._engine_kwargs)
            st = _WorkerState(name=name, proc=proc, conn=conn)
            st.reader = threading.Thread(target=self._reader_loop,
                                         args=(st,), name=f"reader-{name}",
                                         daemon=True)
            self._workers[name] = st
            self.ring.add(name)
            st.reader.start()
        # reader threads are live from here on: a worker dying mid-spawn
        # already mutates the ring under the lock, so read it there too
        with self._lock:
            self._set_routing_gauges()

    # -- metrics helpers ---------------------------------------------------

    def _set_routing_gauges(self):
        self.registry.gauge("fleet_routing_version").set(self.ring.version)
        self.registry.gauge("fleet_workers_alive").set(
            sum(1 for w in self._workers.values() if w.alive))

    # -- wire helpers ------------------------------------------------------

    def _send(self, st: _WorkerState, msg: Dict):
        with st.send_lock:
            st.conn.send_bytes(fleet.pack_msg(msg))

    def _control(self, st: _WorkerState, msg: Dict,
                 timeout: float = 30.0) -> Dict:
        """Send a control op and wait for its ack/reply."""
        req = next(self._req_ids)
        msg = dict(msg, req=req)
        ev = threading.Event()
        st.control[req] = ev
        try:
            self._send(st, msg)
        except (OSError, BrokenPipeError):
            st.control.pop(req, None)
            raise FleetError(f"worker {st.name} unreachable")
        if not ev.wait(timeout):
            st.control.pop(req, None)
            raise FleetError(
                f"worker {st.name} did not ack {msg.get('op')!r} "
                f"within {timeout}s")
        reply = st.control_reply.pop(req, {})
        if reply.get("op") == "err":
            raise FleetError(f"worker {st.name}: {reply.get('error')}")
        return reply

    # -- reader thread -----------------------------------------------------

    def _reader_loop(self, st: _WorkerState):
        while True:
            try:
                raw = st.conn.recv_bytes()
            except (EOFError, OSError):
                break
            try:
                m = fleet.unpack_msg(raw)
            except fleet.WireError:
                continue
            req = m.get("req")
            op = m.get("op")
            if op in ("result",) or (op == "err" and req in st.pending):
                p = st.pending.pop(req, None)
                if p is None:
                    continue
                if op == "err":
                    p.future._set_error(FleetError(
                        f"worker {st.name}: {m.get('error')}"))
                    continue
                self.registry.counter("fleet_results_total",
                                      worker=st.name).inc()
                lat = time.perf_counter() - p.t0
                self.registry.histogram("fleet_latency_s").record(lat)
                if m.get("timed_out"):
                    self.registry.counter("fleet_timeouts_total").inc()
                p.future._set(FleetResult(
                    view_id=p.future.view_id, img=m.get("img"),
                    psnr=m.get("psnr"), latency_s=lat,
                    worker_latency_s=float(m.get("worker_latency_s", 0.0)),
                    timed_out=bool(m.get("timed_out")), scene=p.scene,
                    worker=st.name, replayed=p.replayed))
            else:
                ev = st.control.get(req)
                if ev is not None:
                    st.control_reply[req] = m
                    ev.set()
        self._on_worker_death(st)

    # -- failure handling --------------------------------------------------

    def _on_worker_death(self, st: _WorkerState):
        """Pipe EOF from a worker: re-hash its shard range and resolve
        every request that was in flight on it — replay live requests on
        a surviving owner, complete already-expired ones as timed-out."""
        with self._lock:
            if not st.alive:
                return
            st.alive = False
            orphans = list(st.pending.values())
            st.pending.clear()
            for req, ev in list(st.control.items()):
                st.control_reply[req] = {
                    "op": "err", "error": f"worker {st.name} died"}
                ev.set()
            if self._closed:
                # expected reader exit during shutdown — not a death.
                for p in orphans:
                    p.future._set_error(FleetError("router closed"))
                return
            self.ring.remove(st.name)
            self.registry.counter("fleet_worker_deaths").inc()
            self._set_routing_gauges()
        for p in orphans:
            now = time.perf_counter()
            if p.deadline_t is not None and now >= p.deadline_t:
                # deadline already passed — same semantics as an engine
                # flush discovering a stale request: timed-out result.
                self.registry.counter("fleet_timeouts_total").inc()
                p.future._set(FleetResult(
                    view_id=p.future.view_id, img=None, psnr=None,
                    latency_s=now - p.t0, worker_latency_s=0.0,
                    timed_out=True, scene=p.scene, worker=st.name,
                    replayed=p.replayed))
                continue
            try:
                self.registry.counter("fleet_replays_total").inc()
                self._dispatch(p, replay=True)
            except FleetError as e:
                p.future._set_error(e)

    # -- scene placement ---------------------------------------------------

    def _alive(self, name: str) -> Optional[_WorkerState]:
        st = self._workers.get(name)
        return st if st is not None and st.alive else None

    def _ensure_registered(self, st: _WorkerState, scene: str):
        """Register `scene` on `st` ahead of its first render there. The
        register travels the same FIFO pipe as the render, so ordering is
        guaranteed without waiting for the ack here — but we do wait, so
        registration failures surface on this call, not a later render."""
        if scene in st.scenes:
            return
        path = self.scene_paths.get(scene)
        if path is None:
            raise FleetError(f"unknown scene {scene!r}")
        pin = self._pins.get(scene, {})
        self._control(st, {"op": "register", "scene": scene, "path": path,
                           "pin": bool(pin.get("pinned", False)),
                           "priority": int(pin.get("priority", 0))},
                      timeout=120.0)
        st.scenes.add(scene)
        self.registry.counter("fleet_registrations_total",
                              worker=st.name).inc()

    def _pick_worker(self, scene: str,
                     prefer_worker: Optional[str] = None) -> _WorkerState:
        """Replica choice: among the scene's ring owners (replica count
        for hot scenes, else 1), the one with fewest outstanding
        requests. `prefer_worker` overrides for tests — affinity is
        policy, any alive worker may serve any scene."""
        if prefer_worker is not None:
            st = self._alive(prefer_worker)
            if st is None:
                raise FleetError(f"worker {prefer_worker!r} is not alive")
            return st
        n = self._replicas.get(scene, 1)
        owners = [self._alive(o) for o in self.ring.owners(scene, n)]
        owners = [o for o in owners if o is not None]
        if not owners:
            raise FleetError(f"no alive worker for scene {scene!r}")
        return min(owners, key=lambda st: len(st.pending))

    def _dispatch(self, p: _Pending, *, replay: bool = False,
                  prefer_worker: Optional[str] = None):
        with self._lock:
            st = self._pick_worker(p.scene, prefer_worker)
            self._ensure_registered(st, p.scene)
            p.replayed = p.replayed or replay
            msg = {"op": "render", "req": p.req, "scene": p.scene}
            msg.update(fleet.cam_to_wire(p.cam))
            if p.gt is not None:
                msg["gt"] = np.asarray(p.gt, np.float32)
            if p.deadline_t is not None:
                # recompute remaining time at (re)send so replays keep the
                # original wall-clock deadline, not a fresh one.
                msg["deadline_s"] = max(0.0,
                                        p.deadline_t - time.perf_counter())
            st.pending[p.req] = p
            try:
                self._send(st, msg)
            except (OSError, BrokenPipeError):
                st.pending.pop(p.req, None)
                raise FleetError(f"worker {st.name} unreachable")
            self.registry.counter("fleet_requests_total",
                                  worker=st.name).inc()
            self.registry.gauge("fleet_outstanding",
                                worker=st.name).set(len(st.pending))

    # -- public API --------------------------------------------------------

    def submit(self, cam, gt=None, *, scene: str,
               deadline_s: Optional[float] = None,
               prefer_worker: Optional[str] = None) -> FleetFuture:
        """Route one render. Returns a `FleetFuture` that always
        resolves — result, timed-out result, or `FleetError`."""
        with self._lock:
            closed = self._closed
        if closed:
            raise FleetError("router is closed")
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        t0 = time.perf_counter()
        p = _Pending(req=next(self._req_ids),
                     future=FleetFuture(next(self._view_ids), scene),
                     scene=scene,
                     cam=cam,
                     gt=None if gt is None else np.asarray(gt, np.float32),
                     deadline_t=None if deadline_s is None
                     else t0 + float(deadline_s),
                     t0=t0)
        self._dispatch(p, prefer_worker=prefer_worker)
        return p.future

    def set_replicas(self, scene: str, n: int):
        """Replicate a hot scene onto its first `n` ring owners; later
        submits pick the least-loaded replica. Registration is eager so
        the fan-out exists before the popularity spike it serves."""
        n = max(1, int(n))
        with self._lock:
            self._replicas[scene] = n
            self.registry.gauge("fleet_replicas", scene=scene).set(n)
            for name in self.ring.owners(scene, n):
                st = self._alive(name)
                if st is not None:
                    self._ensure_registered(st, scene)

    def replica_workers(self, scene: str) -> List[str]:
        with self._lock:
            n = self._replicas.get(scene, 1)
            return [o for o in self.ring.owners(scene, n)
                    if self._alive(o) is not None]

    def pin(self, scene: str, pinned: bool = True, *,
            priority: Optional[int] = None):
        """Pin (and optionally prioritise) a scene on every worker that
        has it; remembered for workers that register it later."""
        with self._lock:
            entry = self._pins.setdefault(scene, {})
            entry["pinned"] = bool(pinned)
            if priority is not None:
                entry["priority"] = int(priority)
            for st in self._workers.values():
                if st.alive and scene in st.scenes:
                    msg = {"op": "pin", "scene": scene, "pinned": pinned}
                    if priority is not None:
                        msg["priority"] = int(priority)
                    self._control(st, msg)

    def set_priority(self, scene: str, priority: int):
        with self._lock:
            pinned = self._pins.get(scene, {}).get("pinned", False)
            self.pin(scene, pinned, priority=priority)

    def prefetch(self, scene: str):
        """Async revival of a predicted-next scene on its owner."""
        with self._lock:
            st = self._pick_worker(scene)
            self._ensure_registered(st, scene)
            self._control(st, {"op": "prefetch", "scene": scene})
            self.registry.counter("fleet_prefetches_total").inc()

    def evict(self, scene: str, worker: Optional[str] = None):
        with self._lock:
            targets = ([self._alive(worker)] if worker else
                       [st for st in self._workers.values() if st.alive])
            for st in targets:
                if st is not None and scene in st.scenes:
                    self._control(st, {"op": "evict", "scene": scene})

    def inject(self, worker: str, *, stall_s: float):
        """Fault injection: plant a pre-flush stall in a worker (used by
        the slow-worker fixtures in tests/conftest.py)."""
        with self._lock:
            st = self._alive(worker)
        if st is None:
            raise FleetError(f"worker {worker!r} is not alive")
        self._control(st, {"op": "inject", "stall_s": float(stall_s)})

    def worker_pid(self, worker: str) -> int:
        with self._lock:
            return self._workers[worker].proc.pid

    def alive_workers(self) -> List[str]:
        with self._lock:
            return sorted(n for n, st in self._workers.items() if st.alive)

    def owner_of(self, scene: str) -> str:
        with self._lock:
            return self.ring.owner(scene)

    def poll_stats(self, timeout: float = 30.0) -> Dict[str, Dict]:
        """Fetch per-worker engine stats and refresh the per-worker
        gauges (`fleet_worker_fps` / `_queue_depth` / `_evictions`)."""
        out: Dict[str, Dict] = {}
        with self._lock:
            workers = list(self._workers.items())
        for name, st in workers:
            if not st.alive:
                continue
            try:
                reply = self._control(st, {"op": "stats"}, timeout=timeout)
            except FleetError:
                continue
            s = reply.get("stats", {})
            st.last_stats = s
            out[name] = s
            self.registry.gauge("fleet_worker_fps", worker=name).set(
                float(s.get("fps", 0.0)))
            self.registry.gauge("fleet_worker_queue_depth",
                                worker=name).set(
                int(s.get("queue_depth", 0)))
            self.registry.gauge("fleet_worker_evictions", worker=name).set(
                int(s.get("evictions", 0)))
        return out

    def stats(self) -> Dict:
        """Fleet roll-up: routing state + per-worker engine stats."""
        workers = self.poll_stats()
        snap = self.registry.snapshot()["counters"]
        with self._lock:
            routing_version = self.ring.version

        def total(prefix):
            return sum(v["value"] for k, v in snap.items()
                       if k == prefix or k.startswith(prefix + "{"))

        return {
            "routing_version": routing_version,
            "workers_alive": len(self.alive_workers()),
            "requests_total": total("fleet_requests_total"),
            "results_total": total("fleet_results_total"),
            "replays_total": total("fleet_replays_total"),
            "worker_deaths": total("fleet_worker_deaths"),
            "timeouts_total": total("fleet_timeouts_total"),
            "prefetches_total": total("fleet_prefetches_total"),
            "registrations_total": total("fleet_registrations_total"),
            "latency_p95_s": self.registry.histogram(
                "fleet_latency_s").percentile(95.0),
            "workers": workers,
        }

    # -- lifecycle ---------------------------------------------------------

    def close(self, timeout: float = 15.0):
        """Graceful shutdown: ask workers to exit, then join/terminate."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            workers = list(self._workers.values())
        for st in workers:
            if st.alive:
                try:
                    self._send(st, {"op": "shutdown"})
                except (OSError, BrokenPipeError):
                    pass
        for st in workers:
            st.proc.join(timeout)
            if st.proc.is_alive():
                st.proc.terminate()
                st.proc.join(5.0)
            st.alive = False
            try:
                st.conn.close()
            except OSError:
                pass
        with self._lock:
            self._set_routing_gauges()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


__all__ = ["HashRing", "FleetRouter", "FleetFuture", "FleetResult",
           "FleetError"]
