"""Temporal tier: frame-coherent radiance warping for streaming serving.

Real AR/VR traffic is video — consecutive cameras along a head-tracked
path are nearly identical, yet a stateless engine re-renders every ray of
every frame. Following Cicero (PAPERS.md), this module reprojects the
previous frame's radiance to the new camera and flags the pixels the
reprojection cannot vouch for; the engine then renders ONLY those rays
(`RenderEngine.submit_delta`) and composites warped + fresh into a full
frame. On smooth paths most pixels warp, so per-frame work drops to the
disocclusion fringe — multiplicative with the fused-kernel speedups,
since the delta rays still go through the same jitted compacted step.

The warp is a forward splat:

  1. unproject — every source pixel becomes a world point at its rendered
     surface depth (`aux["depth"]`/`aux["opacity"]` from
     `pipeline.make_ray_renderer`: depth is the opacity-weighted expected
     termination E[w·t], so surface distance = depth / opacity; pixels
     with ~zero opacity are background and sit on a far plane, which is
     color-correct for the white-background scenes served here),
  2. project — world points into the new camera (exact inverse of
     `rendering.pixel_rays`),
  3. splat — nearest-wins z-buffer into the target pixel grid,
  4. confidence — a target pixel is confident only if it was covered by
     at least one splat AND its winning source pixel was not on a depth
     discontinuity (silhouettes hide disocclusions); the low-confidence
     set is dilated so one-pixel misses don't survive as speckle.

Everything here is numpy on purpose: the warp is O(H*W) pointer math per
frame, runs on the submitting thread (traced as the `warp`/`mask` stages),
and must not compete with the jitted render steps for the accelerator.

`plan_delta` turns the confidence mask into a padded fresh-ray index list
(bucketed so the per-flush chunk count — and therefore the jitted step's
shapes — stays stable frame to frame) plus the `warp_fraction` telemetry
the registry exports (`warp_rays_total`, `warp_fraction` histogram).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.rendering import Camera


@dataclasses.dataclass
class WarpResult:
    """Previous frame forward-warped to a new camera (all (H*W,...) numpy,
    row-major like `rendering.camera_rays`)."""
    rgb: np.ndarray          # (H*W, 3) warped radiance (white where uncovered)
    depth: np.ndarray        # (H*W,) E[w·t] in the NEW camera (renderer units)
    opacity: np.ndarray      # (H*W,) carried source opacity (0 = background)
    confidence: np.ndarray   # (H*W,) bool — True = safe to reuse, False =
                             # disoccluded / depth edge / off-screen: re-render
    h: int = 0
    w: int = 0

    @property
    def warp_fraction(self) -> float:
        """Fraction of the frame the warp can serve without rendering."""
        return float(np.mean(self.confidence)) if self.confidence.size else 0.0


def _camera_rays_np(cam: Camera) -> np.ndarray:
    """numpy twin of `rendering.camera_rays` directions (H*W, 3), unit."""
    h, w, f = int(cam.h), int(cam.w), float(cam.focal)
    py, px = np.meshgrid(np.arange(h, dtype=np.float64),
                         np.arange(w, dtype=np.float64), indexing="ij")
    x = (px.reshape(-1) + 0.5 - w / 2.0) / f
    y = -(py.reshape(-1) + 0.5 - h / 2.0) / f
    d_cam = np.stack([x, y, -np.ones_like(x)], axis=-1)
    d = d_cam @ np.asarray(cam.c2w, np.float64).T
    return d / np.linalg.norm(d, axis=-1, keepdims=True)


def _project_np(cam: Camera, pts: np.ndarray):
    """World points -> (px, py, z) in `cam` — exact inverse of
    `rendering.pixel_rays` (z is the forward camera-space depth; points
    with z <= 0 are behind the camera)."""
    rel = (pts - np.asarray(cam.origin, np.float64)) \
        @ np.asarray(cam.c2w, np.float64)            # world->cam: R^T (p-o)
    z = -rel[:, 2]
    zs = np.where(z > 1e-9, z, 1.0)                  # keep the divide finite
    px = rel[:, 0] / zs * float(cam.focal) + cam.w / 2.0 - 0.5
    py = -rel[:, 1] / zs * float(cam.focal) + cam.h / 2.0 - 0.5
    return px, py, z


def warp_radiance(prev_frame: np.ndarray, prev_cam: Camera, new_cam: Camera,
                  depth: np.ndarray, *, opacity: Optional[np.ndarray] = None,
                  min_opacity: float = 0.05, far: Optional[float] = None,
                  depth_grad_thresh: float = 0.15,
                  dilate: int = 1) -> WarpResult:
    """Forward-warp the previous frame's radiance to a new camera.

    prev_frame (H*W, 3) and depth/opacity (H*W,) are the renderer outputs
    for `prev_cam` (`ViewResult.img` / `.depth` / `.opacity`); depth is
    the accumulated E[w·t], so the surface distance along each unit ray is
    depth / opacity. `opacity=None` treats depth as the surface distance
    directly. Pixels below `min_opacity` are background and warp on a far
    plane at `far` (default: 1.5x the deepest surface — far enough that
    background parallax is sub-pixel for nearby cameras).

    Returns a `WarpResult` whose confidence mask is False exactly where
    the new frame must be rendered: target pixels no source splat covered
    (disocclusion / entered the frustum), pixels whose winning source sat
    on a depth discontinuity of relative size > `depth_grad_thresh`
    (silhouettes), and a `dilate`-step 3x3 dilation of both."""
    h, w = int(prev_cam.h), int(prev_cam.w)
    n = h * w
    rgb_src = np.asarray(prev_frame, np.float64).reshape(n, 3)
    d_acc = np.asarray(depth, np.float64).reshape(n)
    if opacity is None:
        op = np.ones(n)
        t_surf = d_acc.copy()
    else:
        op = np.clip(np.asarray(opacity, np.float64).reshape(n), 0.0, 1.0)
        t_surf = d_acc / np.maximum(op, 1e-6)
    fg = op >= min_opacity
    if far is None:
        far = 1.5 * float(t_surf[fg].max()) if fg.any() else \
            2.0 * float(np.linalg.norm(np.asarray(prev_cam.origin))) + 1.0
    t_surf = np.where(fg, t_surf, far)

    # source-space depth edges: a pixel adjacent to a large relative depth
    # jump sits on a silhouette — its far side hides a disocclusion, so
    # neither side of the edge is trustworthy after reprojection
    t_img = t_surf.reshape(h, w)
    grad = np.zeros((h, w))
    grad[:, 1:] = np.maximum(grad[:, 1:], np.abs(np.diff(t_img, axis=1)))
    grad[:, :-1] = np.maximum(grad[:, :-1], np.abs(np.diff(t_img, axis=1)))
    grad[1:, :] = np.maximum(grad[1:, :], np.abs(np.diff(t_img, axis=0)))
    grad[:-1, :] = np.maximum(grad[:-1, :], np.abs(np.diff(t_img, axis=0)))
    edge_src = (grad > depth_grad_thresh * np.maximum(t_img, 1e-6)).reshape(n)

    # unproject -> project -> nearest-wins splat
    pts = np.asarray(prev_cam.origin, np.float64) \
        + _camera_rays_np(prev_cam) * t_surf[:, None]
    px, py, z = _project_np(new_cam, pts)
    t_new = np.linalg.norm(pts - np.asarray(new_cam.origin, np.float64),
                           axis=-1)
    pxi = np.round(px).astype(np.int64)
    pyi = np.round(py).astype(np.int64)
    ok = (z > 1e-9) & (pxi >= 0) & (pxi < w) & (pyi >= 0) & (pyi < h)
    src = np.flatnonzero(ok)
    tgt = pyi[src] * w + pxi[src]
    # write far-to-near so the nearest source wins every contested pixel;
    # tie-break on source index for a deterministic warp
    order = np.lexsort((src, -t_new[src]))
    src, tgt = src[order], tgt[order]

    out_rgb = np.ones((n, 3))                 # white background where bare
    out_depth = np.zeros(n)
    out_op = np.zeros(n)
    covered = np.zeros(n, bool)
    edge_hit = np.zeros(n, bool)
    out_rgb[tgt] = rgb_src[src]
    # keep the E[w·t] representation so a warped frame can seed the next
    # warp exactly like a rendered one: depth = surface distance * opacity
    out_depth[tgt] = np.where(fg[src], t_new[src] * op[src], 0.0)
    out_op[tgt] = np.where(fg[src], op[src], 0.0)
    covered[tgt] = True
    edge_hit[tgt] = edge_src[src]

    bad = (~covered) | edge_hit
    bad = bad.reshape(h, w)
    for _ in range(max(int(dilate), 0)):
        grown = bad.copy()
        grown[1:, :] |= bad[:-1, :]
        grown[:-1, :] |= bad[1:, :]
        grown[:, 1:] |= bad[:, :-1]
        grown[:, :-1] |= bad[:, 1:]
        bad = grown
    return WarpResult(rgb=out_rgb, depth=out_depth, opacity=out_op,
                      confidence=~bad.reshape(n), h=h, w=w)


@dataclasses.dataclass
class DeltaPlan:
    """The fresh-ray work order `submit_delta` attaches to a request."""
    warp: WarpResult
    idx: np.ndarray          # (n_padded,) int64 pixel indices to re-render;
                             # entries past n_real are pad (pixel 0, whose
                             # fresh value overwrites harmlessly)
    n_real: int              # true low-confidence count
    warp_fraction: float     # confident fraction of the frame

    @property
    def n_rays(self) -> int:
        return int(self.idx.shape[0])


def plan_delta(warp: WarpResult, *, bucket: int) -> DeltaPlan:
    """Turn a confidence mask into a padded fresh-ray index list.

    The index count is rounded up to a multiple of `bucket` (minimum one
    bucket) so the number of micro-batch chunks a delta frame contributes
    — and therefore the jitted step invocations per flush — is stable
    across frames instead of tracking the disocclusion count. Pad entries
    point at pixel 0: they render a duplicate fresh value whose composite
    write is idempotent."""
    if bucket <= 0:
        raise ValueError(f"bucket must be positive, got {bucket}")
    need = np.flatnonzero(~warp.confidence)
    n_real = int(need.size)
    n_pad = max(-(-n_real // bucket), 1) * int(bucket)
    idx = np.zeros(n_pad, np.int64)
    idx[:n_real] = need
    n_pix = warp.confidence.size
    frac = 1.0 - n_real / n_pix if n_pix else 0.0
    return DeltaPlan(warp=warp, idx=idx, n_real=n_real, warp_fraction=frac)
