"""SceneStore: a registry of named resident scenes under one device-memory
budget — the multi-scene pivot of the serving layer.

RT-NeRF's hybrid bitmap/COO encoding (paper Sec. 4.2) exists so that *many*
scenes fit in device memory at once; this module is where that pays off for
serving. A `SceneStore` owns, per named scene, the resident published
state: the (normally encoded) `FieldBackend`, its occupancy `CubeSet`, a
per-scene `pipeline.OrderingCache`, and cumulative serving/swap telemetry.
Everything scene-shaped in the serving layer routes through it:

  * `RenderEngine` resolves `submit(cam, scene=...)` against the store and
    renders each flush group from a consistent per-scene snapshot;
  * `FineTuneLoop.attach(store, scene)` publishes refreshed fields through
    `publish()`, so fine-tuning and eviction serialize on the store lock
    and can never race;
  * the **memory budget** (`max_resident_bytes`, defaulting from
    `NeRFConfig.max_resident_bytes`) bounds the total encoded factor bytes
    resident across scenes. Registering, publishing, or reviving a scene
    that would exceed the budget LRU-evicts cold scenes: their encoded
    streams are demoted to disk via `ckpt.spill_field` (bit-for-bit, no
    decompress) together with their cube set, and the next
    `submit`/`publish`/`get_field` touching them revives the identical
    representation via `ckpt.unspill_field` — a revived scene renders
    bit-identically to its pre-eviction self.

Lock order (engine lock -> store lock, never the reverse): the store lock
guards scene records and the LRU clock; renders never run under it — the
engine takes per-scene snapshots (field, cubes, ordering) under the lock
and renders outside, so an in-flight flush keeps its snapshot alive (and
consistent) even if the scene is concurrently evicted or republished.

Telemetry lives in ONE `obs.MetricsRegistry` per store (shared with the
engine serving it and every fine-tune loop attached to it): per-scene
counters/gauges/bounded-ring histograms replace the ad-hoc deques the
records used to carry, `stats()` keys are computed from the registry
bit-compatibly, and the same registry backs the JSON/Prometheus
exposition (`serve --metrics-port`). Swap latencies are a bounded ring
(maxlen 256) with the all-time `swap_latency_s_max` kept by the
histogram — per-publish state never grows for the life of the service.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import threading
import time
from typing import Dict, List, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs.rtnerf import NeRFConfig
from repro.core import distributed, occupancy as occ_lib
from repro.core import field as field_lib
from repro.core import pipeline as rt_pipe
from repro.core.occupancy import CubeSet
from repro.obs import Counter, Histogram, MetricsRegistry, lockdebug

CUBES_FILE = "cubes.npz"

# repro-lint declarations (scripts/repro_lint.py, docs/static_analysis.md).
# `assume_held` methods are called with the store lock held (reentrant
# RLock callers) — the lock is a precondition, not acquired inside.
GUARDED_BY = {
    "SceneStore": {
        "lock": "_lock",
        "attrs": ("_records", "_clock", "_spill_dir", "_rules"),
        "assume_held": ("_get", "_touch", "_enforce_budget"),
    },
}
LOCK_ATTR_CLASSES = {
    "SceneStore.metrics": "MetricsRegistry",
    "SceneStore._evictions_total": "Counter",
    "SceneStore._revivals_total": "Counter",
    "SceneStore._swap_latency_last": "Gauge",
}


def save_cubes(directory: str, cubes: CubeSet):
    """Persist a CubeSet next to a spilled/exported field (`CUBES_FILE`)
    so revival reloads the exact geometry instead of rebuilding it. Shared
    by the store's eviction path and the fleet tier's scene export
    (`serving.fleet.export_scene`)."""
    np.savez(os.path.join(directory, CUBES_FILE),
             centers=np.asarray(cubes.centers),
             valid=np.asarray(cubes.valid), count=cubes.count,
             radius=cubes.radius, occ=np.asarray(cubes.occ))


def load_cubes(directory: str) -> CubeSet:
    """Inverse of `save_cubes` (reloaded, never rebuilt)."""
    with np.load(os.path.join(directory, CUBES_FILE)) as z:
        return CubeSet(jnp.asarray(z["centers"]), jnp.asarray(z["valid"]),
                       int(z["count"]), float(z["radius"]),
                       jnp.asarray(z["occ"]))


class SceneSnapshot(NamedTuple):
    """A consistent per-scene view for one flush: renders read this, never
    the live record, so publishes/evictions mid-render can't tear it."""
    scene: str
    field: field_lib.FieldBackend
    cubes: CubeSet
    ordering: rt_pipe.OrderingCache
    factor_bytes: int
    factor_bytes_dense: int


@dataclasses.dataclass(eq=False)
class SceneMetrics:
    """One scene's registry handles (cumulative — they survive eviction).

    Latency and swap-latency are bounded-ring histograms (percentiles over
    the recent window, all-time count/max kept by the histogram itself),
    so per-request and per-publish state never grows for the life of a
    long-running service; `views_served`/`swaps` count everything.
    """
    views_served: Counter
    latencies: Histogram          # window 4096
    render_s: Counter
    swaps: Counter
    swap_latencies: Histogram     # window 256; .max is the all-time max
    evictions: Counter
    revivals: Counter

    @classmethod
    def create(cls, registry: MetricsRegistry, scene: str) -> "SceneMetrics":
        return cls(
            views_served=registry.counter("scene_views_served", scene=scene),
            latencies=registry.histogram("scene_latency_s", maxlen=4096,
                                         scene=scene),
            render_s=registry.counter("scene_render_s", scene=scene),
            swaps=registry.counter("scene_swaps", scene=scene),
            swap_latencies=registry.histogram("scene_swap_latency_s",
                                              maxlen=256, scene=scene),
            evictions=registry.counter("scene_evictions", scene=scene),
            revivals=registry.counter("scene_revivals", scene=scene),
        )


@dataclasses.dataclass(eq=False)
class SceneRecord:
    """One named scene: resident state + metrics that survive eviction."""
    name: str
    m: SceneMetrics
    field: Optional[field_lib.FieldBackend] = None
    cubes: Optional[CubeSet] = None
    ordering: Optional[rt_pipe.OrderingCache] = None
    factor_bytes: int = 0
    factor_bytes_dense: int = 0
    resident: bool = False
    spill_path: Optional[str] = None
    last_used: int = 0
    pinned: bool = False          # never LRU-evicted while pinned
    priority: int = 0             # higher survives budget pressure longer
    _ord_hits: int = 0            # ordering counters parked while evicted
    _ord_misses: int = 0
    _ord_nn_hits: int = 0


class SceneStore:
    """Named resident scenes with LRU eviction under a byte budget."""

    def __init__(self, cfg: NeRFConfig, *, rules=None, encode: bool = True,
                 order_mode: str = "octant",
                 max_resident_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.encode_fields = bool(encode)
        self.order_mode = order_mode
        if max_resident_bytes is None:
            max_resident_bytes = cfg.max_resident_bytes
        self.max_resident_bytes = (int(max_resident_bytes)
                                   if max_resident_bytes else None)
        self._spill_dir = spill_dir
        self._rules = rules
        self._lock = lockdebug.make_lock("store", kind="rlock")
        self._records: Dict[str, SceneRecord] = {}
        self._clock = 0
        # one registry per store, shared by the engine serving it and by
        # attached fine-tune loops — NOT the process default, so two
        # stores in one process never bleed counters into each other
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._evictions_total = self.metrics.counter("store_evictions")
        self._revivals_total = self.metrics.counter("store_revivals")
        self._swap_latency_last = self.metrics.gauge(
            "store_swap_latency_s_last")

    @property
    def evictions_total(self) -> int:
        return int(self._evictions_total.value)

    @property
    def revivals_total(self) -> int:
        return int(self._revivals_total.value)

    @property
    def last_swap_latency_s(self) -> float:
        return self._swap_latency_last.value

    # -- infrastructure ----------------------------------------------------

    @property
    def rules(self):
        # lazy init is a write: guarded, so two first-callers (e.g. a
        # register racing a publish) can't both build a mesh
        with self._lock:
            if self._rules is None:
                from repro.launch.mesh import make_host_mesh
                from repro.models.sharding import make_rules
                self._rules = make_rules(make_host_mesh())
            return self._rules

    @property
    def spill_dir(self) -> str:
        with self._lock:
            if self._spill_dir is None:
                self._spill_dir = tempfile.mkdtemp(prefix="scene_store_")
            return self._spill_dir

    def _touch(self, rec: SceneRecord):
        self._clock += 1
        rec.last_used = self._clock

    def _prepare(self, field, cubes: Optional[CubeSet]):
        """Coerce -> normalise representation -> place on the mesh. encode
        serves the hybrid streams (no-op when pre-encoded); encode=False
        decodes, the dense-baseline toggle. Cubes rebuild at the shared
        `cfg.occ_sigma_thresh` when not supplied."""
        field = field_lib.as_backend(field, self.cfg)
        field = field.encode() if self.encode_fields else field.decode()
        field = distributed.place_field(field, self.rules)
        if cubes is None:
            occ = occ_lib.build_occupancy(field, self.cfg)
            cubes = occ_lib.extract_cubes(occ, self.cfg)
        return field, cubes

    # -- scene lifecycle ---------------------------------------------------

    def register(self, name: str, field, cubes: Optional[CubeSet] = None
                 ) -> SceneRecord:
        """Make `name` resident with `field` (+ optional precomputed cubes).
        Registering an existing name is an error — republish via
        `publish()`, which keeps the scene's telemetry."""
        def taken():
            return ValueError(
                f"scene '{name}' already registered — use publish() to "
                f"replace its field")
        with self._lock:                  # fail fast, before the encode/
            if name in self._records:     # occupancy work in _prepare
                raise taken()
        field, cubes = self._prepare(field, cubes)
        with self._lock:
            if name in self._records:     # lost a register-register race
                raise taken()
            rec = SceneRecord(name=name,
                              m=SceneMetrics.create(self.metrics, name))
            self._records[name] = rec
            self._install(rec, field, cubes)
            self._touch(rec)
            self._enforce_budget(protect=name)
        return rec

    def _install(self, rec: SceneRecord, field, cubes: CubeSet):
        """Publish (field, cubes) into `rec` (store lock held, field already
        prepared). A NEW ordering cache, counters carried — a flush holding
        the previous snapshot stays consistent."""
        rec.field = field
        rec.cubes = cubes
        if rec.ordering is not None:
            rec.ordering = rec.ordering.with_cubes(cubes)
        else:
            rec.ordering = rt_pipe.OrderingCache(cubes, self.order_mode,
                                                 scene=rec.name,
                                                 registry=self.metrics)
            rec.ordering.hits, rec.ordering.misses, rec.ordering.nn_hits = \
                (rec._ord_hits, rec._ord_misses, rec._ord_nn_hits)
        rec.factor_bytes = field.factor_bytes()
        rec.factor_bytes_dense = field.dense_factor_bytes()
        rec.resident = True

    def publish(self, name: str, field, cubes: Optional[CubeSet] = None):
        """Atomically replace a scene's served field (the swap_field /
        fine-tune path). The scene needn't be resident: publishing into an
        evicted scene revives it around the new field. Queued engine
        requests are never dropped — they render from the new snapshot at
        their flush. Pass precomputed `cubes` (as FineTuneLoop does) to
        keep the lock hold, and with it the producer-visible swap latency,
        to the pointer switch."""
        t0 = time.perf_counter()
        field, cubes = self._prepare(field, cubes)
        with self._lock:
            rec = self._get(name)
            self._install(rec, field, cubes)
            self._touch(rec)
            swap_s = time.perf_counter() - t0
            rec.m.swaps.inc()
            rec.m.swap_latencies.record(swap_s)   # bounded ring, all-time max
            self._swap_latency_last.set(swap_s)
            self._enforce_budget(protect=name)

    def update_cubes(self, name: str, cubes: CubeSet):
        """Occupancy rebuilt (e.g. the field was re-pruned): swap the cube
        set; the ordering cache restarts empty (counters carried)."""
        with self._lock:
            rec = self.ensure_resident(name)
            rec.cubes = cubes
            rec.ordering = rec.ordering.with_cubes(cubes)

    def _get(self, name: str) -> SceneRecord:
        rec = self._records.get(name)
        if rec is None:
            raise KeyError(
                f"unknown scene '{name}' (registered: "
                f"{sorted(self._records) or 'none'})")
        return rec

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._records

    def scenes(self) -> List[str]:
        with self._lock:
            return sorted(self._records)

    def first_scene(self) -> Optional[str]:
        """Earliest-registered scene name — the engine's default route for
        scene-less (single-scene, pre-store) call sites."""
        with self._lock:
            return next(iter(self._records), None)

    def resident_scenes(self) -> List[str]:
        with self._lock:
            return sorted(n for n, r in self._records.items() if r.resident)

    def resident_bytes(self) -> int:
        with self._lock:
            return sum(r.factor_bytes for r in self._records.values()
                       if r.resident)

    # -- pin / priority (fleet-tier hooks) ---------------------------------

    def pin(self, name: str, pinned: bool = True):
        """Pin a scene against LRU eviction: a pinned scene is never chosen
        as a budget victim (explicit `evict()` still works — the caller is
        being deliberate there). The fleet router pins a worker's share of
        replicated hot scenes so popularity spikes on cold scenes can't
        evict them."""
        with self._lock:
            self._get(name).pinned = bool(pinned)

    def set_priority(self, name: str, priority: int):
        """Eviction priority: under budget pressure the LOWEST-priority
        resident scene is evicted first (ties broken by LRU clock).
        Default 0; the router maps scene popularity onto this."""
        with self._lock:
            self._get(name).priority = int(priority)

    # -- eviction / revival ------------------------------------------------

    def _enforce_budget(self, protect: Optional[str] = None):
        """Evict resident scenes until under budget. Victim order: lowest
        priority first, then least-recently-used. Never evicts `protect`,
        pinned scenes, or the last one standing if it alone exceeds the
        budget — an unserveable store would be worse than an over-budget
        one."""
        if self.max_resident_bytes is None:
            return
        while self.resident_bytes() > self.max_resident_bytes:
            victims = [r for r in self._records.values()
                       if r.resident and r.name != protect and not r.pinned]
            if not victims:
                break
            self.evict(min(victims,
                           key=lambda r: (r.priority, r.last_used)).name)

    def evict(self, name: str):
        """Demote a resident scene to its encoded checkpoint: spill the
        bitmap/COO streams as-is (`ckpt.spill_field`) plus the cube set,
        then drop the device-side references. Telemetry stays on the
        record; the ordering cache's counters are parked for revival."""
        with self._lock:
            rec = self._get(name)
            if not rec.resident:
                return
            path = os.path.join(self.spill_dir, name)
            ckpt_lib.spill_field(path, rec.field,
                                 extra_meta={"scene": name})
            save_cubes(path, rec.cubes)
            rec._ord_hits = rec.ordering.hits
            rec._ord_misses = rec.ordering.misses
            rec._ord_nn_hits = rec.ordering.nn_hits
            rec.field = rec.cubes = rec.ordering = None
            rec.spill_path = path
            rec.resident = False
            rec.m.evictions.inc()
            self._evictions_total.inc()

    def ensure_resident(self, name: str) -> SceneRecord:
        """Revive `name` from its spill checkpoint if evicted (bit-for-bit:
        `ckpt.unspill_field` rebuilds the exact encoded representation, and
        the cube set is reloaded, not rebuilt). Touches the LRU clock."""
        with self._lock:
            rec = self._get(name)
            if not rec.resident:
                field, _ = ckpt_lib.unspill_field(rec.spill_path, self.cfg)
                cubes = load_cubes(rec.spill_path)
                # placement only — the representation is already encoded
                field = distributed.place_field(
                    field_lib.as_backend(field, self.cfg), self.rules)
                self._install(rec, field, cubes)
                rec.m.revivals.inc()
                self._revivals_total.inc()
                self._touch(rec)
                self._enforce_budget(protect=name)
            self._touch(rec)
            return rec

    # -- engine-facing reads -----------------------------------------------

    def snapshot(self, name: str) -> SceneSnapshot:
        """The consistent (field, cubes, ordering) triple one flush group
        renders from, reviving the scene first if needed."""
        with self._lock:
            rec = self.ensure_resident(name)
            return SceneSnapshot(name, rec.field, rec.cubes, rec.ordering,
                                 rec.factor_bytes, rec.factor_bytes_dense)

    def get_field(self, name: str) -> field_lib.FieldBackend:
        """The currently published field (revived if evicted) — what a
        fine-tuner attaching to this scene starts from."""
        with self._lock:
            return self.ensure_resident(name).field

    def note_served(self, name: str, latencies: List[float],
                    render_s: float):
        """Commit one flush group's serving telemetry to the scene."""
        with self._lock:
            rec = self._get(name)
            rec.m.views_served.inc(len(latencies))
            rec.m.latencies.extend(latencies)
            rec.m.render_s.inc(render_s)

    # -- telemetry ---------------------------------------------------------

    def _scene_stats(self, rec: SceneRecord) -> Dict:
        m = rec.m
        views, render_s = int(m.views_served.value), m.render_s.value
        ordering = (rec.ordering.stats() if rec.ordering is not None
                    else {"hits": rec._ord_hits, "misses": rec._ord_misses,
                          "nn_hits": rec._ord_nn_hits, "entries": 0})
        return {
            "scene": rec.name,
            "resident": rec.resident,
            "views_served": views,
            "fps": views / render_s if render_s > 0 else 0.0,
            "render_s": render_s,
            "latency_p50_s": m.latencies.percentile(50),
            "latency_p95_s": m.latencies.percentile(95),
            "latency_p99_s": m.latencies.percentile(99),
            "factor_bytes": float(rec.factor_bytes),
            "factor_bytes_dense": float(rec.factor_bytes_dense),
            "compression_ratio": (rec.factor_bytes_dense
                                  / max(rec.factor_bytes, 1)),
            "field_kind": (rec.field.kind if rec.resident else "evicted"),
            "occ_accesses_per_view": (float(rec.cubes.count)
                                      if rec.resident else 0.0),
            "pinned": rec.pinned,
            "priority": rec.priority,
            "swaps": int(m.swaps.value),
            "swap_latency_s_last": m.swap_latencies.last,
            "swap_latency_s_max": m.swap_latencies.max,   # all-time
            "evictions": int(m.evictions.value),
            "revivals": int(m.revivals.value),
            "ordering_cache": ordering,
        }

    def stats(self, scene: Optional[str] = None) -> Dict:
        with self._lock:
            if scene is not None:
                return self._scene_stats(self._get(scene))
            return {
                "n_scenes": len(self._records),
                "resident_scenes": self.resident_scenes(),
                "resident_bytes": self.resident_bytes(),
                "max_resident_bytes": self.max_resident_bytes,
                "evictions": self.evictions_total,
                "revivals": self.revivals_total,
                "scenes": {n: self._scene_stats(r)
                           for n, r in sorted(self._records.items())},
            }
