"""Online fine-tuning service: background trainer -> live per-scene
publish loop through the SceneStore.

Closes the ROADMAP's "multi-scene fine-tuning with one trainer thread per
resident field" item: a `FineTuneLoop` *attaches* to one named scene in a
`serving.store.SceneStore` — `FineTuneLoop.attach(store, scene)` — owns a
`core.train.NerfTrainer` for it (compressed-native, support revival at
every `occ_every` boundary), and runs it on a background thread while the
`RenderEngine` keeps serving every resident scene. Every `publish_every`
steps it snapshots the trainer's field, rebuilds the occupancy cube set
*on the trainer thread*, and publishes through `SceneStore.publish` —
so fine-tuning serializes with LRU eviction on the store lock and the two
can never race: a publish into a scene that was evicted mid-round simply
revives it around the refreshed field. Zero dropped or retraced requests:
the jitted render step takes the field as a pytree argument, and queued
futures survive the swap by construction (engine contract, tested in
tests/test_serving.py / tests/test_store.py).

Run several loops — one per resident scene — to fine-tune a whole store
from one process (`launch/serve.py --scenes a,b,c --finetune-steps N`).

This is the paper's serving story made live: RT-NeRF's hybrid bitmap/COO
encoding and view-dependent ordering (Sec. 3/4) assume resident fields
that track their scenes; Re-ReND (arXiv:2303.08717) makes the same point
for cross-device real-time rendering — the served representation must stay
current without recompilation stalls.

API:
    loop = FineTuneLoop.attach(store, "lego", steps=400, publish_every=100)
    loop.start()            # background thread; the engine keeps serving
    ...                     # submit(cam, scene=...) from any thread
    loop.join()             # waits, re-raises trainer errors
    loop.swaps              # [{step, train_psnr, swap_s, t_wall}, ...]

The pre-store constructor `FineTuneLoop(engine, "lego", ...)` still works
(deprecation shim): it resolves the engine's store and targets the scene
of that name if registered, else the engine's default scene.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core import occupancy as occ_lib
from repro.core import train as train_lib
from repro.obs import lockdebug
from repro.serving.store import SceneStore

# repro-lint lock-discipline declarations (docs/static_analysis.md).
# `history`, `swaps`, and `_error` are written on the trainer thread and
# read from the caller's thread (join(), progress polling); `_lock` is a
# leaf: nothing else is ever acquired while it is held.
GUARDED_BY = {
    "FineTuneLoop": {"lock": "_lock",
                     "attrs": ("history", "swaps", "_error")},
}


class FineTuneLoop:
    """Background compressed-native fine-tuning published into one named
    scene of a live SceneStore.

    The trainer starts from `start_field` when given, else from the
    scene's currently-published field (true *fine*-tuning of the scene
    being served — revived from its spill checkpoint if it was evicted);
    `start_field="init"` trains from a fresh initialisation. One
    publication is always made for the final step, so `steps >=
    publish_every` guarantees at least one swap and `steps >= 2 *
    publish_every` at least two.
    """

    def __init__(self, target, scene_name: str, *,
                 scene: Optional[str] = None, steps: int = 400,
                 publish_every: int = 100, occ_every: Optional[int] = None,
                 n_views: int = 8, image_hw: int = 64,
                 prune_tol: float = 1e-3, revive_frac: float = 0.05,
                 seed: int = 0, start_field=None, verbose: bool = False):
        if isinstance(target, SceneStore):
            store, engine = target, None
        elif hasattr(target, "store"):            # RenderEngine shim
            engine = target
            store = engine.store
        else:
            raise TypeError(
                f"FineTuneLoop target must be a SceneStore or RenderEngine, "
                f"not {type(target).__name__}")
        if scene is None:
            # legacy routing: the training-data scene name if it is a
            # registered store key, else the engine's default scene
            if scene_name in store:
                scene = scene_name
            elif engine is not None:
                scene = engine.default_scene
            else:
                scene = scene_name
        if scene not in store:
            raise KeyError(
                f"scene '{scene}' is not registered in the store "
                f"(registered: {store.scenes() or 'none'}) — register it "
                f"before attaching a fine-tuner")
        self.store = store
        self.scene = scene
        self.steps = int(steps)
        self.publish_every = max(int(publish_every), 1)
        self.verbose = bool(verbose)
        # telemetry lands in the store's shared registry (the same one the
        # engine and exposition read), labelled by the published scene
        m = store.metrics
        self._m_steps = m.counter("finetune_steps", scene=scene)
        self._m_publish_s = m.histogram("finetune_publish_s", maxlen=256,
                                        scene=scene)
        self._g_train_psnr = m.gauge("finetune_train_psnr", scene=scene)
        if start_field is None:
            start_field = store.get_field(scene)   # revives if evicted
        elif start_field == "init":
            start_field = None
        self.trainer = train_lib.NerfTrainer(
            store.cfg, scene_name, field=start_field, n_views=n_views,
            image_hw=image_hw,
            occ_every=(self.publish_every if occ_every is None
                       else int(occ_every)),
            prune_tol=prune_tol, revive_frac=revive_frac, seed=seed,
            verbose=verbose)
        self._lock = lockdebug.make_lock("finetune")
        self.history: List[Dict[str, float]] = []
        self.swaps: List[Dict[str, float]] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._t0 = 0.0

    @classmethod
    def attach(cls, store: SceneStore, scene: str, *,
               data_scene: Optional[str] = None, **kw) -> "FineTuneLoop":
        """One trainer thread for one resident scene: train on
        `data_scene` (default: the scene itself) and publish into
        `store`'s `scene` record."""
        return cls(store, data_scene or scene, scene=scene, **kw)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "FineTuneLoop":
        if self._thread is not None:
            raise RuntimeError("fine-tune loop already started")
        self._t0 = time.perf_counter()
        self._thread = threading.Thread(
            target=self._run, name=f"finetune-trainer-{self.scene}")
        self._thread.start()
        return self

    def stop(self):
        """Request an early exit (the current step finishes first)."""
        self._stop.set()

    def join(self, timeout: Optional[float] = None):
        """Wait for the trainer thread; re-raise any trainer error."""
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError("fine-tune loop still running")
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def __enter__(self) -> "FineTuneLoop":
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        self.join()
        return False

    # -- trainer thread ----------------------------------------------------

    def _run(self):
        try:
            for i in range(self.steps):
                if self._stop.is_set():
                    break
                rec = self.trainer.step()
                rec["t_wall"] = time.perf_counter() - self._t0
                self._m_steps.inc()
                self._g_train_psnr.set(rec["psnr"])
                with self._lock:
                    self.history.append(rec)
                if (i + 1) % self.publish_every == 0 or i == self.steps - 1:
                    self._publish(rec)
        except BaseException as e:                # re-raised by join()
            with self._lock:
                self._error = e

    def _publish(self, rec: Dict[str, float]):
        """Snapshot -> occupancy rebuild (this thread) -> store.publish.
        Everything expensive happens off the serving path; the store lock
        is held only for the pointer switch inside publish — and because
        eviction also runs under that lock, a publish lands either wholly
        before or wholly after any eviction of this scene (after an
        eviction it revives the scene around the refreshed field)."""
        t_pub = time.perf_counter()
        field = self.trainer.snapshot()
        occ = occ_lib.build_occupancy(field, self.store.cfg)
        cubes = occ_lib.extract_cubes(occ, self.store.cfg)
        t0 = time.perf_counter()
        self.store.publish(self.scene, field, cubes)
        swap_s = time.perf_counter() - t0
        # full cost of one publication (snapshot + occupancy rebuild +
        # swap) — the store's scene_swap_latency_s records the swap alone
        self._m_publish_s.record(time.perf_counter() - t_pub)
        with self._lock:
            self.swaps.append(
                {"step": rec["step"], "train_psnr": rec["psnr"],
                 "swap_s": swap_s,
                 "t_wall": time.perf_counter() - self._t0})
        if self.verbose:
            print(f"  [finetune:{self.scene}] step {rec['step']:5d} "
                  f"published field (train-psnr {rec['psnr']:.2f}, "
                  f"swap {swap_s * 1e3:.1f}ms)", flush=True)
