"""Online fine-tuning service: background trainer -> live `swap_field` loop.

Closes the ROADMAP's "wire the train->serve loop end to end" item: a
`FineTuneLoop` owns a `core.train.NerfTrainer` (compressed-native — the
factors stay hybrid-encoded between steps, with support revival at every
`occ_every` boundary) and runs it on a background thread while a
`RenderEngine` keeps serving. Every `publish_every` steps it snapshots the
trainer's field, rebuilds the occupancy cube set *on the trainer thread*
(so the engine lock is held only for the pointer switch), and publishes
through `RenderEngine.swap_field` — zero dropped or retraced requests:
the jitted render step takes the field as a pytree argument, so a
refreshed field with the same encoded structure hits the compiled cache,
and queued futures survive the swap by construction (engine contract,
tested in tests/test_serving.py / tests/test_finetune.py).

This is the paper's serving story made live: RT-NeRF's hybrid bitmap/COO
encoding and view-dependent ordering (Sec. 3/4) assume a resident field
that tracks the scene; Re-ReND (arXiv:2303.08717) makes the same point for
cross-device real-time rendering — the served representation must stay
current without recompilation stalls.

API:
    loop = FineTuneLoop(engine, "lego", steps=400, publish_every=100)
    loop.start()            # background thread; engine keeps serving
    ...                     # submit() from any thread meanwhile
    loop.join()             # waits, re-raises trainer errors
    loop.swaps              # [{step, train_psnr, swap_s, t_wall}, ...]

`launch/serve.py --finetune-steps/--finetune-every` wires this into the
serving CLI; `examples/finetune_serve.py` demonstrates PSNR climbing while
views stream; `benchmarks/finetune_serving.py` measures swap latency, FPS
during training, and PSNR-vs-wall-clock (BENCH_finetune.json).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core import occupancy as occ_lib
from repro.core import train as train_lib


class FineTuneLoop:
    """Background compressed-native fine-tuning published into a live
    engine via `swap_field`.

    The trainer starts from `start_field` when given, else from the
    engine's currently-resident field (true *fine*-tuning of the scene
    being served); `start_field="init"` trains from a fresh initialisation.
    One publication is always made for the final step, so `steps >=
    publish_every` guarantees at least one swap and `steps >= 2 *
    publish_every` at least two.
    """

    def __init__(self, engine, scene_name: str, *, steps: int = 400,
                 publish_every: int = 100, occ_every: Optional[int] = None,
                 n_views: int = 8, image_hw: int = 64,
                 prune_tol: float = 1e-3, revive_frac: float = 0.05,
                 seed: int = 0, start_field=None, verbose: bool = False):
        self.engine = engine
        self.steps = int(steps)
        self.publish_every = max(int(publish_every), 1)
        self.verbose = bool(verbose)
        if start_field is None:
            start_field = engine.field
        elif start_field == "init":
            start_field = None
        self.trainer = train_lib.NerfTrainer(
            engine.cfg, scene_name, field=start_field, n_views=n_views,
            image_hw=image_hw,
            occ_every=(self.publish_every if occ_every is None
                       else int(occ_every)),
            prune_tol=prune_tol, revive_frac=revive_frac, seed=seed,
            verbose=verbose)
        self.history: List[Dict[str, float]] = []
        self.swaps: List[Dict[str, float]] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._t0 = 0.0

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "FineTuneLoop":
        if self._thread is not None:
            raise RuntimeError("fine-tune loop already started")
        self._t0 = time.perf_counter()
        self._thread = threading.Thread(target=self._run,
                                        name="finetune-trainer")
        self._thread.start()
        return self

    def stop(self):
        """Request an early exit (the current step finishes first)."""
        self._stop.set()

    def join(self, timeout: Optional[float] = None):
        """Wait for the trainer thread; re-raise any trainer error."""
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError("fine-tune loop still running")
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def __enter__(self) -> "FineTuneLoop":
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        self.join()
        return False

    # -- trainer thread ----------------------------------------------------

    def _run(self):
        try:
            for i in range(self.steps):
                if self._stop.is_set():
                    break
                rec = self.trainer.step()
                rec["t_wall"] = time.perf_counter() - self._t0
                self.history.append(rec)
                if (i + 1) % self.publish_every == 0 or i == self.steps - 1:
                    self._publish(rec)
        except BaseException as e:                # re-raised by join()
            self._error = e

    def _publish(self, rec: Dict[str, float]):
        """Snapshot -> occupancy rebuild (this thread) -> swap_field.
        Everything expensive happens off the serving path; the engine lock
        is held only for the pointer switch inside swap_field."""
        field = self.trainer.snapshot()
        occ = occ_lib.build_occupancy(field, self.engine.cfg)
        cubes = occ_lib.extract_cubes(occ, self.engine.cfg)
        t0 = time.perf_counter()
        self.engine.swap_field(field, cubes)
        swap_s = time.perf_counter() - t0
        self.swaps.append({"step": rec["step"], "train_psnr": rec["psnr"],
                           "swap_s": swap_s,
                           "t_wall": time.perf_counter() - self._t0})
        if self.verbose:
            print(f"  [finetune] step {rec['step']:5d} published field "
                  f"(train-psnr {rec['psnr']:.2f}, swap {swap_s * 1e3:.1f}ms)",
                  flush=True)
